"""Distributed per-shard-greedy AP (subprocess, 8 host devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_distributed_ap_converges():
    body = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.ap import distributed_ap_sweeps
    from repro.gp.hyperparams import HyperParams
    from repro.gp.kernels_math import regularised_kernel_matrix
    from repro.data.synthetic import make_gp_regression

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, d, s, b = 128, 2, 3, 8   # n_loc=16, 2 blocks/shard
    x, y = make_gp_regression(jax.random.PRNGKey(0), n, d, noise=0.3)
    rhs = jnp.concatenate(
        [y[:, None], jax.random.normal(jax.random.PRNGKey(1), (n, s))], 1)
    params = HyperParams.create(d, noise=0.5)
    sh = NamedSharding(mesh, P(("data", "model"), None))
    xs = jax.device_put(x, sh)
    bs = jax.device_put(rhs, sh)
    v0 = jax.device_put(jnp.zeros_like(rhs), sh)

    step = jax.jit(lambda xx, bb, vv: distributed_ap_sweeps(
        xx, bb, vv, params, mesh, block_size=b, num_iters=10, omega=0.3))
    v, r = step(xs, bs, v0)

    # the tracked residual must equal the true residual
    h = regularised_kernel_matrix(x, params)
    r_true = rhs - h @ v
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_true),
                               rtol=1e-3, atol=1e-3)
    # and it must DECREASE vs the initial residual, and keep decreasing
    def relres(rr):
        return float(jnp.max(jnp.linalg.norm(rr, axis=0) /
                             jnp.linalg.norm(rhs, axis=0)))
    res1 = relres(r)
    assert res1 < 1.0
    v2, r2 = step(xs, bs, v)   # warm-started continuation
    assert relres(r2) < res1
    print("DIST_AP_OK", res1, relres(r2))
    """)
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        + body
    )
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "DIST_AP_OK" in r.stdout
