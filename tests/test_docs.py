"""Documentation gates: docstring coverage and markdown link integrity
stay clean (tools/docs_lint.py is also a standalone CI job)."""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import docs_lint  # noqa: E402


def test_docs_lint_clean():
    findings = docs_lint.run_lint(REPO)
    assert findings == [], "\n".join(findings)


def test_lint_detects_missing_docstring(tmp_path):
    """The checker itself must flag undocumented public API."""
    p = tmp_path / "mod.py"
    p.write_text('"""Doc."""\ndef public():\n    pass\n\ndef _private():\n'
                 '    pass\n')
    findings = docs_lint.missing_docstrings(p)
    assert len(findings) == 1 and "public" in findings[0]


def test_lint_detects_broken_link(tmp_path):
    p = tmp_path / "page.md"
    p.write_text("# Title\n\n[ok](page.md) [bad](missing.md) "
                 "[anchor](#title) [bad-anchor](#nope)\n")
    findings = docs_lint.broken_links(p, tmp_path)
    assert len(findings) == 2
    assert any("missing.md" in f for f in findings)
    assert any("#nope" in f for f in findings)
