"""Regression tests for the lock-discipline findings repro-lint surfaced:
the ArtifactPoller's unguarded poll state, the monitor handler's unlocked
``ticks`` read, and the admission counter's unbounded f-string label."""
import threading

import pytest

from repro.obs import metrics as obs_metrics
from repro.serve.cluster.admission import AdmissionController, Priority
from repro.serve.cluster.monitor import FleetMonitor
from repro.serve.cluster.store import ArtifactPoller

#: Closed outcome-label vocabulary of gp_admission_decisions_total.
_OUTCOMES = {"admitted", "bypass", "shed_rate", "shed_inflight",
             "shed_deadline", "shed_other"}


def test_poller_status_is_locked_snapshot(tmp_path):
    """status() reads the poll state under the poller's lock — including
    concurrently with a poll_once() that is writing it."""
    store = str(tmp_path)
    # A LATEST pointer to a version directory that does not exist makes
    # every poll fail after the version check: poll_once then writes
    # last_error while the readers hammer status().
    (tmp_path / "LATEST").write_text("v000001_feedface\n")
    poller = ArtifactPoller(store, target=None, interval_s=60.0)

    errors = []

    def hammer():
        for _ in range(200):
            snap = poller.status()
            if set(snap) != {"version", "swaps", "last_error"}:
                errors.append(snap)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for _ in range(50):
        assert not poller.poll_once()
    for t in threads:
        t.join()
    assert errors == []
    snap = poller.status()
    assert snap["swaps"] == 0 and snap["version"] is None
    assert snap["last_error"]  # the failed fetch is visible to readers


def test_poller_expire_never_blocks_on_missing_store(tmp_path):
    """An empty store (no LATEST) polls clean: no swap, no error."""
    poller = ArtifactPoller(str(tmp_path), target=None, interval_s=60.0)
    assert not poller.poll_once()
    assert poller.status() == {"version": None, "swaps": 0,
                               "last_error": None}


def test_monitor_tick_count_accessor():
    """tick_count() (the /healthz read) agrees with fleet_slo()['ticks']
    and goes through the status lock rather than the raw attribute."""
    monitor = FleetMonitor(targets={}, interval_s=60.0)
    assert monitor.tick_count() == 0
    monitor.tick()
    monitor.tick()
    assert monitor.tick_count() == 2
    assert monitor.fleet_slo()["ticks"] == 2


@pytest.mark.parametrize("setup,expected", [
    (dict(max_inflight=64), "admitted"),
    (dict(max_inflight=0), "shed_inflight"),
    (dict(rate_qps=1e-9, burst=1e-9, max_inflight=64), "shed_rate"),
])
def test_admission_outcome_labels_are_bounded(setup, expected):
    """Every admit() outcome maps into the closed label vocabulary (the
    old f-string spelling could mint a series per novel reason)."""
    reg = obs_metrics.MetricsRegistry()
    adm = AdmissionController(registry=reg, **setup)
    adm.admit(rows=1)
    fam = reg.counter("gp_admission_decisions_total", "Admission outcomes",
                      labelnames=("outcome",))
    series = fam.render()
    assert len(series) == 1 and f'outcome="{expected}"' in series[0]
    assert fam.value(outcome=expected) == 1.0


def test_admission_bypass_label():
    reg = obs_metrics.MetricsRegistry()
    adm = AdmissionController(registry=reg, max_inflight=0)
    decision = adm.admit(rows=1, priority=Priority.REFRESH)
    assert decision.admitted and decision.reason == "bypass"
    fam = reg.counter("gp_admission_decisions_total", "Admission outcomes",
                      labelnames=("outcome",))
    series = fam.render()
    assert len(series) == 1 and 'outcome="bypass"' in series[0]
