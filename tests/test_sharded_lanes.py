"""Device-sharded lane sweeps and traced per-lane solver numerics.

This module is the ``shard-smoke`` CI target: run it standalone under

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest -q tests/test_sharded_lanes.py

and every test executes against 8 virtual host devices. Inside the shared
tier-1 process the backend is already live with however many devices exist
(forcing a count here would break the smoke/dry-run tests — see
tests/conftest.py), so the in-process tests adapt to the current device
count and a dedicated subprocess test re-runs the parity check with the
8-device flag forced, keeping the multi-device path covered in tier-1 too.
"""
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OuterConfig, fit, fit_batch
from repro.core.outer import outer_scan
from repro.data.synthetic import make_gp_regression
from repro.launch.mesh import make_lane_mesh
from repro.solvers import (
    SolverConfig,
    numerics_of,
    stack_numerics,
    strip_numerics,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORCE_8 = "--xla_force_host_platform_device_count=8"

# 2 seeds x 2 tolerances x 2 learning rates = 8 lanes; the parity check
# meshes over gcd(devices, 8) so any host device count works.
SEEDS = (0, 1)
TOLS = (0.05, 0.005)
LRS = (0.5, 1.0)


def _grid_problem():
    x, y = make_gp_regression(jax.random.PRNGKey(2), 64, 2, noise=0.3)
    base = SolverConfig(name="sgd", tolerance=0.01, max_epochs=40,
                        batch_size=32, learning_rate=0.5)
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=3,
                      num_probes=4, num_rff_pairs=64, bm=64, bn=64,
                      solver=strip_numerics(base))
    cells = [(s, t, lr) for s in SEEDS for t in TOLS for lr in LRS]
    keys = jnp.stack([jax.random.PRNGKey(s) for s, _, _ in cells])
    nums = stack_numerics([
        numerics_of(SolverConfig(name="sgd", tolerance=t, max_epochs=40,
                                 batch_size=32, learning_rate=lr))
        for _, t, lr in cells
    ])
    return x, y, cfg, cells, keys, nums


def run_parity_check(expect_devices: int = 0):
    """Sharded fit_batch == unsharded fit_batch, per lane, one executable
    each. Callable from the subprocess runner below (``__main__``)."""
    if expect_devices:
        assert len(jax.devices()) == expect_devices, (
            f"expected {expect_devices} forced host devices, "
            f"got {len(jax.devices())}"
        )
    x, y, cfg, cells, keys, nums = _grid_problem()

    c0 = outer_scan._cache_size()
    plain = fit_batch(x, y, cfg, keys, numerics=nums)
    c1 = outer_scan._cache_size()
    assert c1 - c0 == 1, "unsharded tol x lr grid must compile exactly once"

    # Largest device count that divides the 8 lanes, so the check also
    # works on hosts whose device count is not in {1, 2, 4, 8} (e.g. a
    # 3-GPU box) instead of tripping the divisibility error.
    mesh = make_lane_mesh(math.gcd(len(jax.devices()), len(cells)))
    sharded = fit_batch(x, y, cfg, keys, numerics=nums, mesh=mesh)
    c2 = outer_scan._cache_size()
    assert c2 - c1 == 1, "sharded grid must compile exactly once"

    for i in range(len(cells)):
        np.testing.assert_array_equal(
            plain[i].history["iters"], sharded[i].history["iters"],
            err_msg=f"lane {i} iters")
        np.testing.assert_allclose(
            plain[i].history["hypers"], sharded[i].history["hypers"],
            rtol=1e-4, atol=1e-6, err_msg=f"lane {i} hypers")
        np.testing.assert_allclose(
            plain[i].history["res_y"], sharded[i].history["res_y"],
            rtol=1e-2, atol=1e-5, err_msg=f"lane {i} res_y")
    return plain


def test_sharded_fit_batch_matches_unsharded():
    """Parity at the CURRENT device count (8 in the shard-smoke CI job,
    whatever exists in the shared tier-1 process)."""
    run_parity_check()


def test_lanes_must_divide_device_count():
    ndev = len(jax.devices())
    if ndev == 1:
        pytest.skip("every lane count divides a 1-device mesh")
    x, y, cfg, _, keys, nums = _grid_problem()
    bad = ndev - 1  # 1 <= bad < ndev: never a multiple of ndev
    with pytest.raises(ValueError, match="multiple"):
        fit_batch(x, y, cfg, keys[:bad],
                  numerics=jax.tree.map(lambda v: v[:bad], nums),
                  mesh=make_lane_mesh())


def test_per_lane_numerics_match_static_config_fits():
    """Each lane of the tolerance x lr grid must reproduce a single fit
    whose STATIC config bakes in the same numbers — traced numerics are a
    compile-sharing mechanism, not a different algorithm."""
    x, y, cfg, cells, keys, nums = _grid_problem()
    batch = fit_batch(x, y, cfg, keys, numerics=nums)
    for i in (0, 3, 5):  # spot-check lanes across the numeric grid
        s, t, lr = cells[i]
        cfg_i = OuterConfig(
            estimator="pathwise", warm_start=True, num_steps=3,
            num_probes=4, num_rff_pairs=64, bm=64, bn=64,
            solver=SolverConfig(name="sgd", tolerance=t, max_epochs=40,
                                batch_size=32, learning_rate=lr))
        single = fit(x, y, cfg_i, key=jax.random.PRNGKey(s))
        np.testing.assert_array_equal(batch[i].history["iters"],
                                      single.history["iters"])
        np.testing.assert_allclose(batch[i].history["hypers"],
                                   single.history["hypers"],
                                   rtol=1e-4, atol=1e-6)


def test_numeric_grid_lanes_actually_differ():
    """Sanity that the grid exercises the early-stopping trade-off: a loose
    tolerance stops earlier than a tight one on the same seed/lr."""
    x, y, cfg, cells, keys, nums = _grid_problem()
    batch = fit_batch(x, y, cfg, keys, numerics=nums)
    by_cell = dict(zip(cells, batch))
    loose = by_cell[(0, TOLS[0], LRS[0])].history["iters"].sum()
    tight = by_cell[(0, TOLS[1], LRS[0])].history["iters"].sum()
    assert loose < tight, (loose, tight)


def test_sharded_parity_on_8_forced_devices():
    """Tier-1 coverage of the real multi-device path: re-run the parity
    check in a fresh process with 8 forced virtual host devices (the shared
    pytest process cannot re-initialise its backend)."""
    if len(jax.devices()) >= 8:
        pytest.skip("already running on >= 8 devices (shard-smoke lane)")
    if jax.default_backend() != "cpu":
        pytest.skip("forcing host devices only affects the CPU backend")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_8).strip()
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "8"],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert r.returncode == 0, (r.stderr or r.stdout)[-3000:]
    assert "PARITY OK on 8 devices" in r.stdout


def test_tolerance_lr_grid_is_one_executable_per_group(tmp_path):
    """launch.batch end-to-end: a seed x tolerance x lr grid (8 cells, one
    kernel) runs as ONE group with exactly one compile, emits one tagged
    JSON per numeric cell, and --shard-lanes round-trips at the current
    device count."""
    from repro.launch import batch

    out = str(tmp_path / "grid")
    argv = ["--out", out, "--dataset", "pol", "--max-n", "128",
            "--kernels", "matern32", "--seeds", "2", "--steps", "2",
            "--smoke", "--bm", "64", "--bn", "64", "--solver", "sgd",
            "--tolerances", "0.05,0.01", "--sgd-lrs", "0.5,1.0",
            "--expect-one-compile-per-group"]
    if len(jax.devices()) in (1, 2, 4, 8):
        argv.append("--shard-lanes")
    assert batch.main(argv) == 0
    with open(tmp_path / "grid" / "_sweep_status.json") as f:
        status = json.load(f)
    assert status["cells"] == 8 and status["groups"] == 1
    assert status["num_compiles"] == 1 and not status["failures"]
    names = sorted(p.name for p in (tmp_path / "grid").iterdir()
                   if not p.name.startswith("_"))
    assert len(names) == 8
    assert "gp-iterative-matern32__s0__tol0.05__lr0.5.json" in names
    rec = json.loads(
        (tmp_path / "grid" / names[0]).read_text())
    assert rec["tolerance"] in (0.05, 0.01) and rec["lanes"] == 8
    # resumable: nothing left to do on re-run
    assert batch.main(argv[:-1]) == 0
    with open(tmp_path / "grid" / "_sweep_status.json") as f:
        assert json.load(f)["cells"] == 0


if __name__ == "__main__":
    # Subprocess entry for test_sharded_parity_on_8_forced_devices: the
    # caller sets XLA_FLAGS before interpreter start, so the forced device
    # count actually takes effect here.
    expect = int(sys.argv[1]) if len(sys.argv) > 1 else 0
    run_parity_check(expect_devices=expect)
    print(f"PARITY OK on {len(jax.devices())} devices")
