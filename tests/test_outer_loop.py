"""Outer-loop behaviour: convergence toward the exact trajectory, the
warm-start bias theorem in practice (Thm. 1), pathwise conditioning
predictions, and warm-start/early-stopping synergy (paper §5)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # multi-step outer-MLL fits; ~1 min on CPU

from repro.core import (
    PATHWISE,
    STANDARD,
    OuterConfig,
    exact_outer_step,
    init_outer_state,
    outer_step,
    pathwise_predict,
)
from repro.gp.hyperparams import HyperParams
from repro.solvers import SolverConfig
from repro.train.adam import AdamConfig, adam_init


def _run(x, y, cfg, steps, key=0):
    st = init_outer_state(jax.random.PRNGKey(key), cfg, x)
    hypers = []
    for _ in range(steps):
        st, m = outer_step(st, x, y, cfg)
        hypers.append(np.asarray(m["hypers"]))
    return st, np.stack(hypers)


def _run_exact(x, y, steps, d):
    params = HyperParams.create(d)
    adam = adam_init(params)
    acfg = AdamConfig(learning_rate=0.1)
    out = []
    for _ in range(steps):
        params, adam, _ = exact_outer_step(params, adam, x, y, acfg)
        out.append(np.asarray(params.flat()))
    return np.stack(out)


CFG = dict(num_probes=64, num_rff_pairs=800, bm=64, bn=64,
           solver=SolverConfig(name="cg", tolerance=0.01, max_epochs=500,
                               precond_rank=20))
STEPS = 25


@pytest.mark.parametrize("est,warm", [
    (STANDARD, False), (STANDARD, True), (PATHWISE, False), (PATHWISE, True),
])
def test_trajectories_match_exact_optimisation(gp_problem, est, warm):
    """Figs. 5/8: all four estimator/warm-start variants track the exact
    Cholesky trajectory when solving to tolerance."""
    x, y, d = gp_problem["x"], gp_problem["y"], gp_problem["d"]
    cfg = OuterConfig(estimator=est, warm_start=warm, **CFG)
    _, hypers = _run(x, y, cfg, STEPS)
    exact = _run_exact(x, y, STEPS, d)
    # final hyperparameters close in constrained space
    rel = np.abs(hypers[-1] - exact[-1]) / (np.abs(exact[-1]) + 0.1)
    assert rel.max() < 0.15, (est, warm, rel)


def test_warm_start_reduces_total_iterations(gp_problem):
    """Fig. 7: warm starting cuts iterations-to-tolerance along the MLL
    trajectory (vs cold) for the same estimator."""
    x, y = gp_problem["x"], gp_problem["y"]
    iters = {}
    for warm in (False, True):
        cfg = OuterConfig(estimator=PATHWISE, warm_start=warm, **CFG)
        st = init_outer_state(jax.random.PRNGKey(0), cfg, x)
        tot = 0
        for _ in range(STEPS):
            st, m = outer_step(st, x, y, cfg)
            tot += int(m["iters"])
        iters[warm] = tot
    assert iters[True] < iters[False]


def test_budget_mode_warm_start_accumulates_progress(gp_problem):
    """Paper §5/Fig. 10: under a tiny epoch budget, residuals DECREASE over
    outer steps with warm starting and stay high without."""
    x, y = gp_problem["x"], gp_problem["y"]
    budget_solver = SolverConfig(name="cg", tolerance=0.01, max_epochs=3,
                                 precond_rank=0)
    res = {}
    for warm in (False, True):
        cfg = OuterConfig(estimator=PATHWISE, warm_start=warm,
                          num_probes=32, num_rff_pairs=400,
                          solver=budget_solver, bm=64, bn=64)
        st = init_outer_state(jax.random.PRNGKey(0), cfg, x)
        rs = []
        for _ in range(12):
            st, m = outer_step(st, x, y, cfg)
            rs.append(float(m["res_z"]))
        res[warm] = rs
    assert res[True][-1] < res[False][-1]
    assert res[True][-1] < res[True][0]


def test_pathwise_predictions_match_exact_posterior(gp_problem):
    """Eq. 16: posterior mean/variance from pathwise conditioning track the
    exact GP posterior."""
    from repro.gp.exact import exact_posterior

    x, y, xs = gp_problem["x"], gp_problem["y"], gp_problem["xs"]
    cfg = OuterConfig(estimator=PATHWISE, warm_start=True, num_probes=256,
                      num_rff_pairs=2000, bm=64, bn=64,
                      solver=SolverConfig(name="cg", tolerance=0.002,
                                          max_epochs=1000, precond_rank=20))
    st = init_outer_state(jax.random.PRNGKey(0), cfg, x)
    st, _ = outer_step(st, x, y, cfg)
    params_prev = st.params  # predictions use the params the carry solved
    # re-solve at the CURRENT params for a clean comparison
    st2, _ = outer_step(st, x, y, cfg)
    pred = pathwise_predict(x, xs, st2.carry_v, st2.probes, params_prev,
                            bm=64, bn=64)
    ex = exact_posterior(x, y, xs, params_prev)
    err_mean = float(jnp.max(jnp.abs(pred.mean - ex.mean)))
    assert err_mean < 0.1
    # variance within sampling error of the exact latent variance
    rel_var = np.abs(np.asarray(pred.var) - np.asarray(ex.var)) / (
        np.asarray(ex.var) + 1e-3
    )
    assert np.median(rel_var) < 0.5


def test_fixed_probes_under_warm_start_vs_resampled(gp_problem):
    """Warm start fixes the probe base draws; without it they resample each
    step (paper App. B contract)."""
    x, y = gp_problem["x"], gp_problem["y"]
    cfg_w = OuterConfig(estimator=PATHWISE, warm_start=True, num_probes=8,
                        num_rff_pairs=100, bm=64, bn=64,
                        solver=SolverConfig(name="cg", max_epochs=20,
                                            precond_rank=0))
    st = init_outer_state(jax.random.PRNGKey(0), cfg_w, x)
    w0 = np.asarray(st.probes.rff.w)
    st, _ = outer_step(st, x, y, cfg_w)
    st, _ = outer_step(st, x, y, cfg_w)
    np.testing.assert_array_equal(w0, np.asarray(st.probes.rff.w))

    cfg_c = dataclasses.replace(cfg_w, warm_start=False)
    st = init_outer_state(jax.random.PRNGKey(0), cfg_c, x)
    w0 = np.asarray(st.probes.rff.w)
    st, _ = outer_step(st, x, y, cfg_c)
    assert not np.array_equal(w0, np.asarray(st.probes.rff.w))
