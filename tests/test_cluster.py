"""Cluster serving layer: admission control, versioned artifact store,
HTTP transport, replica processes. Process-spawning end-to-end tests are
slow-marked; everything else runs in the fast lane in-process."""
import json
import os
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import OuterConfig, init_outer_state, outer_step
from repro.data.synthetic import make_gp_regression
from repro.serve import (
    BucketedEngine,
    MultiModelServer,
    export_servable,
    servable_predict,
)
from repro.serve.cluster import (
    AdmissionController,
    ArtifactPoller,
    Priority,
    ReplicaSupervisor,
    ServeFrontend,
    TokenBucket,
    WireError,
    fetch_servable,
    latest_version,
    list_versions,
    publish_servable,
    start_http_server,
)
from repro.serve.cluster.replica import _http_json
from repro.solvers import SolverConfig


@pytest.fixture(scope="module")
def fitted():
    x, y = make_gp_regression(jax.random.PRNGKey(0), 160, 2, noise=0.2)
    xq = x[128:]
    x, y = x[:128], y[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=200, precond_rank=0),
        num_steps=2, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    return {"x": x, "y": y, "xq": xq, "cfg": cfg, "state": state}


@pytest.fixture(scope="module")
def model(fitted):
    return export_servable(fitted["state"], fitted["x"])


# -- admission ---------------------------------------------------------------
def test_token_bucket_refill_and_retry_hint():
    tb = TokenBucket(rate=2.0, burst=3.0)
    t = 100.0
    for _ in range(3):
        ok, _ = tb.try_acquire(now=t)
        assert ok
    ok, retry = tb.try_acquire(now=t)
    assert not ok and retry == pytest.approx(0.5)  # 1 token / 2 per s
    ok, _ = tb.try_acquire(now=t + 0.5)  # refilled exactly one token
    assert ok
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)


def test_admission_rate_shed_with_retry_after():
    adm = AdmissionController(buckets=(8, 32), rate_qps=1.0, burst=2.0,
                              max_inflight=100)
    t = 50.0
    assert adm.admit(rows=4, now=t).admitted
    assert adm.admit(rows=4, now=t).admitted
    d = adm.admit(rows=4, now=t)
    assert not d.admitted and d.reason == "rate" and d.retry_after_s > 0
    # a different bucket class has its own tokens
    assert adm.admit(rows=20, now=t).admitted
    assert adm.as_dict()["shed_rate"] == 1


def test_admission_inflight_cap_and_release():
    adm = AdmissionController(max_inflight=2)
    assert adm.admit().admitted
    assert adm.admit().admitted
    d = adm.admit()
    assert not d.admitted and d.reason == "inflight"
    adm.release(0.01)
    assert adm.admit().admitted
    assert adm.inflight == 2


def test_admission_deadline_shed_uses_service_ewma():
    adm = AdmissionController(max_inflight=100)
    # Seed the EWMA: one request that took 2s, while another is in flight.
    assert adm.admit().admitted
    assert adm.admit().admitted
    adm.release(2.0)
    # 1 inflight x ~2s wait >> 100ms deadline => shed before queueing.
    d = adm.admit(deadline_ms=100)
    assert not d.admitted and d.reason == "deadline"
    # A generous deadline is admitted.
    assert adm.admit(deadline_ms=60_000).admitted
    assert adm.as_dict()["shed_deadline"] == 1


def test_admission_priority_never_sheds_admin():
    adm = AdmissionController(rate_qps=0.001, burst=1.0, max_inflight=1)
    assert adm.admit().admitted  # spends the only token, fills the cap
    assert not adm.admit().admitted
    for prio in (Priority.REFRESH, Priority.ADMIN):
        d = adm.admit(priority=prio)
        assert d.admitted and d.reason == "bypass"
    assert adm.as_dict()["bypassed"] == 2


# -- engine stats wire format ------------------------------------------------
def test_engine_stats_as_dict_is_json_and_counts_waste(fitted, model):
    engine = BucketedEngine(model, buckets=(8, 32), bm=64, bn=64)
    compiles = engine.warmup()
    engine.submit(fitted["xq"][:5])   # 3 padded rows in the 8 bucket
    engine.submit(fitted["xq"][:32])  # exact fit
    d = engine.stats_dict()
    json.dumps(d)  # must be JSON-serialisable as-is
    assert d["requests"] == 2 and d["rows"] == 37 and d["padded_rows"] == 3
    assert d["per_bucket"] == {"8": 1, "32": 1}
    assert d["padding_waste"] == pytest.approx(3 / 40)
    assert d["num_compiles"] == compiles


# -- artifact store ----------------------------------------------------------
def test_store_publish_fetch_roundtrip(tmp_path, fitted, model):
    store = str(tmp_path)
    assert latest_version(store) is None
    v1 = publish_servable(store, model, name="pol")
    assert latest_version(store) == v1 == "v0000001"
    loaded, version, manifest = fetch_servable(store)
    assert version == v1 and manifest["name"] == "pol"
    for a, b in zip(jax.tree.leaves(model), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    v2 = publish_servable(store, model._replace(correction=model.correction * 2))
    assert latest_version(store) == v2 and list_versions(store) == [v1, v2]
    old, _, _ = fetch_servable(store, version=v1)  # old versions stay readable
    np.testing.assert_allclose(np.asarray(old.correction),
                               np.asarray(model.correction), rtol=1e-6)


def test_store_verify_detects_corruption(tmp_path, model):
    store = str(tmp_path)
    v1 = publish_servable(store, model)
    payload = os.path.join(store, v1, "step_0.npz")
    with open(payload, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(ValueError, match="hash mismatch"):
        fetch_servable(store)


def test_store_poller_swaps_without_retrace(tmp_path, fitted, model):
    store = str(tmp_path)
    publish_servable(store, model)
    engine = BucketedEngine(None, buckets=(8, 32), bm=64, bn=64)
    poller = ArtifactPoller(store, engine, interval_s=60.0)
    assert poller.poll_once()
    compiles = engine.num_compiles()
    before = engine.submit(fitted["xq"][:5])
    publish_servable(store, model._replace(correction=model.correction * 2))
    assert poller.poll_once()
    after = engine.submit(fitted["xq"][:5])
    # same static shapes + kernel => warm executables reused, no retrace
    assert engine.num_compiles() == compiles
    np.testing.assert_allclose(np.asarray(after.mean),
                               np.asarray(before.mean) * 2, rtol=1e-5)
    assert not poller.poll_once()  # no new version => no swap
    assert poller.swaps == 2


# -- transport (in-process server) ------------------------------------------
@pytest.fixture()
def http_server(tmp_path, model):
    store = str(tmp_path / "store")
    publish_servable(store, model)
    server = MultiModelServer(buckets=(8, 32), bm=64, bn=64)
    adm = AdmissionController(buckets=(8, 32), max_inflight=64)
    frontend = ServeFrontend(server, adm, store_dir=store)
    poller = ArtifactPoller(store, server, interval_s=60.0,
                            on_swap=lambda v, m: setattr(frontend, "version", v))
    assert poller.poll_once()
    frontend.version = poller.version
    httpd, _ = start_http_server(frontend)
    yield {"url": f"http://127.0.0.1:{httpd.port}", "frontend": frontend,
           "store": store, "server": server}
    httpd.shutdown()


def test_http_predict_parity_and_health(http_server, fitted, model):
    url = http_server["url"]
    status, body = _http_json(url + "/healthz")
    assert status == 200 and body["status"] == "ok"
    assert body["version"] == "v0000001"
    xq = fitted["xq"][:7]
    status, body = _http_json(url + "/predict",
                              {"x": np.asarray(xq).tolist(), "samples": True})
    assert status == 200 and body["rows"] == 7
    want = servable_predict(model, xq, bm=64, bn=64)
    np.testing.assert_allclose(body["mean"], np.asarray(want.mean),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(body["var"], np.asarray(want.var),
                               rtol=1e-5, atol=1e-6)
    assert np.asarray(body["samples"]).shape == want.samples.shape


def test_http_wire_errors(http_server):
    url = http_server["url"]
    for payload, match in [
        ({}, "missing required field"),
        ({"x": "nope"}, "not a numeric matrix"),
        ({"x": [[1.0, float("nan")]]}, "non-finite"),
        ({"x": [[0.1, 0.2]], "deadline_ms": -5}, "positive"),
        ({"x": [[0.1, 0.2]], "priority": "bogus"}, "unknown priority"),
    ]:
        status, body = _http_json(url + "/predict", payload)
        assert status == 400 and match in body["error"], (payload, body)
    status, body = _http_json(url + "/predict",
                              {"x": [[0.1, 0.2]], "model": "nope"})
    assert status == 404
    status, body = _http_json(url + "/predict", {"x": [[0.1, 0.2, 0.3]]})
    assert status == 400 and "features" in body["error"]
    status, _ = _http_json(url + "/nope")
    assert status == 404


def test_predict_deadline_expired_is_504(http_server, fitted):
    frontend = http_server["frontend"]
    with pytest.raises(WireError) as e:
        frontend.predict({"x": np.asarray(fitted["xq"][:2]).tolist(),
                          "deadline_ms": 50},
                         arrival=time.monotonic() - 1.0)
    assert e.value.status == 504
    # the slot must have been released despite the 504
    assert frontend.admission.inflight == 0


def test_http_flood_sheds_429_with_retry_after(tmp_path, model):
    store = str(tmp_path / "store")
    publish_servable(store, model)
    engine = BucketedEngine(model, buckets=(8,), bm=64, bn=64)
    adm = AdmissionController(buckets=(8,), rate_qps=1.0, burst=2.0)
    frontend = ServeFrontend(engine, adm, store_dir=store)
    httpd, _ = start_http_server(frontend)
    try:
        url = f"http://127.0.0.1:{httpd.port}"
        xq = [[0.1, 0.2]]
        codes = []
        retry_after = None
        for _ in range(5):
            req = urllib.request.Request(
                url + "/predict", data=json.dumps({"x": xq}).encode(),
                headers={"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=30) as resp:
                    codes.append(resp.status)
            except urllib.error.HTTPError as e:
                codes.append(e.code)
                if e.code == 429:
                    retry_after = e.headers.get("Retry-After")
        assert codes.count(429) >= 2 and codes.count(200) >= 1, codes
        assert retry_after is not None and int(retry_after) >= 1
        status, body = _http_json(url + "/stats")
        assert status == 200
        assert body["admission"]["shed_rate"] == codes.count(429)
        assert body["engine"]["requests"] == codes.count(200)
        # admin traffic is never rate-shed
        status, body = _http_json(
            url + "/predict", {"x": xq, "priority": "admin"})
        assert status == 200
    finally:
        httpd.shutdown()


def test_http_admin_swap_and_drain(http_server, fitted, model):
    url = http_server["url"]
    publish_servable(http_server["store"],
                     model._replace(correction=model.correction * 2))
    status, body = _http_json(url + "/admin/swap", {})
    assert status == 200 and body["version"] == "v0000002"
    status, body = _http_json(url + "/healthz")
    assert body["version"] == "v0000002"
    xq = fitted["xq"][:4]
    status, body = _http_json(url + "/predict", {"x": np.asarray(xq).tolist()})
    want = servable_predict(model, xq, bm=64, bn=64)
    np.testing.assert_allclose(body["mean"], 2 * np.asarray(want.mean),
                               rtol=1e-5, atol=1e-6)
    # drain: refuses new predictions, healthz flips to 503
    status, body = _http_json(url + "/admin/drain", {})
    assert status == 200 and body["draining"]
    status, _ = _http_json(url + "/predict", {"x": np.asarray(xq).tolist()})
    assert status == 503
    status, _ = _http_json(url + "/healthz")
    assert status == 503


# -- concurrent swap vs in-flight traffic ------------------------------------
def test_concurrent_swap_during_enqueue(fitted, model):
    """No request may see a half-swapped model: every response must equal
    the prediction of exactly one published model version, and same-shape
    swaps must not retrace."""
    model2 = model._replace(correction=model.correction * 2)
    engine = BucketedEngine(model, buckets=(8, 32), bm=64, bn=64)
    compiles = engine.warmup()
    xq = fitted["xq"][:4]
    want1 = np.asarray(servable_predict(model, xq, bm=64, bn=64).mean)
    want2 = np.asarray(servable_predict(model2, xq, bm=64, bn=64).mean)

    stop = threading.Event()

    def swapper():
        flip = False
        while not stop.is_set():
            engine.swap_model(model2 if flip else model)
            flip = not flip

    th = threading.Thread(target=swapper, daemon=True)
    th.start()
    try:
        futs = [engine.enqueue(xq) for _ in range(40)]
        results = [np.asarray(f.result(timeout=60).mean) for f in futs]
    finally:
        stop.set()
        th.join(timeout=10)
        engine.stop()
    for got in results:
        match1 = np.allclose(got, want1, rtol=1e-5, atol=1e-6)
        match2 = np.allclose(got, want2, rtol=1e-5, atol=1e-6)
        assert match1 or match2, "response matches neither model version"
    assert engine.num_compiles() == compiles  # same static shapes: no retrace


# -- cross-process distribution ---------------------------------------------
@pytest.mark.slow
def test_store_publish_poll_swap_across_processes(tmp_path, fitted, model):
    """publish (this process) -> poll + swap (worker process) round-trip."""
    store = str(tmp_path / "store")
    publish_servable(store, model)
    sup = ReplicaSupervisor(store, num_replicas=1, buckets=(8, 32),
                            bm=64, bn=64, poll_interval_s=0.2)
    try:
        (url,) = sup.start(timeout_s=180)
        xq = fitted["xq"][:5]
        status, body = _http_json(url + "/predict",
                                  {"x": np.asarray(xq).tolist()})
        assert status == 200 and body["version"] == "v0000001"
        want = np.asarray(servable_predict(model, xq, bm=64, bn=64).mean)
        np.testing.assert_allclose(body["mean"], want, rtol=1e-4, atol=1e-5)

        v2 = publish_servable(store,
                              model._replace(correction=model.correction * 2))
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            status, body = _http_json(url + "/healthz")
            if body.get("version") == v2:
                break
            time.sleep(0.2)
        assert body.get("version") == v2, "worker never picked up v2"
        status, body = _http_json(url + "/predict",
                                  {"x": np.asarray(xq).tolist()})
        np.testing.assert_allclose(body["mean"], 2 * want, rtol=1e-4,
                                   atol=1e-5)

        # supervision: kill the worker; check() must respawn it and the
        # replacement must come up serving the CURRENT version.
        sup._procs[0].kill()
        sup._procs[0].join(timeout=30)
        assert sup.check() == 1
        deadline = time.monotonic() + 120
        healthy = False
        while time.monotonic() < deadline and not healthy:
            try:
                with open(sup._port_file(0)) as f:
                    sup.ports[0] = int(f.read().strip())
                status, body = _http_json(sup.endpoint(0) + "/healthz",
                                          timeout=2.0)
                healthy = status == 200 and body.get("version") == v2
            except (FileNotFoundError, ValueError, OSError):
                pass
            time.sleep(0.3)
        assert healthy, "respawned replica never became healthy on v2"
    finally:
        sup.stop()


@pytest.mark.slow
def test_cluster_two_replicas_swap_and_overload(tmp_path, fitted, model):
    """The acceptance scenario: two replicas serve one versioned artifact;
    a publish propagates to both without dropping in-flight requests;
    overload sheds 429 while admitted requests stay correct."""
    store = str(tmp_path / "store")
    publish_servable(store, model)
    model2 = model._replace(correction=model.correction * 2)
    xq = fitted["xq"][:4]
    want1 = np.asarray(servable_predict(model, xq, bm=64, bn=64).mean)
    want2 = np.asarray(servable_predict(model2, xq, bm=64, bn=64).mean)

    sup = ReplicaSupervisor(store, num_replicas=2, buckets=(8, 32),
                            bm=64, bn=64, poll_interval_s=0.2)
    try:
        urls = sup.start(timeout_s=240)

        # Drive traffic from both endpoints while v2 is published mid-flight.
        errors, bad = [], []
        statuses = []

        def client(url, n):
            for i in range(n):
                try:
                    status, body = _http_json(
                        url + "/predict", {"x": np.asarray(xq).tolist()},
                        timeout=30)
                    statuses.append(status)
                    if status == 200:
                        got = np.asarray(body["mean"])
                        if not (np.allclose(got, want1, rtol=1e-4, atol=1e-5)
                                or np.allclose(got, want2, rtol=1e-4,
                                               atol=1e-5)):
                            bad.append(got)
                except Exception as e:  # pragma: no cover - diagnostics
                    errors.append(repr(e))

        threads = [threading.Thread(target=client, args=(u, 15))
                   for u in urls for _ in range(2)]
        for t in threads:
            t.start()
        v2 = publish_servable(store, model2)
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors[:3]
        assert not bad, "a response matched neither artifact version"
        assert statuses.count(200) == len(statuses), statuses

        # both replicas converge on v2
        deadline = time.monotonic() + 60
        seen = set()
        while len(seen) < 2 and time.monotonic() < deadline:
            for u in urls:
                _, body = _http_json(u + "/healthz")
                if body.get("version") == v2:
                    seen.add(u)
            time.sleep(0.2)
        assert len(seen) == 2, "v2 did not propagate to every replica"

        # overload: hammer replica 0 with impossible deadlines while
        # background traffic keeps its queue non-empty — admission must
        # shed (429 + Retry-After / 504 if admitted but aged out) instead
        # of parking doomed work, and admitted requests stay correct.
        stop_bg = threading.Event()

        def background():
            while not stop_bg.is_set():
                try:
                    _http_json(urls[0] + "/predict",
                               {"x": np.asarray(xq).tolist()}, timeout=30)
                except OSError:
                    pass

        flood_codes = []

        def flooder():
            for _ in range(20):
                try:
                    s, _ = _http_json(
                        urls[0] + "/predict",
                        {"x": np.asarray(xq).tolist(), "deadline_ms": 1},
                        timeout=30)
                    flood_codes.append(s)
                except OSError:
                    pass

        bg = [threading.Thread(target=background) for _ in range(2)]
        fl = [threading.Thread(target=flooder) for _ in range(6)]
        for t in bg + fl:
            t.start()
        for t in fl:
            t.join(timeout=120)
        stop_bg.set()
        for t in bg:
            t.join(timeout=30)
        assert set(flood_codes) <= {200, 429, 504}, sorted(set(flood_codes))
        assert 429 in flood_codes, "overload never shed"
        _, stats = _http_json(urls[0] + "/stats")
        assert stats["admission"]["shed_deadline"] >= flood_codes.count(429)
    finally:
        sup.stop()
