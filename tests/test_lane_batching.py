"""Lane-batched scenario engine parity: vmapped solves vs loops of single
solves (freeze masks), scan-chunked fit vs per-step fit (bitwise), lane-
stacked outer steps and fit_batch vs single fits, the named SGD divergence
threshold, and the driver's solver-time accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OuterConfig,
    fit,
    fit_batch,
    init_outer_state,
    init_outer_state_lanes,
    outer_scan,
    outer_step,
    outer_step_lanes,
    unstack_state,
)
from repro.core.driver import (
    SGD_DIVERGENCE_THRESHOLD,
    pick_sgd_learning_rate,
)
from repro.data.synthetic import make_gp_regression
from repro.gp.hyperparams import HyperParams
from repro.solvers import HOperator, SolverConfig, solve, solve_lanes

TOL = 0.01
LANES = 3


@pytest.fixture(scope="module")
def lane_problem():
    """Shared inputs x, per-lane hyperparameters and right-hand sides."""
    n, d, s = 96, 2, 4
    x, y = make_gp_regression(jax.random.PRNGKey(0), n, d, noise=0.3)
    b1 = jnp.concatenate(
        [y[:, None], jax.random.normal(jax.random.PRNGKey(1), (n, s))], axis=1
    )
    params = [
        HyperParams.create(d, lengthscale=0.6 + 0.3 * i, noise=0.3 + 0.25 * i)
        for i in range(LANES)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    b = jnp.stack([b1 * (1.0 + 0.1 * i) for i in range(LANES)])
    keys = jax.random.split(jax.random.PRNGKey(9), LANES)
    return {"x": x, "n": n, "d": d, "params": params, "stacked": stacked,
            "b": b, "keys": keys}


SOLVERS = [
    ("cg", dict(precond_rank=15)),
    ("ap", dict(block_size=32)),
    ("sgd", dict(batch_size=32, learning_rate=2.0)),
]


@pytest.mark.parametrize("name,kw", SOLVERS)
@pytest.mark.parametrize("warm", [False, True])
def test_lane_solve_matches_loop_of_single_solves(lane_problem, name, kw, warm):
    """A vmapped lane-batched solve must reproduce each lane's single-lane
    solve: same per-lane iteration counts (freeze masks keep early finishers
    honest) and the same solutions to fp32 accumulation tolerance."""
    lp = lane_problem
    cfg = SolverConfig(name=name, tolerance=TOL, max_epochs=2000, **kw)
    v0 = (0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                  lp["b"].shape) if warm else None)
    res_b = solve_lanes(lp["x"], lp["stacked"], lp["b"], v0, cfg,
                        bm=64, bn=64, keys=lp["keys"])
    for i in range(LANES):
        op = HOperator(x=lp["x"], params=lp["params"][i], bm=64, bn=64)
        r = solve(op, lp["b"][i], v0[i] if warm else None, cfg,
                  key=lp["keys"][i])
        assert int(res_b.iters[i]) == int(r.iters), (name, warm, i)
        vb, vs = np.asarray(res_b.v[i]), np.asarray(r.v)
        rel = np.linalg.norm(vb - vs) / np.linalg.norm(vs)
        assert rel < 1e-3, (name, warm, i, rel)
        np.testing.assert_allclose(
            float(res_b.res_y[i]), float(r.res_y), rtol=1e-2, atol=1e-4)


@pytest.mark.parametrize("name,kw", [
    ("cg", dict(precond_rank=15)), ("ap", dict(block_size=32)),
])
def test_converged_lane_freezes(lane_problem, name, kw):
    """A lane warm-started at its exact solution is converged at entry: the
    shared while-loop keeps running for the other lane, but the frozen lane
    must report 0 iterations and return its warm start unchanged (up to the
    normalise/denormalise round trip) — the freeze-mask contract."""
    lp = lane_problem
    cfg = SolverConfig(name=name, tolerance=TOL, max_epochs=2000, **kw)
    two = jax.tree.map(lambda v: v[:2], lp["stacked"])
    h0 = (np.asarray(HOperator(x=lp["x"], params=lp["params"][0]).dense()))
    v_exact = jnp.asarray(np.linalg.solve(h0, np.asarray(lp["b"][0])))
    v0 = jnp.stack([v_exact, jnp.zeros_like(v_exact)])
    res = solve_lanes(lp["x"], two, lp["b"][:2], v0, cfg, bm=64, bn=64,
                      keys=lp["keys"][:2])
    assert int(res.iters[0]) == 0
    assert int(res.iters[1]) > 0
    np.testing.assert_allclose(np.asarray(res.v[0]), np.asarray(v_exact),
                               rtol=1e-5, atol=1e-6)
    # the live lane still solved its system
    assert float(res.res_y[1]) <= TOL * 1.01


OUTER_CFG = dict(num_probes=4, num_rff_pairs=64, bm=64, bn=64,
                 solver=SolverConfig(name="cg", tolerance=TOL, max_epochs=50,
                                     precond_rank=0))


@pytest.fixture(scope="module")
def outer_problem():
    x, y = make_gp_regression(jax.random.PRNGKey(2), 64, 2, noise=0.3)
    return x, y


def test_outer_scan_matches_step_loop_bitwise(outer_problem):
    """outer_scan runs the same traced body as outer_step: the trajectory
    must be bitwise identical, for one scan and for chunked scans."""
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=6,
                      **OUTER_CFG)
    st0 = init_outer_state(jax.random.PRNGKey(3), cfg, x)
    st_loop = st0
    hypers = []
    for _ in range(6):
        st_loop, m = outer_step(st_loop, x, y, cfg)
        hypers.append(np.asarray(m["hypers"]))
    st_scan, ms = outer_scan(st0, x, y, cfg, 6)
    np.testing.assert_array_equal(np.stack(hypers), np.asarray(ms["hypers"]))
    np.testing.assert_array_equal(np.asarray(st_loop.carry_v),
                                  np.asarray(st_scan.carry_v))
    # chunking must not change the trajectory either
    sa, _ = outer_scan(st0, x, y, cfg, 3)
    sb, _ = outer_scan(sa, x, y, cfg, 3)
    np.testing.assert_array_equal(np.asarray(st_scan.carry_v),
                                  np.asarray(sb.carry_v))


def test_scan_chunked_fit_matches_per_step_fit_bitwise(outer_problem):
    """fit(steps_per_round=4) histories are bitwise equal to the per-step
    fit(steps_per_round=1) — the scan chunking is pure orchestration."""
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=6,
                      **OUTER_CFG)
    r1 = fit(x, y, cfg, key=jax.random.PRNGKey(5), steps_per_round=1)
    r4 = fit(x, y, cfg, key=jax.random.PRNGKey(5), steps_per_round=4)
    for k in ("res_y", "res_z", "iters", "epochs", "hypers", "grad_norm"):
        np.testing.assert_array_equal(r1.history[k], r4.history[k], err_msg=k)


def test_outer_step_lanes_matches_loop(outer_problem):
    """One lane-stacked outer step == a loop of single outer steps."""
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=2,
                      **OUTER_CFG)
    keys = jax.random.split(jax.random.PRNGKey(11), LANES)
    states = init_outer_state_lanes(keys, cfg, x)
    for _ in range(2):
        states, ml = outer_step_lanes(states, x, y, cfg)
    for i in range(LANES):
        st = init_outer_state(keys[i], cfg, x)
        for _ in range(2):
            st, m = outer_step(st, x, y, cfg)
        assert int(ml["iters"][i]) == int(m["iters"])
        np.testing.assert_allclose(
            np.asarray(unstack_state(states, i).carry_v),
            np.asarray(st.carry_v), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(unstack_state(states, i).params.flat()),
            np.asarray(st.params.flat()), rtol=1e-5, atol=1e-6)


def test_fit_batch_matches_single_fits(outer_problem):
    """fit_batch lanes reproduce per-seed single fits (history parity)."""
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=4,
                      **OUTER_CFG)
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    batch = fit_batch(x, y, cfg, keys)
    assert len(batch) == 2
    for i in range(2):
        single = fit(x, y, cfg, key=keys[i])
        np.testing.assert_array_equal(batch[i].history["iters"],
                                      single.history["iters"])
        np.testing.assert_allclose(batch[i].history["hypers"],
                                   single.history["hypers"],
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(batch[i].history["res_y"],
                                   single.history["res_y"],
                                   rtol=1e-2, atol=1e-5)


def test_fit_populates_solver_frac_and_time_split(outer_problem):
    """Regression for the silent-empty ``solver_frac_iters`` history key and
    the whole-step ``solver_time_s``: the fraction is populated per step in
    (0, 1], and solve + grad/Adam time partition the measured step time."""
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=4,
                      **OUTER_CFG)
    r = fit(x, y, cfg, key=jax.random.PRNGKey(0))
    frac = r.history["solver_frac_iters"]
    assert frac.shape == (4,)
    assert np.all(frac > 0.0) and np.all(frac <= 1.0)
    total = float(np.sum(r.history["step_time_s"]))
    assert r.solver_time_s > 0.0 and r.grad_time_s > 0.0
    np.testing.assert_allclose(r.solver_time_s + r.grad_time_s, total,
                               rtol=1e-6)
    assert r.solver_time_s <= r.wall_time_s


def test_sgd_divergence_threshold_constant_and_grid_search(outer_problem):
    """The magic `2.0 * 2.0` is now the named, documented constant; the grid
    search keeps the largest stable lr and rejects a diverging one."""
    assert SGD_DIVERGENCE_THRESHOLD == 4.0
    x, y = outer_problem
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_steps=1,
                      num_probes=4, num_rff_pairs=64, bm=64, bn=64,
                      solver=SolverConfig(name="sgd", tolerance=TOL,
                                          batch_size=32, max_epochs=3))
    key = jax.random.PRNGKey(0)
    params = HyperParams.create(2, noise=0.5)
    # 1e6 blows past the quadratic's stability limit -> rejected.
    lr = pick_sgd_learning_rate(x, y, params, cfg, key, grid=[0.5, 1e6])
    assert lr == 0.5
    # An infinite threshold accepts any finite residual -> largest grid lr
    # (grid order must not matter; the search sorts ascending).
    lr_inf = pick_sgd_learning_rate(x, y, params, cfg, key, grid=[1.0, 0.5],
                                    divergence_threshold=float("inf"))
    assert lr_inf == 1.0
    assert pick_sgd_learning_rate(x, y, params, cfg, key, grid=[1.0, 0.5],
                                  divergence_threshold=float("inf"),
                                  halve=True) == 0.5


def test_launch_batch_one_executable_per_group(tmp_path):
    """launch.batch end-to-end (in-process): a 2-kernel x 2-seed grid runs
    as 2 groups with exactly one compile each, emits one JSON per cell plus
    a sweep status, and skips completed cells on re-run."""
    import json

    from repro.launch import batch

    out = str(tmp_path / "batch")
    argv = ["--out", out, "--dataset", "pol", "--max-n", "128",
            "--kernels", "rbf,matern52", "--seeds", "2", "--steps", "2",
            "--smoke", "--bm", "64", "--bn", "64",
            "--expect-one-compile-per-group"]
    assert batch.main(argv) == 0
    cells = sorted(p.name for p in (tmp_path / "batch").iterdir()
                   if not p.name.startswith("_"))
    assert cells == [
        "gp-iterative-matern52__s0.json", "gp-iterative-matern52__s1.json",
        "gp-iterative-rbf__s0.json", "gp-iterative-rbf__s1.json",
    ]
    with open(tmp_path / "batch" / "_sweep_status.json") as f:
        status = json.load(f)
    assert status["groups"] == 2 and status["num_compiles"] == 2
    assert status["cells"] == 4 and not status["failures"]
    rec = json.loads((tmp_path / "batch" / cells[0]).read_text())
    assert rec["kernel"] == "matern52" and rec["mode"] == "batched"
    assert len(rec["history"]["res_y"]) == 2
    # resumability: everything done -> nothing re-runs, still a success
    assert batch.main(argv[: -1]) == 0
    with open(tmp_path / "batch" / "_sweep_status.json") as f:
        assert json.load(f)["cells"] == 0
