"""Pallas kernel sweeps: shapes x dtypes x block sizes against ref.py,
forward and VJP (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gp.hyperparams import HyperParams
from repro.kernels.matern import h_mvm, h_mvm_ref, matern_mvm, matern_mvm_ref


@pytest.mark.parametrize(
    "n,m,d,s,bm,bn",
    [
        (64, 64, 1, 1, 64, 64),
        (128, 128, 4, 8, 64, 64),
        (100, 132, 7, 5, 32, 64),     # non-divisible rows (padding path)
        (256, 256, 26, 65, 128, 128),  # POL-like d, s=64+1
        (96, 33, 9, 3, 32, 32),
        (8, 8, 2, 2, 8, 8),
    ],
)
def test_forward_matches_oracle(n, m, d, s, bm, bn):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n * m + d), 3)
    x1 = jax.random.normal(k1, (n, d))
    x2 = jax.random.normal(k2, (m, d))
    v = jax.random.normal(k3, (m, s))
    p = HyperParams.create(d, lengthscale=0.8, signal=1.3, noise=0.2)
    out = matern_mvm(x1, x2, v, p, bm=bm, bn=bn)
    ref = matern_mvm_ref(x1, x2, v, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_h_mvm_adds_noise_diagonal(dtype):
    n, d, s = 64, 3, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (n, d), dtype)
    v = jax.random.normal(k2, (n, s), dtype)
    p = HyperParams.create(d, noise=0.5)
    np.testing.assert_allclose(
        np.asarray(h_mvm(x, v, p, bm=32, bn=32)),
        np.asarray(h_mvm_ref(x, v, p)),
        rtol=1e-4, atol=1e-4,
    )


def test_vjp_matches_oracle_all_args():
    n, m, d, s = 48, 40, 3, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x1 = jax.random.normal(k1, (n, d))
    x2 = jax.random.normal(k2, (m, d))
    v = jax.random.normal(k3, (m, s))
    p = HyperParams.create(d, lengthscale=0.7, signal=1.1, noise=0.3)

    def loss_pallas(x1, x2, v, p):
        return jnp.sum(jnp.sin(matern_mvm(x1, x2, v, p, bm=16, bn=16)))

    def loss_ref(x1, x2, v, p):
        return jnp.sum(jnp.sin(matern_mvm_ref(x1, x2, v, p)))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(x1, x2, v, p)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x1, x2, v, p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_vjp_symmetric_inputs():
    """x1 is x2 (the GP case): gradients flow through both roles."""
    n, d, s = 40, 2, 3
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (n, d))
    v = jax.random.normal(k2, (n, s))
    p = HyperParams.create(d)

    g1 = jax.grad(lambda x: jnp.sum(matern_mvm(x, x, v, p, bm=8, bn=8) ** 2))(x)
    g2 = jax.grad(lambda x: jnp.sum(matern_mvm_ref(x, x, v, p) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-4)


def test_jit_and_grad_composition():
    n, d, s = 32, 4, 2
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    v = jax.random.normal(jax.random.PRNGKey(4), (n, s))
    p = HyperParams.create(d)

    @jax.jit
    def f(p):
        return jnp.sum(h_mvm(x, v, p, bm=16, bn=16))

    g = jax.grad(f)(p)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
