"""Online subsystem (PR 6): geometric capacity growth, damped old-row
correction, escalation budget accounting, the `/stats` refresh section,
and a sequential-BO smoke run on the serving stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OuterConfig,
    grow_capacity,
    init_outer_state,
    outer_step,
)
from repro.data.synthetic import make_gp_regression
from repro.serve import (
    GROWTH_GEOMETRIC,
    BucketedEngine,
    OnlineGP,
    servable_predict,
)
from repro.serve.cluster.admission import AdmissionController
from repro.serve.cluster.transport import ServeFrontend
from repro.solvers import SolverConfig


# -- grow_capacity: the schedule itself --------------------------------------

def test_grow_capacity_schedule():
    """Ladder invariants: covers `needed`, never shrinks, O(log N) distinct
    values across N one-row appends."""
    assert grow_capacity(0, 1) == 16          # floor allocation
    assert grow_capacity(16, 16) == 16        # already fits: unchanged
    assert grow_capacity(16, 17) == 32        # one geometric hop
    assert grow_capacity(16, 100) == 128      # multi-hop lands >= needed
    assert grow_capacity(100, 50) == 100      # never shrinks below current

    caps = set()
    cap = 0
    for n in range(1, 5001):
        cap = grow_capacity(cap, n)
        assert cap >= n
        caps.add(cap)
    # 5000 appends, factor-2 ladder from 16: ~log2(5000/16) + 1 values.
    assert len(caps) <= 10, sorted(caps)

    with pytest.raises(ValueError, match="factor"):
        grow_capacity(16, 32, factor=1.0)


# -- OnlineGP under geometric growth -----------------------------------------

def _synced_fit(tolerance: float):
    """Fit with carry synced to the final hypers (same protocol as
    test_serve.block_fit) plus weak/strong append clusters."""
    xall, yall = make_gp_regression(jax.random.PRNGKey(0), 208, 2, noise=0.2)
    x, y = xall[:128], yall[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=400, precond_rank=0,
                            tolerance=tolerance),
        num_steps=3, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    sync = OnlineGP(x, y, state, cfg)
    sync.refine(mode="solve")
    k = 16
    far = (x[:k] + 8.0, jax.random.normal(jax.random.PRNGKey(3), (k,)) * 0.5)
    return {"x": x, "y": y, "xq": xall[144:176], "cfg": cfg,
            "state": sync.state, "far": far,
            "overlap": (xall[128:144], yall[128:144])}


@pytest.fixture(scope="module")
def online_fit():
    """Tight tolerance: the growth-parity / budget / stats regime."""
    return _synced_fit(1e-4)


@pytest.fixture(scope="module")
def loose_fit():
    """Serving tolerance (1e-2): the streaming-append regime the damped
    correction targets — small-k appends whose coupling residual sits
    above the auto threshold but within one cheap polish of it."""
    return _synced_fit(1e-2)


def test_geometric_growth_matches_exact(online_fit):
    """Ghost-row padding must be inert: after the same append + full
    re-solve, geometric and exact growth predict identically and the
    geometric capacity sits on the ladder with `n` tracking real rows."""
    x_new, y_new = online_fit["far"]
    arms = {}
    for growth in ("exact", "geometric"):
        o = OnlineGP(online_fit["x"], online_fit["y"], online_fit["state"],
                     online_fit["cfg"], growth=growth)
        o.append(x_new, y_new)
        o.refine(mode="solve")
        arms[growth] = o
    geo, exact = arms["geometric"], arms["exact"]
    n_real = online_fit["x"].shape[0] + x_new.shape[0]
    assert geo.n == exact.n == n_real
    assert geo.capacity >= n_real and geo.capacity == grow_capacity(0, n_real)
    assert exact.capacity == n_real
    # exported artifact keeps the padded shape (stable engine buckets) ...
    assert geo.export().x.shape[0] == geo.capacity
    # ... but predictions are bitwise-insensitive to the ghosts.
    pg = servable_predict(geo.export(), online_fit["xq"], bm=64, bn=64)
    pe = servable_predict(exact.export(), online_fit["xq"], bm=64, bn=64)
    scale = float(jnp.std(pe.mean)) + 1e-6
    assert float(jnp.max(jnp.abs(pg.mean - pe.mean))) / scale < 0.01
    assert float(jnp.max(jnp.abs(pg.var - pe.var))) < 0.01


def test_geometric_growth_compile_count(online_fit):
    """N sequential appends compile O(log N) solver executables under
    geometric growth; `reserve=` makes it O(1)."""
    def run(reserve):
        o = OnlineGP(online_fit["x"], online_fit["y"], online_fit["state"],
                     online_fit["cfg"], growth=GROWTH_GEOMETRIC,
                     reserve=reserve)
        key = jax.random.PRNGKey(7)
        for r in range(24):
            xr = online_fit["x"][:1] + 8.0 + 0.05 * r
            yr = jax.random.normal(jax.random.fold_in(key, r), (1,)) * 0.5
            o.append(xr, yr)
            o.refine(mode="block")
        return o

    o = run(reserve=0)
    compiles = o.num_solve_compiles()
    if compiles is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    # 24 appends from n=128: ladder hits {256, ...} — a couple of shapes
    # times two wrappers (full+block), nowhere near one per append.
    assert compiles <= 8, compiles
    assert o.stats_dict()["growth_events"] >= 1

    o2 = run(reserve=32)
    assert o2.stats_dict()["growth_events"] == 1  # the reserve itself
    assert o2.num_solve_compiles() <= 4  # one shape for the whole stream


def test_step_mode_refused_under_geometric_growth(online_fit):
    o = OnlineGP(online_fit["x"], online_fit["y"], online_fit["state"],
                 online_fit["cfg"], growth=GROWTH_GEOMETRIC)
    with pytest.raises(ValueError, match="step"):
        o.refine(mode="step")


# -- damped old-row correction (ROADMAP follow-up (a)) -----------------------

def test_damped_correction_avoids_escalation_on_coupled_append(loose_fit):
    """A strongly-coupled append (lands inside the bulk) escalates under
    plain auto mode; the damped correction must repair the old rows at
    ~block cost instead, and the residual it reports must be the honest
    post-polish solver residual, back under the auto threshold."""
    x_new, y_new = loose_fit["overlap"]
    x_new, y_new = x_new[:2], y_new[:2]  # streaming-scale append
    plain = OnlineGP(loose_fit["x"], loose_fit["y"], loose_fit["state"],
                     loose_fit["cfg"])
    plain.append(x_new, y_new)
    plain_report = plain.refine(mode="auto")
    assert plain_report.escalated  # the baseline this feature removes

    o = OnlineGP(loose_fit["x"], loose_fit["y"], loose_fit["state"],
                 loose_fit["cfg"])
    o.append(x_new, y_new)
    report = o.refine(mode="auto", correction="damped")
    tol = loose_fit["cfg"].solver.tolerance
    assert report.corrected and not report.escalated, (
        report.res_y, report.res_z)
    assert report.correction_epochs > 0
    # honest residual: the coupling estimate was replaced by the polish
    # solver's own residual, and it is back under the auto threshold.
    assert max(report.res_y, report.res_z) <= 5.0 * tol
    # the whole point: cheaper than the escalated full re-solve.
    assert report.epochs < 0.5 * plain_report.epochs, (
        report.epochs, plain_report.epochs)
    cnt = o.stats_dict()
    assert cnt["corrections"] == 1 and cnt["escalations"] == 0


def test_escalation_budget_not_double_spent(online_fit):
    """When auto mode does escalate under a budget, the full solve gets
    only the REMAINING budget (block spend deducted): total charged epochs
    stay within the budget plus bookkeeping, never ~2x."""
    x_new, y_new = online_fit["overlap"]
    budget = 6.0
    o = OnlineGP(online_fit["x"], online_fit["y"], online_fit["state"],
                 online_fit["cfg"])
    o.append(x_new, y_new)
    report = o.refine(mode="auto", budget_epochs=budget)
    assert report.escalated
    # block attempt + escalation together must respect the single budget
    # (+1 epoch slack for the cross-MVM bookkeeping of the block attempt).
    assert report.epochs <= budget + 1.0, report.epochs


# -- /stats surfaces the refresh section -------------------------------------

def test_stats_refresh_section(online_fit):
    """A frontend wired to an OnlineGP reports its refresh counters —
    including escalation and coupling residual — under GET /stats."""
    o = OnlineGP(online_fit["x"], online_fit["y"], online_fit["state"],
                 online_fit["cfg"])
    model = o.export()
    engine = BucketedEngine(model, buckets=(32,), bm=64, bn=64)
    frontend = ServeFrontend(engine, AdmissionController(buckets=(32,)),
                             refresh_source=o)
    status, body = frontend.stats()
    assert status == 200
    assert body["refresh"]["refines"] == 0 and "last" not in body["refresh"]

    x_new, y_new = online_fit["overlap"]
    o.append(x_new, y_new)
    o.refresh_into(engine, mode="auto")
    status, body = frontend.stats()
    r = body["refresh"]
    assert r["refines"] == 1 and r["escalations"] == 1
    assert r["last"]["escalated"] and r["last"]["mode"] == "auto"
    assert r["last"]["res_y"] <= 5.0 * online_fit["cfg"].solver.tolerance
    import json
    json.dumps(body)  # the whole payload must be wire-serialisable

    # a frontend without a refresh source omits the section entirely
    bare = ServeFrontend(engine, AdmissionController(buckets=(32,)))
    assert "refresh" not in bare.stats()[1]


# -- sequential BO smoke ------------------------------------------------------

@pytest.mark.slow
def test_run_bo_smoke():
    """End-to-end sequential loop on the serving stack: appends + block
    refreshes + bucketed acquisition, zero engine retraces after warmup."""
    from repro.core import fit
    from repro.online import BOConfig, make_gaussian_bumps, run_bo

    d = 2
    objective, f_opt = make_gaussian_bumps(jax.random.PRNGKey(5), d)
    key = jax.random.PRNGKey(0)
    x0 = jax.random.uniform(key, (48, d), minval=-1.0, maxval=1.0)
    y0 = objective(x0)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8,
        num_rff_pairs=64,
        solver=SolverConfig(name="cg", tolerance=1e-2, precond_rank=0),
        num_steps=3, bm=64, bn=64,
    )
    res = fit(x0, y0, cfg, key=jax.random.PRNGKey(1))
    bo = BOConfig(rounds=10, num_candidates=64, refresh_mode="auto",
                  correction="damped")
    out = run_bo(objective, x0, y0, res.state, cfg, bo=bo,
                 bounds=(-1.0, 1.0), f_opt=f_opt)
    assert len(out.history) == bo.rounds
    assert out.engine_retraces in (None, 0)
    assert out.cum_epochs > 0 and np.isfinite(out.best_y)
    assert out.regret is not None and out.regret < 1.0
    assert out.refresh_stats["appended_rows"] == bo.rounds
