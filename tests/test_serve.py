"""Serving subsystem: bucket padding, artifact round-trip, zero-retrace
steady state, microbatch coalescing, online refresh, multi-model routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OuterConfig,
    correction_matrix,
    extend_state,
    init_outer_state,
    outer_step,
    pathwise_predict,
    pathwise_predict_from_correction,
)
from repro.data.synthetic import make_gp_regression
from repro.serve import (
    BucketedEngine,
    MultiModelServer,
    OnlineGP,
    export_servable,
    load_servable,
    save_servable,
    servable_predict,
)
from repro.solvers import SolverConfig


@pytest.fixture(scope="module")
def fitted():
    """A small pathwise fit (converged CG) plus its data."""
    x, y = make_gp_regression(jax.random.PRNGKey(0), 160, 2, noise=0.2)
    xq = x[128:]
    x, y = x[:128], y[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=200, precond_rank=0),
        num_steps=3, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    return {"x": x, "y": y, "xq": xq, "cfg": cfg, "state": state}


@pytest.fixture(scope="module")
def model(fitted):
    return export_servable(fitted["state"], fitted["x"])


def test_export_matches_pathwise_predict(fitted, model):
    st = fitted["state"]
    want = pathwise_predict(fitted["x"], fitted["xq"], st.carry_v, st.probes,
                            st.params, bm=64, bn=64)
    got = servable_predict(model, fitted["xq"], bm=64, bn=64)
    np.testing.assert_allclose(np.asarray(got.mean), np.asarray(want.mean),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.var), np.asarray(want.var),
                               rtol=1e-5, atol=1e-6)


def test_bucket_padding_agrees_with_unpadded(fitted, model):
    """Padded-to-bucket predictions equal the direct unpadded ones row-wise."""
    engine = BucketedEngine(model, buckets=(8, 32), bm=64, bn=64)
    xq = fitted["xq"][:13]  # ragged: padded to the 32 bucket
    got = engine.submit(xq)
    want = servable_predict(model, xq, bm=64, bn=64)
    assert got.mean.shape == (13,)
    np.testing.assert_allclose(np.asarray(got.mean), np.asarray(want.mean),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.var), np.asarray(want.var),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.samples),
                               np.asarray(want.samples), rtol=1e-5, atol=1e-6)


def test_engine_zero_retrace_after_warmup(fitted, model):
    engine = BucketedEngine(model, buckets=(8, 32), bm=64, bn=64)
    compiles = engine.warmup()
    assert compiles == 2  # one executable per bucket
    for m in (1, 3, 8, 9, 20, 32, 5):
        pred = engine.submit(fitted["xq"][:m])
        assert pred.mean.shape == (m,)
    assert engine.num_compiles() == compiles  # zero retraces in steady state
    assert engine.stats.requests == 7


def test_engine_chunks_oversized_queries(fitted, model):
    engine = BucketedEngine(model, buckets=(8,), bm=64, bn=64)
    xq = fitted["xq"][:20]  # 3 chunks of <= 8
    got = engine.submit(xq)
    want = servable_predict(model, xq, bm=64, bn=64)
    assert got.mean.shape == (20,)
    np.testing.assert_allclose(np.asarray(got.mean), np.asarray(want.mean),
                               rtol=1e-5, atol=1e-6)


def test_engine_queue_microbatches(fitted, model):
    engine = BucketedEngine(model, buckets=(8, 32), bm=64, bn=64)
    engine.warmup()
    try:
        futs = [engine.enqueue(fitted["xq"][i : i + 4]) for i in range(6)]
        for i, f in enumerate(futs):
            pred = f.result(timeout=30)
            want = servable_predict(model, fitted["xq"][i : i + 4],
                                    bm=64, bn=64)
            np.testing.assert_allclose(np.asarray(pred.mean),
                                       np.asarray(want.mean),
                                       rtol=1e-5, atol=1e-6)
    finally:
        engine.stop()
    assert engine.stats.requests == 6
    assert engine.stats.batches <= 6  # some coalescing or at worst 1:1


def test_artifact_save_load_roundtrip(tmp_path, fitted, model):
    save_servable(str(tmp_path), model, step=4)
    loaded = load_servable(str(tmp_path))
    assert loaded.kind == model.kind
    assert loaded.rff.kind == model.rff.kind
    assert loaded.params.kernel == model.params.kernel
    for a, b in zip(jax.tree.leaves(model), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    want = servable_predict(model, fitted["xq"], bm=64, bn=64)
    got = servable_predict(loaded, fitted["xq"], bm=64, bn=64)
    np.testing.assert_allclose(np.asarray(got.mean), np.asarray(want.mean),
                               rtol=1e-6)


def test_export_requires_pathwise(fitted):
    cfg = OuterConfig(estimator="standard", num_probes=4,
                      solver=SolverConfig(precond_rank=0))
    st = init_outer_state(jax.random.PRNGKey(2), cfg, fitted["x"])
    with pytest.raises(ValueError, match="pathwise"):
        export_servable(st, fitted["x"])


def test_extend_state_shapes_and_carry(fitted):
    st = fitted["state"]
    n, s1 = st.carry_v.shape
    ext = extend_state(st, 16)
    assert ext.carry_v.shape == (n + 16, s1)
    np.testing.assert_allclose(np.asarray(ext.carry_v[:n]),
                               np.asarray(st.carry_v))
    assert np.all(np.asarray(ext.carry_v[n:]) == 0.0)
    assert ext.probes.w_eps.shape == (n + 16, s1 - 1)
    np.testing.assert_allclose(np.asarray(ext.probes.w_eps[:n]),
                               np.asarray(st.probes.w_eps))
    # fresh base noise on the new rows, not zeros
    assert float(jnp.std(ext.probes.w_eps[n:])) > 0.1
    assert extend_state(st, 0) is st


def test_refresh_then_swap_preserves_old_predictions(fitted, model):
    """Appending data + warm refine must not distort predictions on old
    points beyond solver tolerance; the swap is atomic on the engine."""
    engine = BucketedEngine(model, buckets=(32,), bm=64, bn=64)
    before = engine.submit(fitted["xq"])
    key = jax.random.PRNGKey(9)
    x_new, y_new = make_gp_regression(key, 24, 2, noise=0.2)
    online = OnlineGP(fitted["x"], fitted["y"], fitted["state"], fitted["cfg"])
    online.append(x_new, y_new)
    report = online.refresh_into(engine, budget_epochs=200.0)
    assert report.n == 128 + 24
    assert report.res_y <= 2 * fitted["cfg"].solver.tolerance
    after = engine.submit(fitted["xq"])
    assert engine.model.n == 128 + 24  # swap happened
    scale = float(jnp.std(before.mean)) + 1e-6
    diff = float(jnp.max(jnp.abs(after.mean - before.mean))) / scale
    assert diff < 0.5, f"old-point predictions moved {diff:.2f}x std"


def test_merge_preserves_rows_appended_during_refine(fitted):
    """An append that races a background refine must survive the commit:
    the solved rows overwrite only the snapshot prefix."""
    from repro.serve import merge_refined_state

    st = fitted["state"]
    n = st.carry_v.shape[0]
    snapshot = st
    current = extend_state(st, 8)  # append happened while refine was solving
    refined = snapshot._replace(carry_v=snapshot.carry_v + 1.0)
    merged = merge_refined_state(current, refined)
    assert merged.carry_v.shape[0] == n + 8
    np.testing.assert_allclose(np.asarray(merged.carry_v[:n]),
                               np.asarray(refined.carry_v))
    assert np.all(np.asarray(merged.carry_v[n:]) == 0.0)  # extension kept
    assert merged.probes.w_eps.shape[0] == n + 8  # extended probes kept


def test_refresh_into_background_returns_future(fitted, model):
    engine = BucketedEngine(model, buckets=(32,), bm=64, bn=64)
    online = OnlineGP(fitted["x"], fitted["y"], fitted["state"], fitted["cfg"])
    x_new, y_new = make_gp_regression(jax.random.PRNGKey(21), 8, 2, noise=0.2)
    online.append(x_new, y_new)
    fut = online.refresh_into(engine, budget_epochs=50.0, background=True)
    report = fut.result(timeout=120)
    assert report.n == 128 + 8
    assert engine.model.n == 128 + 8  # swap landed
    # failures must surface through the future, not die with the thread
    bad = OnlineGP(fitted["x"], fitted["y"], fitted["state"], fitted["cfg"])
    fut = bad.refresh_into(engine, mode="nope", background=True)
    with pytest.raises(ValueError, match="unknown refine mode"):
        fut.result(timeout=120)


def test_warm_refresh_cheaper_than_cold(fitted):
    x_new, y_new = make_gp_regression(jax.random.PRNGKey(11), 32, 2, noise=0.2)
    epochs = {}
    for warm in (True, False):
        online = OnlineGP(fitted["x"], fitted["y"], fitted["state"],
                          fitted["cfg"])
        online.append(x_new, y_new)
        epochs[warm] = online.refine(warm=warm, mode="solve").epochs
    assert epochs[True] < epochs[False], epochs


def test_multimodel_registry_routes_and_swaps(fitted):
    st, x = fitted["state"], fitted["x"]
    m32 = export_servable(st, x)
    rbf_params = st.params._replace(kernel="rbf")
    mrbf = export_servable(st._replace(params=rbf_params), x, kind="rbf")
    server = MultiModelServer(buckets=(8, 32), bm=64, bn=64)
    server.register("m32", m32)
    server.register("rbf", mrbf)
    assert server.names() == ("m32", "rbf")
    compiles = server.warmup()
    assert compiles == 4  # 2 buckets x 2 kernels, one shared jit cache
    p32 = server.submit("m32", fitted["xq"][:8])
    prbf = server.submit("rbf", fitted["xq"][:8])
    # different kernels must route to different executables/results
    assert float(jnp.max(jnp.abs(p32.mean - prbf.mean))) > 1e-6
    assert server.engine.num_compiles() == compiles
    server.swap("m32", mrbf)
    np.testing.assert_allclose(
        np.asarray(server.submit("m32", fitted["xq"][:8]).mean),
        np.asarray(prbf.mean), rtol=1e-6,
    )
    with pytest.raises(ValueError, match="already registered"):
        server.register("m32", m32)
    with pytest.raises(KeyError):
        server.submit("nope", fitted["xq"][:8])


@pytest.fixture(scope="module")
def block_fit():
    """Tight-tolerance fit whose carry is synced to the final
    hyperparameters (an outer step leaves the carry one Adam update
    behind; the sync isolates the block-vs-full comparison)."""
    xall, yall = make_gp_regression(jax.random.PRNGKey(0), 208, 2, noise=0.2)
    x, y = xall[:128], yall[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=400, precond_rank=0,
                            tolerance=1e-5),
        num_steps=3, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    sync = OnlineGP(x, y, state, cfg)
    sync.refine(mode="solve")
    return {"x": x, "y": y, "xq": xall[144:], "cfg": cfg,
            "state": sync.state, "overlap": (xall[128:144], yall[128:144])}


def test_block_refresh_matches_full_resolve_weak_coupling(block_fit):
    """Acceptance: block refine matches the full re-solve within tolerance
    while its solver only runs on the new-row block (epoch accounting).

    Weak coupling (an appended cluster ~10 lengthscales away) is the block
    mode's validity regime: there the neglected back-coupling K12 dv is
    ~zero and the parity is at solver-tolerance level."""
    k = 16
    x_new = block_fit["x"][:k] + 8.0
    y_new = jax.random.normal(jax.random.PRNGKey(3), (k,)) * 0.5
    online = {}
    for mode in ("block", "solve"):
        o = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                     block_fit["cfg"])
        o.append(x_new, y_new)
        online[mode] = (o, o.refine(mode=mode))
    rb, rf = online["block"][1], online["solve"][1]
    assert rb.mode == "block" and rb.block_rows == k
    # epoch accounting: the block path pays 2k/n cross-MVM epochs plus the
    # k-system solve scaled by (k/n)^2 — a tiny fraction of the full solve.
    assert rb.epochs < 0.1 * rf.epochs, (rb.epochs, rf.epochs)
    assert rb.block_epochs > 0  # the k x k solver actually ran
    # the neglected-coupling residual is at solver-tolerance scale here
    assert rb.res_y < 1e-3, rb.res_y
    # parity on predictions, old region and new region
    for xq in (block_fit["xq"], x_new + 0.1):
        pb = servable_predict(export_servable(online["block"][0].state,
                                              online["block"][0].x),
                              xq, bm=64, bn=64)
        pf = servable_predict(export_servable(online["solve"][0].state,
                                              online["solve"][0].x),
                              xq, bm=64, bn=64)
        scale = float(jnp.std(pf.mean)) + 1e-6
        assert float(jnp.max(jnp.abs(pb.mean - pf.mean))) / scale < 0.01
        assert float(jnp.max(jnp.abs(pb.var - pf.var))) < 0.01


def test_block_refresh_coupling_residual_flags_overlap(block_fit):
    """Strongly coupled appends (same region as the bulk) are OUTSIDE the
    block mode's validity regime; the reported residual must say so loudly
    instead of pretending the system is solved."""
    x_new, y_new = block_fit["overlap"]
    o = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                 block_fit["cfg"])
    o.append(x_new, y_new)
    report = o.refine(mode="block")
    assert report.res_y > 0.01, (
        f"overlapping appends must surface a large coupling residual, "
        f"got {report.res_y}"
    )


def test_auto_refresh_stays_block_under_weak_coupling(block_fit):
    """ROADMAP follow-up (b): mode="auto" triggers block-vs-full off the
    reported coupling residual. Weakly coupled appends (a far-away cluster)
    leave the residual at ~tolerance scale, so auto must keep the cheap
    block path: no escalation, block-refresh epoch accounting."""
    k = 16
    x_new = block_fit["x"][:k] + 8.0
    y_new = jax.random.normal(jax.random.PRNGKey(3), (k,)) * 0.5
    o = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                 block_fit["cfg"])
    o.append(x_new, y_new)
    report = o.refine(mode="auto")
    assert report.mode == "auto" and not report.escalated
    assert report.block_rows == k and report.block_epochs > 0
    # still the incremental price: a tiny fraction of a full epoch
    assert report.epochs < 1.0, report.epochs
    tol = block_fit["cfg"].solver.tolerance
    assert max(report.res_y, report.res_z) <= 5.0 * tol


def test_auto_refresh_escalates_under_strong_coupling(block_fit):
    """Strongly coupled appends (same region as the bulk) push the coupling
    residual orders of magnitude past tolerance: auto must pay the full
    re-solve — warm from the block-corrected carry — and report both the
    escalation and a residual back at solver tolerance, instead of
    silently returning a large res_y as plain mode="block" does."""
    x_new, y_new = block_fit["overlap"]
    blocked = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                       block_fit["cfg"])
    blocked.append(x_new, y_new)
    block_report = blocked.refine(mode="block")  # the silent-residual path

    o = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                 block_fit["cfg"])
    o.append(x_new, y_new)
    report = o.refine(mode="auto")
    tol = block_fit["cfg"].solver.tolerance
    assert report.mode == "auto" and report.escalated
    assert block_report.res_y > 5.0 * tol  # block alone left it unsolved
    assert max(report.res_y, report.res_z) <= tol * 1.01  # auto solved it
    # escalation charges block attempt + full solve: more than either alone
    assert report.epochs > block_report.epochs
    assert report.block_rows == x_new.shape[0]
    # an explicit lax threshold keeps the block path instead
    o2 = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                  block_fit["cfg"])
    o2.append(x_new, y_new)
    lax_report = o2.refine(mode="auto", coupling_threshold=10.0)
    assert not lax_report.escalated
    assert lax_report.epochs < report.epochs


def test_block_refresh_requires_warm_and_noop_without_appends(block_fit):
    o = OnlineGP(block_fit["x"], block_fit["y"], block_fit["state"],
                 block_fit["cfg"])
    with pytest.raises(ValueError, match="warm"):
        o.refine(mode="block", warm=False)
    report = o.refine(mode="block")  # nothing appended => no-op
    assert report.appended == 0 and report.epochs == 0.0
    np.testing.assert_allclose(np.asarray(o.state.carry_v),
                               np.asarray(block_fit["state"].carry_v))


def test_single_sample_variance_raises(fitted):
    """Regression: s=1 used to silently return a zero-information variance
    through jnp.maximum(s - 1, 1); it must fail loudly now."""
    st = fitted["state"]
    corr = correction_matrix(st.carry_v[:, :2])  # keep only [v_y | z_1]
    rff1 = st.probes.rff._replace(w=st.probes.rff.w[:, :1])  # 1 prior sample
    with pytest.raises(ValueError, match=">= 2 pathwise samples"):
        pathwise_predict_from_correction(
            fitted["x"], fitted["xq"], corr, rff1, st.params, bm=64, bn=64,
        )
