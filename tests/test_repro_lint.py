"""repro-lint gates: seeded fixtures hit exact rules/lines, the
suppression/baseline round-trip holds, and the live tree stays clean
(tools/repro_lint.py is also a standalone static-lint CI job)."""
import shutil
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import (config_discipline, freeze_mask,  # noqa: E402
                            lock_discipline, runner, telemetry, trace_safety)

FIXTURES = REPO / "tests" / "fixtures" / "repro_lint"


def _findings(checker, name):
    return checker.run([FIXTURES / name], REPO)


def _pairs(findings):
    return [(f.rule, f.line) for f in findings]


# -- each checker: bad fixture yields exact (rule, line), good is clean ------

def test_trace_safety_fixture():
    assert _pairs(_findings(trace_safety, "bad_trace.py")) == [
        ("trace-python-branch", 10),
        ("trace-impure-call", 12),
        ("trace-host-sync", 13),
    ]
    assert _findings(trace_safety, "good_trace.py") == []


def test_config_discipline_fixture():
    assert _pairs(_findings(config_discipline, "bad_config.py")) == [
        ("config-static-array", 13),
        ("config-static-traced", 17),
        ("config-static-traced", 18),
        ("config-static-traced", 21),
    ]
    assert _findings(config_discipline, "good_config.py") == []


def test_freeze_mask_fixture():
    assert _pairs(_findings(freeze_mask, "bad_freeze.py")) == [
        ("freeze-mask", 23),
    ]
    assert _findings(freeze_mask, "good_freeze.py") == []


def test_lock_discipline_fixture():
    assert _pairs(_findings(lock_discipline, "bad_lock.py")) == [
        ("lock-discipline", 11),   # guarded attr touched without the lock
        ("lock-discipline", 17),   # *_locked helper called outside a lock
        ("lock-discipline", 29),   # foreign class reaches into guarded attr
    ]
    assert _findings(lock_discipline, "good_lock.py") == []


def test_telemetry_fixture():
    assert _pairs(_findings(telemetry, "bad_telemetry.py")) == [
        ("telemetry-label", 11),
        ("telemetry-label", 13),
        ("telemetry-event-schema", 14),
        ("telemetry-event-schema", 15),
    ]
    assert _findings(telemetry, "good_telemetry.py") == []


def test_findings_carry_hints():
    for f in _findings(freeze_mask, "bad_freeze.py"):
        assert f.hint  # every finding ships a fix hint
        assert "freeze(" in f.hint


# -- CLI: nonzero exit + rule/line in output per seeded fixture --------------

@pytest.mark.parametrize("fixture,subdir,expect", [
    ("bad_trace.py", "src/repro/solvers", "[trace-python-branch]"),
    ("bad_config.py", "src/repro/core", "[config-static-traced]"),
    ("bad_freeze.py", "src/repro/solvers", "[freeze-mask]"),
    ("bad_lock.py", "src/repro/serve", "[lock-discipline]"),
    ("bad_telemetry.py", "src/repro/obs", "[telemetry-label]"),
])
def test_cli_fails_on_seeded_fixture(tmp_path, capsys, fixture, subdir,
                                     expect):
    dest = tmp_path / subdir
    dest.mkdir(parents=True)
    shutil.copy(FIXTURES / fixture, dest / fixture)
    assert runner.main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert expect in out
    assert f"{subdir}/{fixture}:" in out


# -- suppression / baseline round-trip ---------------------------------------

def _toy_repo(tmp_path, source):
    sol = tmp_path / "src" / "repro" / "solvers"
    sol.mkdir(parents=True)
    (tmp_path / "src" / "repro" / "analysis").mkdir()
    (sol / "toy.py").write_text(source)
    return sol / "toy.py"


_BAD = (FIXTURES / "bad_freeze.py").read_text() if FIXTURES.exists() else ""
_SUPPRESSED = _BAD.replace(
    "            res=res,",
    "            # repro-lint: disable=freeze-mask -- toy keeps res live\n"
    "            res=res,")
_NO_REASON = _BAD.replace(
    "            res=res,",
    "            # repro-lint: disable=freeze-mask\n"
    "            res=res,")


def test_suppression_baseline_round_trip(tmp_path, capsys):
    toy = _toy_repo(tmp_path, _SUPPRESSED)
    # Suppressed inline but not baselined: the ledger contract fails.
    assert runner.main(["--root", str(tmp_path)]) == 1
    assert "missing from" in capsys.readouterr().out
    # --update-baseline records the reviewed entry; the tree goes clean.
    assert runner.main(["--root", str(tmp_path), "--update-baseline"]) == 0
    assert runner.main(["--root", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "1 baselined suppression" in out
    # Dropping the inline comment revives the finding AND stales the entry.
    toy.write_text(_BAD)
    assert runner.main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "[freeze-mask]" in out and "stale entry" in out


def test_suppression_requires_reason(tmp_path, capsys):
    _toy_repo(tmp_path, _NO_REASON)
    assert runner.main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "has no reason" in out


def test_baseline_entries_have_inline_comments():
    """Acceptance: every baseline entry maps to a live inline suppression."""
    findings = runner.collect_findings(REPO)
    _active, suppressed, errors = runner.partition(REPO, findings)
    assert errors == []
    assert runner.check_baseline(REPO, suppressed) == []
    live = {(f.rule, f.path) for f, _ in suppressed}
    from repro.analysis.common import load_baseline
    for e in load_baseline(REPO / runner.BASELINE):
        assert (e["rule"], e["path"]) in live
        assert e["reason"].strip()


# -- the live tree stays clean (tier-1 gate mirroring the CI job) ------------

def test_live_tree_clean(capsys):
    assert runner.main(["--root", str(REPO), "--check"]) == 0
    assert "clean" in capsys.readouterr().out
