"""End-to-end system behaviour: the paper's full pipeline on a small
dataset — all four (estimator x warm-start) variants reach the same
predictive quality, and the headline orderings from Table 1 hold."""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full-pipeline fits; minutes on CPU

from repro.core import OuterConfig, fit
from repro.data.synthetic import load_dataset, pad_to_block_multiple
from repro.solvers import SolverConfig


@pytest.fixture(scope="module")
def ds():
    return load_dataset("pol", max_n=1200)


def _fit(ds, solver_cfg, est, warm, steps=30, probes=32, event_log=None):
    x, y = ds.x_train, ds.y_train
    if solver_cfg.name in ("ap", "sgd"):
        blk = (solver_cfg.block_size if solver_cfg.name == "ap"
               else solver_cfg.batch_size)
        x, y, _ = pad_to_block_multiple(x, y, blk)
    cfg = OuterConfig(
        estimator=est, warm_start=warm, num_probes=probes,
        num_rff_pairs=500, solver=solver_cfg, num_steps=steps,
        bm=256, bn=256,
    )
    return fit(x, y, cfg, key=jax.random.PRNGKey(0),
               x_test=ds.x_test, y_test=ds.y_test, eval_every=steps,
               event_log=event_log)


def test_end_to_end_cg_all_variants_same_quality(ds):
    """Solving to tolerance: predictive metrics agree across variants
    (paper: 'predictive performance is almost identical')."""
    solver = SolverConfig(name="cg", tolerance=0.01, max_epochs=500,
                          precond_rank=20)
    llh = {}
    for est in ("standard", "pathwise"):
        for warm in (False, True):
            r = _fit(ds, solver, est, warm)
            llh[(est, warm)] = r.history["eval_llh"][-1]
    vals = np.array(list(llh.values()))
    assert np.isfinite(vals).all()
    assert vals.max() - vals.min() < 0.2, llh


@pytest.fixture(scope="module")
def ap_variants(ds):
    """standard+cold vs pathwise+warm AP fits, run once for both ordering
    tests, each with a structured event log attached: (total epochs, total
    iters, parsed telemetry events) per variant."""
    import io
    import json

    from repro.obs.trace import EventLog

    solver = SolverConfig(name="ap", tolerance=0.01, max_epochs=300,
                          block_size=100)
    out = {}
    for est, warm in [("standard", False), ("pathwise", True)]:
        buf = io.StringIO()
        log = EventLog(stream=buf)
        r = _fit(ds, solver, est, warm, steps=20, event_log=log)
        events = [json.loads(l) for l in buf.getvalue().splitlines()]
        out[(est, warm)] = (
            float(r.history["epochs"].sum()),
            int(r.history["iters"].sum()),
            events,
        )
    return out


def test_warm_start_speedup_ordering_ap(ap_variants):
    """Table 1's structural claim for AP: pathwise+warm beats standard cold
    in solver epochs and iterations. (The paper's 72x arises over 100 outer
    steps on n=13.5k as conditioning degrades; at CPU-test scale the
    ordering is the invariant — magnitudes live in benchmarks/table1.)
    Deterministic budget accounting only — the telemetry companion below
    checks the same ordering through the event stream."""
    e_base, i_base, _ = ap_variants[("standard", False)]
    e_best, i_best, _ = ap_variants[("pathwise", True)]
    assert e_best < e_base, ap_variants
    assert i_best < i_base, ap_variants


def test_warm_start_telemetry_ordering_ap(ap_variants):
    """Telemetry companion to the epoch ordering (replaces the old
    wall-clock assertion, which was load-sensitive and flaked under CI
    noise): the structured solve_step/fit_done events must agree with the
    history's deterministic budget accounting, and the per-event solver
    work ordering — warm below cold in total and in the post-warmup tail —
    must hold in the event stream itself. Epoch counts are device-work
    units (epochs x n^2 kernel elements), so cheaper epochs ARE cheaper
    compute, without a host timer in the loop."""
    orderings = {}
    for variant, (epochs, iters, events) in ap_variants.items():
        steps = [e for e in events if e["kind"] == "solve_step"]
        done = [e for e in events if e["kind"] == "fit_done"]
        assert len(steps) == 20 and len(done) == 1, variant
        # Telemetry must agree with the history aggregation exactly.
        assert np.isclose(sum(e["epochs"] for e in steps), epochs), variant
        assert sum(e["iters"] for e in steps) == iters, variant
        assert np.isclose(done[0]["total_epochs"], epochs), variant
        assert done[0]["num_steps"] == 20
        # Tail = everything after the first step (the cold first solve of
        # the warm variant is identical work to the cold baseline's).
        orderings[variant] = (
            sum(e["epochs"] for e in steps),
            sum(e["epochs"] for e in steps[1:]),
        )
    total_base, tail_base = orderings[("standard", False)]
    total_best, tail_best = orderings[("pathwise", True)]
    assert total_best < total_base, orderings
    assert tail_best < tail_base, orderings


def test_driver_checkpoint_resume(ds, tmp_path):
    """Kill-and-resume mid-fit: final state identical to an uninterrupted
    run (fault-tolerance contract)."""
    solver = SolverConfig(name="cg", tolerance=0.01, max_epochs=200,
                          precond_rank=10)
    cfg = OuterConfig(estimator="pathwise", warm_start=True, num_probes=8,
                      num_rff_pairs=200, solver=solver, num_steps=8,
                      bm=256, bn=256)
    x, y = ds.x_train, ds.y_train
    full = fit(x, y, cfg, key=jax.random.PRNGKey(1))

    ck = str(tmp_path / "ck")
    cfg_half = OuterConfig(**{**cfg.__dict__, "num_steps": 4})
    fit(x, y, cfg_half, key=jax.random.PRNGKey(1), ckpt_dir=ck, ckpt_every=4)
    resumed = fit(x, y, cfg, key=jax.random.PRNGKey(1), ckpt_dir=ck,
                  resume=True)
    np.testing.assert_allclose(
        np.asarray(full.state.params.flat()),
        np.asarray(resumed.state.params.flat()), rtol=1e-5,
    )
