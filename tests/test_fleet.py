"""Fleet observability plane: Prometheus parse/render round-trip, the
fleet scraper's staleness/TTL machinery, SLO burn-rate rules + the alert
state machine, EventLog rotation, bucket-quantile helpers, the bench
regression observatory, and the end-to-end 2-replica fleet test."""
import json
import math
import os
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import (
    MetricsRegistry,
    bucket_fraction_le,
    quantile_from_buckets,
)
from repro.obs.scrape import (
    FleetScraper,
    parse_prometheus,
    render_families,
    unescape_label_value,
)
from repro.obs.slo import (
    OK,
    PAGE,
    WARN,
    AvailabilitySLO,
    BurnRateRule,
    LatencySLO,
    SLOEngine,
)

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))


# -- Prometheus round-trip ----------------------------------------------------

ADVERSARIAL_LABELS = [
    'plain',
    'with"quote',
    "back\\slash",
    "new\nline",
    'all\\three" \n mixed',
    '\\n literal-backslash-n',
    'trailing\\',
]


def _families_equal(a, b):
    assert sorted(a) == sorted(b)
    for name in a:
        fa, fb = a[name], b[name]
        assert fa.kind == fb.kind, name
        assert fa.help == fb.help, name
        sa = sorted((s.name, tuple(sorted(s.labels.items())), s.value)
                    for s in fa.samples if not math.isnan(s.value))
        sb = sorted((s.name, tuple(sorted(s.labels.items())), s.value)
                    for s in fb.samples if not math.isnan(s.value))
        assert sa == sb, name


def test_parse_render_round_trip_all_kinds():
    """parse(render(registry)) recovers every family, sample and label for
    counters, gauges and histograms — including adversarial escapes."""
    reg = MetricsRegistry()
    c = reg.counter("gp_rt_total", 'help with "quotes" and \\slash\nline',
                    ["path"])
    for i, lbl in enumerate(ADVERSARIAL_LABELS):
        c.inc(i + 0.5, path=lbl)
    g = reg.gauge("gp_rt_gauge", "gauge help", ["k"])
    g.set(math.inf, k="inf")
    g.set(-math.inf, k="-inf")
    g.set(-12.75, k="neg")
    g.set(3, k="int")
    h = reg.histogram("gp_rt_seconds", "hist help", ["op"],
                      buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v, op='o"p\\s\n')

    text = reg.render()
    parsed = parse_prometheus(text)
    # Re-render the parse and parse again: a true inverse is idempotent.
    re_text = "\n".join(render_families(parsed)) + "\n"
    _families_equal(parsed, parse_prometheus(re_text))

    fam = parsed["gp_rt_total"]
    assert fam.kind == "counter"
    assert fam.help == 'help with "quotes" and \\slash\nline'
    got = {s.labels["path"]: s.value for s in fam.samples}
    assert got == {lbl: i + 0.5 for i, lbl in enumerate(ADVERSARIAL_LABELS)}

    gauge = {s.labels["k"]: s.value for s in parsed["gp_rt_gauge"].samples}
    assert gauge["inf"] == math.inf and gauge["-inf"] == -math.inf
    assert gauge["neg"] == -12.75 and gauge["int"] == 3.0

    hist = parsed["gp_rt_seconds"]
    assert hist.kind == "histogram"
    buckets = {s.labels["le"]: s.value for s in hist.samples
               if s.name.endswith("_bucket")}
    assert buckets == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}
    count = [s for s in hist.samples if s.name.endswith("_count")]
    total = [s for s in hist.samples if s.name.endswith("_sum")]
    assert count[0].value == 3.0
    assert total[0].value == pytest.approx(5.55)


def test_unescape_is_exact_inverse():
    for raw in ADVERSARIAL_LABELS:
        assert unescape_label_value(
            obs_metrics.escape_label_value(raw)) == raw


def test_parse_value_specials_and_malformed():
    from repro.obs.scrape import parse_value

    assert parse_value("+Inf") == math.inf
    assert parse_value("-Inf") == -math.inf
    assert math.isnan(parse_value("NaN"))
    with pytest.raises(ValueError):
        parse_prometheus("gp_x{bad} 1\n")
    with pytest.raises(ValueError):
        parse_prometheus("gp_x\n")


def test_render_families_appends_extra_label():
    fams = parse_prometheus('# TYPE gp_a counter\ngp_a{x="1"} 2\ngp_a 3\n')
    lines = render_families(fams, extra_label=("replica", 'r"0'))
    assert 'gp_a{x="1",replica="r\\"0"} 2' in lines
    assert 'gp_a{replica="r\\"0"} 3' in lines


# -- bucket quantiles ---------------------------------------------------------

def test_quantile_from_buckets_interpolation():
    bounds = (0.1, 1.0)
    # 5 obs <= 0.1, 5 more in (0.1, 1.0], none above.
    cum = [5.0, 10.0, 10.0]
    assert quantile_from_buckets(bounds, cum, 0.5) == pytest.approx(0.1)
    assert quantile_from_buckets(bounds, cum, 0.75) == pytest.approx(0.55)
    assert quantile_from_buckets(bounds, cum, 0.25) == pytest.approx(0.05)
    # Everything in +Inf clamps to the last finite bound.
    assert quantile_from_buckets(bounds, [0.0, 0.0, 7.0], 0.9) == 1.0
    assert math.isnan(quantile_from_buckets(bounds, [0.0, 0.0, 0.0], 0.5))
    assert math.isnan(quantile_from_buckets(bounds, cum, 1.5))
    with pytest.raises(ValueError):
        quantile_from_buckets(bounds, [1.0], 0.5)


def test_bucket_fraction_le():
    bounds = (0.1, 1.0)
    cum = [5.0, 10.0, 10.0]
    assert bucket_fraction_le(bounds, cum, 0.1) == pytest.approx(0.5)
    assert bucket_fraction_le(bounds, cum, 1.0) == pytest.approx(1.0)
    assert bucket_fraction_le(bounds, cum, 2.0) == 1.0
    assert bucket_fraction_le(bounds, cum, 0.55) == pytest.approx(0.75)
    assert math.isnan(bucket_fraction_le(bounds, [0.0, 0.0, 0.0], 0.1))


# -- FleetScraper -------------------------------------------------------------

class FakeFleetHTTP:
    """In-memory stand-in for N replica HTTP endpoints."""

    def __init__(self):
        self.registries = {}
        self.stats = {}
        self.dead = set()

    def add(self, name):
        reg = MetricsRegistry()
        self.registries[name] = reg
        self.stats[name] = {
            "admission": {"admitted": 0, "shed": 0, "service_ewma_ms": 1.5,
                          "inflight": 0},
            "engine": {"requests": 0},
            "draining": False,
            "version": "v1",
        }
        return reg

    def fetch(self, url, timeout):
        name, _, route = url.partition("://")[2].partition("/")
        if name in self.dead:
            raise OSError("connection refused")
        if route == "metrics":
            return self.registries[name].render().encode()
        if route == "stats":
            return json.dumps(self.stats[name]).encode()
        raise OSError(f"404 {route}")


def _make_scraper(http, names, **kw):
    clock = {"t": 0.0}
    kw.setdefault("stale_after_misses", 2)
    kw.setdefault("ttl_s", 10.0)
    scraper = FleetScraper(
        targets={n: f"fake://{n}" for n in names},
        clock=lambda: clock["t"], fetch=http.fetch, **kw)
    return scraper, clock


def test_scraper_aggregates_with_replica_label_exactly():
    http = FakeFleetHTTP()
    for name, inc in (("r0", 3), ("r1", 5)):
        reg = http.add(name)
        reg.counter("gp_http_requests_total", "reqs",
                    ["path", "status"]).inc(inc, path="/predict", status="200")
    scraper, _ = _make_scraper(http, ["r0", "r1"])
    assert scraper.scrape_once() == {"r0": True, "r1": True}

    total = scraper.counter_total(
        "gp_http_requests_total",
        where=lambda lbl: lbl.get("path") == "/predict")
    assert total == 8.0

    fams = parse_prometheus(scraper.render())
    per_replica = {
        s.labels["replica"]: s.value
        for s in fams["gp_http_requests_total"].samples
    }
    assert per_replica == {"r0": 3.0, "r1": 5.0}
    up = {s.labels["replica"]: s.value
          for s in fams["gp_fleet_replica_up"].samples}
    assert up == {"r0": 1.0, "r1": 1.0}


def test_scraper_staleness_and_ttl():
    http = FakeFleetHTTP()
    reg = http.add("r0")
    reg.counter("gp_x_total", "x").inc(7)
    http.add("r1")
    scraper, clock = _make_scraper(http, ["r0", "r1"],
                                   stale_after_misses=2, ttl_s=5.0)
    scraper.scrape_once()
    assert scraper.health()["r0"]["up"]

    http.dead.add("r0")
    clock["t"] = 1.0
    scraper.scrape_once()
    h = scraper.health()["r0"]
    assert h["up"] and h["consecutive_misses"] == 1  # one miss: still up
    clock["t"] = 2.0
    scraper.scrape_once()
    h = scraper.health()["r0"]
    assert not h["up"] and h["consecutive_misses"] == 2  # second miss: down
    # Series survive until the TTL expires...
    assert scraper.counter_total("gp_x_total") == 7.0
    fams = parse_prometheus(scraper.render())
    assert fams["gp_fleet_replica_up"].samples[0].value == 0.0
    # ...then are dropped.
    clock["t"] = 6.0
    scraper.scrape_once()
    assert scraper.counter_total("gp_x_total") == 0.0
    fams = parse_prometheus(scraper.render())
    assert "gp_x_total" not in fams
    # The up series itself survives the drop: the fleet must keep seeing
    # the dead member.
    up = {s.labels["replica"]: s.value
          for s in fams["gp_fleet_replica_up"].samples}
    assert up == {"r0": 0.0, "r1": 1.0}
    # Recovery resets the machinery.
    http.dead.discard("r0")
    clock["t"] = 7.0
    scraper.scrape_once()
    assert scraper.health()["r0"]["up"]
    assert scraper.counter_total("gp_x_total") == 7.0


def test_scraper_target_removal_drops_series():
    http = FakeFleetHTTP()
    http.add("r0").counter("gp_x_total", "x").inc(1)
    http.add("r1").counter("gp_x_total", "x").inc(2)
    scraper, _ = _make_scraper(http, ["r0", "r1"])
    scraper.scrape_once()
    scraper.set_targets({"r1": "fake://r1"})  # r0 scaled down
    assert scraper.counter_total("gp_x_total") == 2.0
    fams = parse_prometheus(scraper.render())
    names = {s.labels["replica"]
             for s in fams["gp_fleet_replica_up"].samples}
    assert names == {"r1"}


def test_scraper_health_lifts_stats_signals():
    http = FakeFleetHTTP()
    http.add("r0")
    http.stats["r0"]["admission"].update(
        admitted=30, shed=10, service_ewma_ms=4.25, inflight=2)
    reg = http.registries["r0"]
    reg.gauge("gp_engine_queue_depth", "depth").set(3)
    scraper, _ = _make_scraper(http, ["r0"])
    scraper.scrape_once()
    h = scraper.health()["r0"]
    assert h["service_ewma_ms"] == 4.25
    assert h["shed_rate"] == pytest.approx(0.25)
    assert h["inflight"] == 2
    assert h["queue_depth"] == 3.0
    assert h["version"] == "v1"


def test_scraper_histogram_cumulative_merges_across_replicas():
    http = FakeFleetHTTP()
    for name, vals in (("r0", (0.05, 0.5)), ("r1", (0.05,))):
        reg = http.add(name)
        hist = reg.histogram("gp_http_request_seconds", "lat", ["path"],
                             buckets=(0.1, 1.0))
        for v in vals:
            hist.observe(v, path="/predict")
    scraper, _ = _make_scraper(http, ["r0", "r1"])
    scraper.scrape_once()
    bounds, cum = scraper.histogram_cumulative("gp_http_request_seconds")
    assert bounds == (0.1, 1.0)
    assert cum == [2.0, 3.0, 3.0]


# -- SLO engine ---------------------------------------------------------------

class FakeFleet:
    """Direct control over the accessor surface the SLO engine reads."""

    def __init__(self):
        self.good = 0.0
        self.bad = 0.0
        self.hist = ((0.1, 1.0), [0.0, 0.0, 0.0])

    def counter_total(self, family, where=None):
        if where is not None and where({"status": "500"}):
            return self.bad
        return self.good

    def scrape_totals(self):
        return 0.0, 0.0

    def histogram_cumulative(self, family, where=None):
        return self.hist


def _engine(fleet, objective=0.9, fast=10.0, slow=30.0, stream=None):
    rules = [
        BurnRateRule(PAGE, 10.0, fast, slow),
        BurnRateRule(WARN, 2.0, fast, slow),
    ]
    log = obs_trace.EventLog(stream=stream) if stream is not None else None
    clock = {"t": 0.0}
    eng = SLOEngine(
        fleet, [AvailabilitySLO(objective=objective, rules=rules,
                                count_scrapes=False)],
        event_log=log, clock=lambda: clock["t"])
    return eng, clock


def test_slo_burn_escalates_and_pages():
    import io

    fleet = FakeFleet()
    stream = io.StringIO()
    eng, clock = _engine(fleet, stream=stream)
    fleet.good = 100.0
    status = eng.evaluate()
    assert status["availability"]["state"] == OK

    # 100% errors: burn = 1.0 / (1 - 0.9) = 10 >= PAGE threshold in both
    # windows once the window holds only bad deltas.
    for step in range(1, 4):
        clock["t"] = step * 1.0
        fleet.bad += 50.0
        status = eng.evaluate()
    assert status["availability"]["state"] == PAGE
    events = [json.loads(line) for line in
              stream.getvalue().splitlines()]
    transitions = [(e["from_state"], e["to_state"]) for e in events
                   if e["kind"] == "slo_alert"]
    assert transitions[-1][1] == PAGE
    assert all(e["slo"] == "availability" for e in events)


def test_slo_warn_then_hysteresis_deescalation():
    fleet = FakeFleet()
    eng, clock = _engine(fleet, fast=5.0, slow=5.0)
    fleet.good = 100.0
    eng.evaluate()
    # ~30% errors -> burn 3: above WARN(2), below PAGE(10).
    clock["t"] = 1.0
    fleet.bad += 30.0
    fleet.good += 70.0
    status = eng.evaluate()
    assert status["availability"]["state"] == WARN
    # Burn just below the raw threshold but above threshold*hysteresis
    # (2 * 0.8 = 1.6): must HOLD the WARN state.
    clock["t"] = 2.0
    fleet.bad += 18.0
    fleet.good += 82.0
    status = eng.evaluate()
    assert status["availability"]["state"] == WARN
    # Clean traffic only; once the window slides past the bad spell the
    # burn collapses and the state returns to OK.
    for step in range(3, 10):
        clock["t"] = float(step)
        fleet.good += 100.0
        status = eng.evaluate()
    assert status["availability"]["state"] == OK


def test_slo_gauges_and_budget_exported():
    fleet = FakeFleet()
    eng, clock = _engine(fleet)
    fleet.good, fleet.bad = 95.0, 5.0
    eng.evaluate()
    text = eng.registry.render()
    fams = parse_prometheus(text)
    state = {s.labels["slo"]: s.value for s in fams["gp_slo_state"].samples}
    assert state == {"availability": 0.0}
    budget = fams["gp_slo_error_budget_remaining"].samples[0].value
    # 5 bad of allowed 10 (10% of 100) -> half the budget left.
    assert budget == pytest.approx(0.5)


def test_latency_slo_splits_histogram():
    fleet = FakeFleet()
    fleet.hist = ((0.1, 1.0), [8.0, 10.0, 10.0])
    slo = LatencySLO(objective=0.5, threshold_s=0.1)
    good, bad = slo.totals(fleet)
    assert good == pytest.approx(8.0)
    assert bad == pytest.approx(2.0)
    qs = slo.quantiles(fleet, qs=(0.5,))
    assert qs[0.5] == pytest.approx(0.0625)


# -- EventLog rotation --------------------------------------------------------

def test_event_log_rotation_mid_stream(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = obs_trace.EventLog(path=path, max_bytes=400, backups=2)
    for i in range(50):
        log.emit("tick", i=i)
    log.close()
    assert log.rotations > 0
    files = [path, path + ".1", path + ".2"]
    for f in files[:2]:
        assert os.path.exists(f), f
    assert not os.path.exists(path + ".3")
    # Every surviving line is intact JSON (rotation never splits a line)
    # and the newest file holds the newest events.
    seen = []
    for f in files:
        if not os.path.exists(f):
            continue
        for line in open(f):
            seen.append(json.loads(line)["i"])
        assert os.path.getsize(f) <= 400 + 100  # one line of slack
    assert max(seen) == 49
    assert sorted(seen) == list(range(min(seen), 50))


def test_event_log_rotation_requires_path():
    import io

    with pytest.raises(ValueError):
        obs_trace.EventLog(stream=io.StringIO(), max_bytes=100)


# -- EngineStats latency quantiles (schema v3) --------------------------------

def test_engine_stats_latency_quantiles_schema_v3():
    from repro.serve.engine import STATS_SCHEMA_VERSION, EngineStats

    assert STATS_SCHEMA_VERSION == 3
    stats = EngineStats()
    d = stats.as_dict()
    assert d["schema_version"] == 3
    assert d["latency_p50"] is None and d["latency_p99"] is None
    for _ in range(90):
        stats.record(16, 16, 1, dur_s=0.002)
    for _ in range(10):
        stats.record(16, 16, 1, dur_s=4.0)
    d = stats.as_dict()
    assert 0.001 < d["latency_p50"] <= 0.0025
    assert d["latency_p99"] > 1.0


# -- bench history observatory ------------------------------------------------

def _seed_history(bench_dir, module, metric_rows):
    from benchmarks import history

    for ts, metrics in enumerate(metric_rows):
        path = history.history_path(str(bench_dir), module)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps({"ts": float(ts), "metrics": metrics}) + "\n")


def test_bench_history_flatten_and_append(tmp_path):
    from benchmarks import history

    report = {
        "module": "m", "wall_s": 2.0, "failed": False,
        "rows": [{"name": "k/dense", "us_per_call": 10.0, "derived": ""}],
        "nested": {"qps": 100.0, "note": "text", "deep": {"x": 1.0}},
        "flags": [1, 2, 3],
    }
    flat = history.flatten_metrics(report)
    assert flat == {"wall_s": 2.0, "k/dense.us_per_call": 10.0,
                    "nested.qps": 100.0, "nested.deep.x": 1.0}
    assert history.append_history(str(tmp_path), "m", report) is not None
    assert history.append_history(
        str(tmp_path), "m", {"failed": True, "wall_s": 1.0}) is None
    entries = history.load_history(str(tmp_path), "m")
    assert len(entries) == 1 and entries[0]["metrics"] == flat
    assert history.list_modules(str(tmp_path)) == ["m"]


def test_bench_history_check_flags_2x_throughput_regression(tmp_path):
    import bench_history

    base = [{"bo.rounds_per_sec": 20.0, "wall_s": 3.0} for _ in range(3)]
    _seed_history(tmp_path, "online_bo", base + [
        {"bo.rounds_per_sec": 9.5, "wall_s": 3.1}])  # > 2x slower
    rc = bench_history.main(
        ["--bench-dir", str(tmp_path), "--check", "--max-ratio", "2.0"])
    assert rc == 1

    # Same shape within threshold passes.
    clean = tmp_path / "clean"
    _seed_history(clean, "online_bo", base + [
        {"bo.rounds_per_sec": 15.0, "wall_s": 3.2}])
    rc = bench_history.main(
        ["--bench-dir", str(clean), "--check", "--max-ratio", "2.0"])
    assert rc == 0


def test_bench_history_lower_better_and_baseline_dir(tmp_path):
    import bench_history

    # Latency doubled vs the rolling median: regression.
    _seed_history(tmp_path, "kernel", [
        {"k.us_per_call": 100.0}, {"k.us_per_call": 102.0},
        {"k.us_per_call": 98.0}, {"k.us_per_call": 260.0}])
    rc = bench_history.main(
        ["--bench-dir", str(tmp_path), "--check", "--max-ratio", "1.5"])
    assert rc == 1

    # Single entry + committed BENCH baseline: gated against the file.
    solo = tmp_path / "solo"
    _seed_history(solo, "kernel", [{"k.us_per_call": 300.0}])
    (solo).mkdir(exist_ok=True)
    baseline = tmp_path / "baseline"
    baseline.mkdir()
    (baseline / "BENCH_kernel.json").write_text(json.dumps({
        "module": "kernel", "failed": False, "wall_s": 1.0,
        "rows": [{"name": "k", "us_per_call": 100.0, "derived": ""}]}))
    rc = bench_history.main(
        ["--bench-dir", str(solo), "--baseline", str(baseline),
         "--check", "--max-ratio", "1.5"])
    assert rc == 1
    # Without any baseline the module is recorded but not gated.
    rc = bench_history.main(
        ["--bench-dir", str(solo), "--check", "--max-ratio", "1.5"])
    assert rc == 0


def test_bench_history_real_artifacts_pass():
    """The committed artifacts/bench state must be regression-free."""
    import bench_history

    bench_dir = REPO / "artifacts" / "bench"
    if not (bench_dir / "history").is_dir():
        pytest.skip("no committed bench history")
    rc = bench_history.main(
        ["--bench-dir", str(bench_dir), "--baseline", str(bench_dir),
         "--check", "--max-ratio", "5.0"])
    assert rc == 0


# -- trace_report --fleet -----------------------------------------------------

def test_trace_report_fleet_merges_alerts_and_requests(tmp_path, capsys):
    import trace_report

    fleet = tmp_path / "fleet-logs"
    fleet.mkdir()
    t0 = time.time()
    with open(fleet / "replica_0.jsonl", "w") as f:
        f.write(json.dumps({"ts": t0, "kind": "request", "trace_id": "tr-1",
                            "path": "/predict", "status": 200}) + "\n")
        f.write("{\"ts\": truncated-mid-write")
    with open(fleet / "monitor.jsonl", "w") as f:
        f.write(json.dumps({
            "ts": t0 + 1.0, "kind": "slo_alert", "slo": "availability",
            "from_state": "OK", "to_state": "PAGE",
            "burn_rates": {"fast_page": 50.0}}) + "\n")

    rc = trace_report.main(["--fleet", str(fleet)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "fleet timeline" in out
    assert "OK -> PAGE" in out
    assert "tr-1" in out  # traced request still renders in the waterfall


# -- end-to-end fleet ---------------------------------------------------------

@pytest.mark.slow
def test_fleet_monitor_end_to_end(tmp_path):
    """Supervisor replicas under live traffic -> monitor scrapes both ->
    aggregate equals the per-replica counters EXACTLY, health matches
    /stats, and killing a replica flips up to 0 within ~2 scrape
    intervals and pages the availability burn-rate rule."""
    import urllib.request

    import jax

    from repro.core import OuterConfig, init_outer_state, outer_step
    from repro.data.synthetic import make_gp_regression
    from repro.obs.slo import default_rules
    from repro.serve import export_servable
    from repro.serve.cluster import ReplicaSupervisor, publish_servable
    from repro.serve.cluster.monitor import (
        FleetMonitor,
        start_monitor_server,
    )
    from repro.serve.cluster.replica import _http_json
    from repro.solvers import SolverConfig

    x, y = make_gp_regression(jax.random.PRNGKey(0), 160, 2, noise=0.2)
    xq = x[128:132]
    x, y = x[:128], y[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=200, precond_rank=0),
        num_steps=2, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    model = export_servable(state, x)

    store = str(tmp_path / "store")
    publish_servable(store, model)
    sup = ReplicaSupervisor(store, num_replicas=2, buckets=(8, 32),
                            bm=64, bn=64, poll_interval_s=0.5)
    interval = 0.3
    alert_log = str(tmp_path / "monitor.jsonl")
    monitor = FleetMonitor(
        supervisor=sup, interval_s=interval,
        slos=[AvailabilitySLO(
            objective=0.99,
            rules=default_rules(fast_window_s=6 * interval,
                                slow_window_s=18 * interval))],
        event_log=obs_trace.EventLog(path=alert_log),
    )

    def wait_for(pred, timeout_s, what):
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if pred():
                return
            time.sleep(0.05)
        pytest.fail(f"timed out waiting for {what}")

    server = None
    try:
        sup.start(timeout_s=240)
        server, _ = start_monitor_server(monitor)
        ep = f"http://127.0.0.1:{server.port}"

        wait_for(lambda: _http_json(ep + "/fleet/health")[1]["num_up"] == 2,
                 60, "both replicas up")

        payload = {"x": np.asarray(xq).tolist()}
        for _ in range(3):
            for url in sup.endpoints():
                status, _ = _http_json(url + "/predict", payload)
                assert status == 200

        # Exactness: /fleet/metrics /predict totals == per-replica totals.
        def predict_total(fams, where=None):
            fam = fams.get("gp_http_requests_total")
            return sum(s.value for s in (fam.samples if fam else ())
                       if s.labels.get("path") == "/predict"
                       and (where is None or where(s.labels)))

        def parse_url(url):
            with urllib.request.urlopen(url, timeout=10) as resp:
                return parse_prometheus(resp.read().decode())

        direct = {
            f"replica_{i}": predict_total(parse_url(url + "/metrics"))
            for i, url in enumerate(sup.endpoints())
        }
        assert sum(direct.values()) >= 6.0

        def aggregate_matches():
            fams = parse_url(ep + "/fleet/metrics")
            got = {
                name: predict_total(
                    fams, where=lambda lbl, n=name: lbl.get("replica") == n)
                for name in direct
            }
            return got == direct

        wait_for(aggregate_matches, 20, f"aggregate == {direct}")

        # Health signals match each replica's own /stats exactly.
        _, health = _http_json(ep + "/fleet/health")
        for i, url in enumerate(sup.endpoints()):
            entry = health["replicas"][f"replica_{i}"]
            _, stats = _http_json(url + "/stats")
            adm = stats["admission"]
            assert entry["service_ewma_ms"] == pytest.approx(
                adm["service_ewma_ms"], abs=1e-9)
            denom = adm["admitted"] + adm["shed"]
            want = adm["shed"] / denom if denom else 0.0
            assert entry["shed_rate"] == pytest.approx(want, abs=1e-9)

        wait_for(lambda: _http_json(ep + "/fleet/slo")[1]["slos"]
                 ["availability"]["state"] == "OK", 30,
                 "availability to settle OK")

        # Chaos: kill replica 1; up must flip within ~2 scrape intervals.
        sup.kill(1)
        t_kill = time.monotonic()
        wait_for(lambda: not _http_json(ep + "/fleet/health")[1]
                 ["replicas"]["replica_1"]["up"],
                 4 * interval + 10, "replica_1 marked down")
        assert time.monotonic() - t_kill < 4 * interval + 10

        wait_for(lambda: _http_json(ep + "/fleet/slo")[1]["slos"]
                 ["availability"]["state"] == "PAGE",
                 18 * interval + 30, "availability PAGE")

        # The alert trail recorded the escalation to PAGE.
        alerts = [json.loads(line) for line in open(alert_log)]
        assert any(e["kind"] == "slo_alert" and e["to_state"] == "PAGE"
                   for e in alerts)
    finally:
        if server is not None:
            server.shutdown()
        monitor.stop()
        sup.stop()
