"""LM substrate: loss correctness, microbatch-accumulation equivalence,
gradient compression error feedback, Adam reference behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.compression import compress, decompress, ef_init
from repro.models import init_params, lm_loss, make_train_step
from repro.models.steps import _forward_loss
from repro.train.adam import AdamConfig, adam_init, adam_update


def test_lm_loss_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, v = 2, 5, 11
    logits = jax.random.normal(key, (b, s, v))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, v)
    mask = jnp.ones((b, s))
    loss = lm_loss(logits, labels, mask)
    logp = jax.nn.log_softmax(logits, axis=-1)
    naive = -jnp.mean(
        jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    )
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-5)


def test_lm_loss_mask_excludes_positions():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (1, 4, 7))
    labels = jnp.zeros((1, 4), jnp.int32)
    m1 = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    l1 = lm_loss(logits, labels, m1)
    l2 = lm_loss(logits[:, :2], labels[:, :2], jnp.ones((1, 2)))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_microbatch_accumulation_equivalent():
    """Grad accumulation over M microbatches == single big batch (fp32)."""
    import dataclasses

    cfg = dataclasses.replace(get_config("llama3-8b", smoke=True),
                              compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    key = jax.random.PRNGKey(3)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "mask": jnp.ones((4, 16)),
    }
    s1 = jax.jit(make_train_step(cfg, num_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, num_microbatches=4))
    p1, _, l1 = s1(params, opt, batch)
    p4, _, l4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_compression_error_feedback_unbiased_over_time():
    """Sum of compressed grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((64, 64))}
    state = ef_init(params)
    total_true = jnp.zeros((64, 64))
    total_sent = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64)) * 1e-3}
        gq, state = compress(g, state)
        total_true += g["w"]
        total_sent += decompress(gq)["w"]
    drift = total_true - (total_sent + state.residual["w"])
    assert float(jnp.max(jnp.abs(drift))) < 1e-5


def test_compression_residual_bounded():
    key = jax.random.PRNGKey(1)
    params = {"w": jnp.zeros((128,))}
    state = ef_init(params)
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (128,))}
        _, state = compress(g, state)
    # residual stays at quantisation scale, does not accumulate unboundedly
    assert float(jnp.max(jnp.abs(state.residual["w"]))) < 0.1


def test_adam_matches_reference_scalar():
    """Closed-form check of one Adam step on a scalar."""
    p = {"x": jnp.asarray(1.0)}
    g = {"x": jnp.asarray(0.5)}
    st = adam_init(p)
    cfg = AdamConfig(learning_rate=0.1)
    p2, st2 = adam_update(g, st, p, cfg)
    # first step: mhat = g, vhat = g^2 -> delta = lr * g/(|g|+eps) = lr*sign
    np.testing.assert_allclose(float(p2["x"]), 1.0 - 0.1, rtol=1e-5)
    assert int(st2.step) == 1


def test_adam_maximize_ascends():
    p = {"x": jnp.asarray(1.0)}
    g = {"x": jnp.asarray(0.5)}
    p2, _ = adam_update(g, adam_init(p), p, AdamConfig(learning_rate=0.1),
                        maximize=True)
    assert float(p2["x"]) > 1.0


def test_vision_frontend_loss_path():
    cfg = get_config("internvl2-2b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, st = 2, 24
    npfx = cfg.frontend.num_prefix
    batch = {
        "tokens": jnp.zeros((b, st), jnp.int32),
        "patch_embeds": jnp.ones((b, npfx, cfg.frontend.embed_dim)) * 0.1,
        "labels": jnp.zeros((b, st), jnp.int32),
        "mask": jnp.ones((b, st)),
    }
    loss = _forward_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
