import jax
import jax.numpy as jnp
import pytest

# Smoke tests and benches must see 1 device (the dry-run sets its own flag
# in a subprocess) — do NOT force a device count here.


@pytest.fixture(scope="session")
def gp_problem():
    """Small synthetic GP regression problem + dense reference quantities."""
    from repro.data.synthetic import make_gp_regression
    from repro.gp.hyperparams import HyperParams
    from repro.gp.kernels_math import regularised_kernel_matrix

    key = jax.random.PRNGKey(0)
    n, d = 256, 3
    x, y = make_gp_regression(key, n + 64, d, noise=0.2)
    params = HyperParams.create(d, lengthscale=0.7, signal=1.1, noise=0.3)
    h = regularised_kernel_matrix(x[:n], params)
    return {
        "x": x[:n], "y": y[:n], "xs": x[n:], "ys": y[n:],
        "params": params, "h": h, "n": n, "d": d,
    }


@pytest.fixture(scope="session")
def batched_system(gp_problem):
    """H [v_y, v_1..v_s] = [y, b_1..b_s] with dense solution."""
    key = jax.random.PRNGKey(7)
    s = 8
    b = jnp.concatenate(
        [gp_problem["y"][:, None],
         jax.random.normal(key, (gp_problem["n"], s))], axis=1,
    )
    v = jnp.linalg.solve(gp_problem["h"], b)
    return {"b": b, "v_true": v, "s": s}
