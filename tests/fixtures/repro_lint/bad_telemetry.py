"""Seeded telemetry-hygiene violations (exact lines asserted in tests)."""


class Frontend:
    def __init__(self, registry, log):
        self._m_requests = registry.counter(
            "x_requests_total", "Requests", labelnames=("path",))
        self.log = log

    def observe(self, path, user_id, dur_ms):
        self._m_requests.inc(path=f"/q/{user_id}")  # LINE 11: telemetry-label
        label = "p_" + path
        self._m_requests.inc(path=label)  # LINE 13: telemetry-label (local)
        self.log.emit("requst", path=path)  # LINE 14: unknown event kind
        self.log.emit("request", method="GET",
                      pathname=path)  # LINE 15-16: off-schema key
