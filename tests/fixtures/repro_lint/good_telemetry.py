"""Telemetry-clean twin of bad_telemetry.py: bounded labels, schema'd events."""

_ROUTES = ("/predict", "/stats", "/metrics")


class Frontend:
    def __init__(self, registry, log):
        self._m_requests = registry.counter(
            "x_requests_total", "Requests", labelnames=("path",))
        self.log = log

    def observe(self, path, status, dur_ms):
        route = path if path in _ROUTES else "other"  # bounded vocabulary
        self._m_requests.inc(path=route)
        self.log.emit("request", method="GET", path=route, status=status,
                      dur_ms=dur_ms)
