"""Freeze-clean twin of bad_freeze.py: every update masked or gated."""
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.solvers.base import freeze


class _ToyState(NamedTuple):
    v: jnp.ndarray
    t: jnp.ndarray
    res: jnp.ndarray


def solve(active, s0):
    def body(s):
        v = s.v * 0.5
        res = jnp.abs(v).sum()
        return _ToyState(
            v=freeze(active, v, s.v),
            t=s.t + active.astype(jnp.int32),
            res=freeze(active, res, s.res),
        )

    return lax.while_loop(lambda s: jnp.any(s.t < 3), body, s0)
