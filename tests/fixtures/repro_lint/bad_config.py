"""Seeded config-discipline violations (exact lines asserted in tests)."""
from dataclasses import dataclass
from functools import partial

import jax

from repro.solvers.base import SolverNumerics


@dataclass(frozen=True)
class FrozenCfg:
    rank: int
    weights: jax.Array  # LINE 13: config-static-array


def cache_key(numerics: SolverNumerics):
    table = {numerics.tolerance: 1}  # LINE 17: config-static-traced
    return table, hash(numerics)  # LINE 18: config-static-traced


@partial(jax.jit, static_argnames=("numerics",))  # LINE 21: config-static-traced
def step(x, numerics: SolverNumerics):
    return x * numerics.learning_rate
