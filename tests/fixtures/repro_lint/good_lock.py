"""Lock-clean twin of bad_lock.py: guarded state behind its lock."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0  #: guarded by self._lock

    def tick(self):
        with self._lock:
            self.count += 1

    def _drain_locked(self):
        self.count = 0

    def reset(self):
        with self._lock:
            self._drain_locked()

    def snapshot(self):
        with self._lock:
            return self.count


class Handler:
    def __init__(self, worker):
        self.worker = worker

    def healthz(self):
        return {"count": self.worker.snapshot()}  # locked accessor
