"""Config-disciplined twin of bad_config.py: numerics stay traced."""
from dataclasses import dataclass
from functools import partial

import jax

from repro.solvers.base import SolverNumerics


@dataclass(frozen=True)
class FrozenCfg:
    rank: int
    tol_exponent: int  # scalars only: hashes stably into jit cache keys


def cache_key(cfg: FrozenCfg):
    return {cfg: 1}, hash(cfg)  # static config IS the cache key


@partial(jax.jit, static_argnames=("cfg",))
def step(x, numerics: SolverNumerics, cfg: FrozenCfg):
    del cfg
    return x * numerics.learning_rate  # numerics ride as a traced pytree
