"""Trace-safe twin of bad_trace.py: masks, no host syncs, clocks outside."""
import time

import jax.numpy as jnp
from jax import lax


def body(state):
    val = jnp.sin(state)
    val = jnp.where(val > 0, val + 1.0, val)
    return state + val


def run(n):
    t0 = time.time()  # host side: not reachable from a traced entry point
    out = lax.while_loop(lambda s: s < n, body, 0.0)
    return out, time.time() - t0
