"""Seeded trace-safety violations (exact lines asserted in tests)."""
import time

import jax.numpy as jnp
from jax import lax


def body(state):
    val = jnp.sin(state)
    if val > 0:  # LINE 10: trace-python-branch
        val = val + 1.0
    t0 = time.time()  # LINE 12: trace-impure-call
    x = float(val)  # LINE 13: trace-host-sync
    return state + x + t0


def run(n):
    return lax.while_loop(lambda s: s < n, body, 0.0)
