"""Seeded lock-discipline violations (exact lines asserted in tests)."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0  #: guarded by self._lock

    def tick(self):
        self.count += 1  # LINE 11: lock-discipline (no lock held)

    def _drain_locked(self):
        self.count = 0

    def reset(self):
        self._drain_locked()  # LINE 17: lock-discipline (_locked outside lock)

    def snapshot(self):
        with self._lock:
            return self.count


class Handler:
    def __init__(self, worker):
        self.worker = worker

    def healthz(self):
        return {"count": self.worker.count}  # LINE 29: lock-discipline
