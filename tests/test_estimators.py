"""Estimator theory: initial-distance (eqs. 12-15), variance equality
(App. A.1), and gradient-estimate accuracy for both estimators."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    PATHWISE,
    STANDARD,
    build_system_targets,
    init_probes,
    mll_grad_estimate,
    probe_targets,
)
from repro.core.gradients import exact_grad_reference


def test_initial_distance_theory(gp_problem):
    """E||0 - u||_H^2 = tr(H^-1) (standard, eq.14) vs n (pathwise, eq.15)."""
    x, params, h = gp_problem["x"], gp_problem["params"], gp_problem["h"]
    n, d = x.shape
    h_inv = jnp.linalg.inv(h)
    s = 512

    def mean_sqdist(est):
        probes = init_probes(jax.random.PRNGKey(3), est, n, d, s, 2000)
        b = probe_targets(probes, x, params)  # (n, s)
        u = h_inv @ b
        return float(jnp.mean(jnp.sum(u * (h @ u), axis=0)))

    std = mean_sqdist(STANDARD)
    path = mean_sqdist(PATHWISE)
    tr = float(jnp.trace(h_inv))
    assert abs(std - tr) / tr < 0.15
    assert abs(path - n) / n < 0.15
    # the paper's point: pathwise distance is smaller when noise precision
    # is high; with sigma=0.3, tr(H^-1) >> n is expected here
    assert std > path


def test_pathwise_probe_covariance(gp_problem):
    """xi ~ N(0, H): empirical second moment of the targets matches H."""
    x, params, h = gp_problem["x"], gp_problem["params"], gp_problem["h"]
    n, d = x.shape
    probes = init_probes(jax.random.PRNGKey(5), PATHWISE, n, d, 4096, 4000)
    xi = probe_targets(probes, x, params)
    emp = (xi @ xi.T) / xi.shape[1]
    err = jnp.max(jnp.abs(emp - h)) / jnp.max(jnp.abs(h))
    assert float(err) < 0.2


def test_variance_equality_noise_derivative(gp_problem):
    """A.1: for dH/dsigma = 2 sigma I (commutes with H^-1), both estimators
    have the SAME variance; empirical check."""
    x, params, h = gp_problem["x"], gp_problem["params"], gp_problem["h"]
    n = x.shape[0]
    h_inv = jnp.linalg.inv(h)
    key = jax.random.PRNGKey(11)
    m = 4000
    # standard: z^T H^-1 (2 sigma I) z
    z = jax.random.normal(key, (n, m))
    sigma = params.noise
    q_std = 2 * sigma * jnp.sum(z * (h_inv @ z), axis=0)
    # pathwise: zhat^T (2 sigma I) zhat with zhat ~ N(0, H^-1)
    l = jnp.linalg.cholesky(h_inv + 1e-9 * jnp.eye(n))
    zh = l @ jax.random.normal(jax.random.PRNGKey(12), (n, m))
    q_path = 2 * sigma * jnp.sum(zh * zh, axis=0)
    v1, v2 = float(jnp.var(q_std)), float(jnp.var(q_path))
    assert abs(v1 - v2) / max(v1, v2) < 0.2
    # means agree with the exact trace
    tr = float(2 * sigma * jnp.trace(h_inv))
    assert abs(float(jnp.mean(q_std)) - tr) / abs(tr) < 0.1
    assert abs(float(jnp.mean(q_path)) - tr) / abs(tr) < 0.1


@pytest.mark.parametrize("est", [STANDARD, PATHWISE])
def test_gradient_estimate_matches_exact(gp_problem, est):
    """With exact inner solves and many probes, the stochastic gradient
    approaches the exact Cholesky gradient (eq. 5)."""
    x, y, params, h = (gp_problem["x"], gp_problem["y"], gp_problem["params"],
                       gp_problem["h"])
    n, d = x.shape
    probes = init_probes(jax.random.PRNGKey(3), est, n, d, 512, 4000)
    targets = build_system_targets(probes, x, y, params)
    v = jnp.linalg.solve(h, targets)
    g, aux = mll_grad_estimate(x, y, params, v, targets, est, bm=64, bn=64)
    g_exact = exact_grad_reference(x, y, params)
    # Global relative error (per-leaf is dominated by MC noise on the
    # small-magnitude leaves; unbiasedness is tested separately).
    ga = jnp.concatenate([q.reshape(-1) for q in jax.tree.leaves(g)])
    gb = jnp.concatenate([q.reshape(-1) for q in jax.tree.leaves(g_exact)])
    rel = float(jnp.linalg.norm(ga - gb) / jnp.linalg.norm(gb))
    assert rel < 0.15, rel


def test_grad_estimate_unbiased_over_draws(gp_problem):
    """Standard estimator is unbiased: average over independent probe draws
    converges to the exact gradient."""
    x, y, params, h = (gp_problem["x"], gp_problem["y"], gp_problem["params"],
                       gp_problem["h"])
    n, d = x.shape
    g_exact = jnp.concatenate([
        v.reshape(-1) for v in jax.tree.leaves(exact_grad_reference(x, y, params))
    ])
    acc = 0.0
    reps = 24
    for i in range(reps):
        probes = init_probes(jax.random.PRNGKey(100 + i), STANDARD, n, d, 16)
        targets = build_system_targets(probes, x, y, params)
        v = jnp.linalg.solve(h, targets)
        g, _ = mll_grad_estimate(x, y, params, v, targets, STANDARD,
                                 bm=64, bn=64)
        acc = acc + jnp.concatenate([q.reshape(-1) for q in jax.tree.leaves(g)])
    mean = acc / reps
    rel = float(jnp.linalg.norm(mean - g_exact) / jnp.linalg.norm(g_exact))
    assert rel < 0.1
