"""Fault tolerance: atomic checkpoint/restore, resume determinism,
retention GC, and elastic re-sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OuterConfig, init_outer_state, outer_step
from repro.data.synthetic import make_gp_regression
from repro.distributed import (
    latest_step,
    restore_checkpoint,
    reshard,
    row_sharded_builder,
    save_checkpoint,
)
from repro.solvers import SolverConfig


@pytest.fixture(scope="module")
def small_fit():
    x, y = make_gp_regression(jax.random.PRNGKey(0), 128, 2)
    cfg = OuterConfig(num_probes=4, num_rff_pairs=64,
                      solver=SolverConfig(name="cg", max_epochs=50,
                                          precond_rank=0),
                      num_steps=4, bm=64, bn=64)
    return x, y, cfg


def test_save_restore_resume_identical(small_fit, tmp_path):
    x, y, cfg = small_fit
    st = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    st, _ = outer_step(st, x, y, cfg)
    st, _ = outer_step(st, x, y, cfg)
    save_checkpoint(str(tmp_path), 2, st)
    st2, step = restore_checkpoint(
        str(tmp_path), init_outer_state(jax.random.PRNGKey(1), cfg, x)
    )
    assert step == 2
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # training continues identically from the restored state — the warm
    # start carry survives restart (the paper's amortisation as FT)
    a1, _ = outer_step(st, x, y, cfg)
    a2, _ = outer_step(st2, x, y, cfg)
    np.testing.assert_allclose(
        np.asarray(a1.params.raw_lengthscales),
        np.asarray(a2.params.raw_lengthscales), rtol=1e-6,
    )
    np.testing.assert_allclose(np.asarray(a1.carry_v), np.asarray(a2.carry_v),
                               rtol=1e-6)


def test_atomicity_no_partial_files(small_fit, tmp_path):
    x, y, cfg = small_fit
    st = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    save_checkpoint(str(tmp_path), 1, st)
    names = os.listdir(tmp_path)
    assert not any(n.startswith("tmp.") for n in names)
    assert "step_1.npz" in names and "step_1.json" in names


def test_retention_gc(small_fit, tmp_path):
    x, y, cfg = small_fit
    st = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for i in range(1, 7):
        save_checkpoint(str(tmp_path), i, st, keep=3)
    steps = sorted(
        int(n.split("_")[1].split(".")[0])
        for n in os.listdir(tmp_path) if n.endswith(".npz")
    )
    assert steps == [4, 5, 6]
    assert latest_step(str(tmp_path)) == 6


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(3)})


def test_elastic_reshard_roundtrip(small_fit):
    """Restore-then-reshard onto the local mesh: values unchanged."""
    x, y, cfg = small_fit
    st = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    st2 = reshard(st, mesh, row_sharded_builder(axes=("data",)))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0)
