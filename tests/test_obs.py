"""Observability layer: metrics registry + Prometheus rendering, trace
IDs/event log/spans, solver residual ring buffers (including vmap lane
parity), and end-to-end trace propagation through a 2-replica cluster."""
import io
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gp.hyperparams import HyperParams
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry, NullRegistry
from repro.solvers import (
    HOperator,
    SolverConfig,
    solve,
    solve_lanes,
)
from repro.solvers.base import history_init, history_record, unroll_history


# -- metrics ------------------------------------------------------------------
def test_counter_gauge_histogram_render():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "Requests", labelnames=("path",))
    c.inc(path="/a")
    c.inc(2.0, path="/a")
    c.inc(path="/b")
    g = reg.gauge("depth", "Queue depth")
    g.set(7)
    h = reg.histogram("lat_seconds", "Latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.render()
    assert 'req_total{path="/a"} 3' in text
    assert 'req_total{path="/b"} 1' in text
    assert "# TYPE req_total counter" in text
    assert "depth 7" in text
    # Cumulative buckets + +Inf + sum/count.
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text


def test_prometheus_label_escaping():
    """Backslash, quote and newline in label values per the 0.0.4 spec."""
    reg = MetricsRegistry()
    c = reg.counter("esc_total", 'help with "quotes"\nand newline',
                    labelnames=("path",))
    c.inc(path='/pre"dict\n\\x')
    text = reg.render()
    assert r'esc_total{path="/pre\"dict\n\\x"} 1' in text
    # HELP escapes backslash and newline (quotes stay raw).
    assert '# HELP esc_total help with "quotes"\\nand newline' in text
    parsed = [l for l in text.splitlines() if not l.startswith("#")]
    assert all("\n" not in l for l in parsed)


def test_registry_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x")
    assert reg.counter("x_total", "x") is a
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", labelnames=("k",))


def test_null_registry_is_inert():
    reg = NullRegistry()
    reg.counter("a_total", "a").inc()
    reg.gauge("b", "b").set(1.0)
    reg.histogram("c", "c").observe(0.5)
    assert reg.render() == ""


# -- trace / event log --------------------------------------------------------
def test_sanitize_trace_id():
    assert obs_trace.sanitize_trace_id("abc-123.X_9") == "abc-123.X_9"
    assert obs_trace.sanitize_trace_id("  ok42  ") == "ok42"
    for bad in (None, "", "has space", "semi;colon", "a" * 200,
                "-leadingdash", 'inj"ect\n'):
        assert obs_trace.sanitize_trace_id(bad) is None


def test_event_log_and_span_carry_trace_id():
    buf = io.StringIO()
    log = obs_trace.EventLog(stream=buf)
    with obs_trace.trace_context("t-1") as tid:
        assert tid == "t-1" and obs_trace.current_trace_id() == "t-1"
        log.emit("thing", value=3)
        with pytest.raises(RuntimeError):
            with obs_trace.span("work", log=log, rows=4):
                raise RuntimeError("boom")
    assert obs_trace.current_trace_id() is None
    events = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert [e["kind"] for e in events] == ["thing", "span"]
    assert all(e["trace_id"] == "t-1" for e in events)
    sp = events[1]
    assert sp["span"] == "work" and sp["error"] == "RuntimeError"
    assert sp["dur_ms"] >= 0 and sp["rows"] == 4
    assert log.events_written == 2


def test_module_emit_noop_until_configured(tmp_path):
    obs_trace.configure()  # ensure cleared
    assert obs_trace.emit("ignored") is None
    path = str(tmp_path / "log" / "events-{pid}.jsonl")
    obs_trace.configure(path=path)
    try:
        obs_trace.emit("hello", n=1)
        expanded = path.replace("{pid}", str(os.getpid()))
        (ev,) = [json.loads(l) for l in open(expanded)]
        assert ev["kind"] == "hello" and ev["n"] == 1
    finally:
        obs_trace.configure()
    assert obs_trace.emit("ignored") is None


# -- solver residual rings ----------------------------------------------------
def _toy_system(n=96, d=2, t=3, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, t))
    params = HyperParams.create(d, lengthscale=1.2, signal=1.0, noise=0.3)
    op = HOperator(x=x, params=params, bm=64, bn=64)
    return x, b, params, op


@pytest.mark.parametrize("name", ["cg", "ap", "sgd"])
def test_ring_buffer_matches_final_residuals(name):
    _, b, _, op = _toy_system()
    cfg = SolverConfig(name=name, max_epochs=8, precond_rank=0,
                       block_size=32, batch_size=32, tolerance=1e-8,
                       record_history=16)
    res = solve(op, b, None, cfg, key=jax.random.PRNGKey(2))
    assert res.res_history is not None and res.res_history.shape == (16, 2)
    iters = int(res.iters)
    assert iters >= 1
    hist = np.asarray(res.res_history)
    # Slot (iters-1) % H holds the residuals after the last iteration —
    # exactly the SolveResult's reported residuals.
    last = hist[(iters - 1) % 16]
    np.testing.assert_allclose(last, [float(res.res_y), float(res.res_z)],
                               rtol=1e-6)
    # Unwritten slots stay NaN.
    written = np.isfinite(hist[:, 0]).sum()
    assert written == min(iters, 16)

    # Off path: no history, identical solution bits.
    cfg_off = SolverConfig(name=name, max_epochs=8, precond_rank=0,
                           block_size=32, batch_size=32, tolerance=1e-8)
    res_off = solve(op, b, None, cfg_off, key=jax.random.PRNGKey(2))
    assert res_off.res_history is None
    np.testing.assert_array_equal(np.asarray(res.v), np.asarray(res_off.v))


@pytest.mark.parametrize("name", ["cg", "ap", "sgd"])
def test_ring_buffer_vmap_lane_parity(name):
    """Each lane of a vmapped solve records the same residual trajectory as
    its own single-lane solve — the freeze mask must stop a converged
    lane's ring exactly where the single solve stops."""
    lanes = 3
    x, _, _, _ = _toy_system()
    b = jax.random.normal(jax.random.PRNGKey(7), (lanes, 96, 3))
    # Distinct hypers per lane => distinct convergence points.
    stacked = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls),
        *[HyperParams.create(2, lengthscale=0.8 + 0.4 * i, signal=1.0,
                             noise=0.2 + 0.1 * i) for i in range(lanes)])
    cfg = SolverConfig(name=name, max_epochs=6, precond_rank=0,
                       block_size=32, batch_size=32, tolerance=1e-8,
                       record_history=8)
    keys = jax.random.split(jax.random.PRNGKey(3), lanes)
    lane_res = solve_lanes(x, stacked, b, None, cfg, bm=64, bn=64, keys=keys)
    assert lane_res.res_history.shape == (lanes, 8, 2)
    for i in range(lanes):
        p = jax.tree_util.tree_map(lambda l: l[i], stacked)
        op = HOperator(x=x, params=p, bm=64, bn=64)
        single = solve(op, b[i], None, cfg, key=keys[i])
        assert int(single.iters) == int(lane_res.iters[i])
        got = np.asarray(lane_res.res_history[i])
        want = np.asarray(single.res_history)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-7)


def test_unroll_history_wraps_and_stacks():
    hist = history_init(SolverConfig(name="cg", record_history=4),
                        dtype=jnp.float32)
    active = jnp.asarray(True)
    for t in range(6):  # 6 writes into 4 slots: wraps, keeps last 4
        hist = history_record(hist, jnp.asarray(t), jnp.asarray(float(10 + t)),
                              jnp.asarray(float(20 + t)), active)
    rolled = unroll_history(np.asarray(hist), 6)
    np.testing.assert_allclose(rolled[:, 0], [12, 13, 14, 15])
    np.testing.assert_allclose(rolled[:, 1], [22, 23, 24, 25])
    # Fewer writes than slots: time order with NaN tail.
    h2 = history_init(SolverConfig(name="cg", record_history=4))
    h2 = history_record(h2, jnp.asarray(0), jnp.asarray(1.0), jnp.asarray(2.0),
                        active)
    r2 = unroll_history(np.asarray(h2), 1)
    assert r2[0, 0] == 1.0 and np.isnan(r2[1:, 0]).all()
    # Lane-stacked rings unroll per lane.
    stacked = np.stack([np.asarray(hist), np.asarray(hist)])
    rs = unroll_history(stacked, 6)
    assert rs.shape == (2, 4, 2)
    np.testing.assert_allclose(rs[1, :, 0], [12, 13, 14, 15])
    # record_history=0 => no ring at all.
    assert history_init(SolverConfig(name="cg")) is None
    assert history_record(None, jnp.asarray(0), jnp.asarray(1.0),
                          jnp.asarray(1.0), active) is None


# -- end-to-end: trace propagation through a 2-replica cluster ---------------
@pytest.mark.slow
def test_trace_propagates_through_two_replica_cluster(tmp_path):
    """One X-Trace-Id, sent by the client, must surface in the serving
    replica's own request log as the SAME id on the request event, the
    admission event, and the engine.submit span — and come back on the
    response header. Each replica writes its own log file."""
    from repro.core import OuterConfig, init_outer_state, outer_step
    from repro.data.synthetic import make_gp_regression
    from repro.serve import export_servable
    from repro.serve.cluster import ReplicaSupervisor, publish_servable

    x, y = make_gp_regression(jax.random.PRNGKey(0), 160, 2, noise=0.2)
    xq = x[128:132]
    x, y = x[:128], y[:128]
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=8, num_rff_pairs=64,
        solver=SolverConfig(name="cg", max_epochs=200, precond_rank=0),
        num_steps=2, bm=64, bn=64,
    )
    state = init_outer_state(jax.random.PRNGKey(1), cfg, x)
    for _ in range(cfg.num_steps):
        state, _ = outer_step(state, x, y, cfg)
    model = export_servable(state, x)

    store = str(tmp_path / "store")
    log_dir = str(tmp_path / "logs")
    publish_servable(store, model)
    sup = ReplicaSupervisor(store, num_replicas=2, buckets=(8, 32),
                            bm=64, bn=64, poll_interval_s=0.5,
                            request_log_dir=log_dir)
    import urllib.request

    payload = json.dumps({"x": np.asarray(xq).tolist()}).encode()
    try:
        urls = sup.start(timeout_s=240)
        tids = {}
        for i, url in enumerate(urls):
            tid = f"e2e-trace-{i}"
            req = urllib.request.Request(
                url + "/predict", data=payload,
                headers={"Content-Type": "application/json",
                         obs_trace.TRACE_HEADER: tid})
            with urllib.request.urlopen(req, timeout=60) as resp:
                assert resp.status == 200
                assert resp.headers.get(obs_trace.TRACE_HEADER) == tid
            tids[i] = tid

        # Each replica's own log holds its request's full path. emit()
        # flushes per line, so the events are visible while workers run.
        for i in range(2):
            log_path = os.path.join(log_dir, f"replica_{i}.jsonl")
            deadline = time.monotonic() + 30
            by_kind = {}
            while time.monotonic() < deadline:
                events = []
                if os.path.exists(log_path):
                    with open(log_path) as f:
                        for line in f:
                            try:
                                events.append(json.loads(line))
                            except json.JSONDecodeError:
                                pass
                mine = [e for e in events if e.get("trace_id") == tids[i]]
                by_kind = {}
                for e in mine:
                    by_kind.setdefault(e["kind"], []).append(e)
                if {"request", "admission", "span"} <= set(by_kind):
                    break
                time.sleep(0.3)
            assert {"request", "admission", "span"} <= set(by_kind), (
                i, sorted(by_kind))
            req_ev = by_kind["request"][0]
            assert req_ev["path"] == "/predict" and req_ev["status"] == 200
            assert by_kind["admission"][0]["outcome"] == "admitted"
            assert any(e.get("span") == "engine.submit"
                       for e in by_kind["span"])
            # The OTHER replica's trace must not leak into this log.
            other = tids[1 - i]
            assert not [e for e in events if e.get("trace_id") == other]
    finally:
        sup.stop()
