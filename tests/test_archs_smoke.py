"""Per-architecture smoke tests (reduced same-family configs, CPU):
one train step + one decode step, output shapes, no NaNs — plus the
train-vs-decode parity invariant that validates caches/masks/recurrences.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # one train+decode step per LM arch; minutes on CPU

from repro.configs import LM_ARCHS, SMOKE_SHAPES, get_config
from repro.models import (
    concrete_batch,
    decode_step,
    forward_encdec,
    forward_lm,
    init_cache,
    init_params,
    make_serve_step,
    make_train_step,
)
from repro.models.transformer import prefill_cross_cache
from repro.train.adam import adam_init

DECODER_ONLY = [a for a in LM_ARCHS if a != "whisper-large-v3"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    shape = SMOKE_SHAPES["train_4k"]
    batch = concrete_batch(cfg, shape, jax.random.PRNGKey(1))["batch"]
    step = jax.jit(make_train_step(cfg, num_microbatches=2))
    p2, o2, loss = step(params, adam_init(params), batch)
    assert np.isfinite(float(loss))
    # params actually changed
    delta = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params))
    )
    assert delta > 0
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    shape = SMOKE_SHAPES["decode_32k"]
    enc_len = 32 if cfg.is_encdec else 0
    cache = init_cache(cfg, shape.global_batch, shape.seq_len, enc_len=enc_len)
    step = jax.jit(make_serve_step(cfg))
    toks = jnp.zeros((shape.global_batch,), jnp.int32)
    logits, cache2 = step(params, cache, toks, jnp.asarray(3, jnp.int32))
    assert logits.shape == (shape.global_batch, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", DECODER_ONLY)
def test_decode_matches_train_forward(arch):
    """Token-by-token decode reproduces the train forward logits (fp32)."""
    T = 48
    cfg = get_config(arch, smoke=True)
    rep = {"compute_dtype": "float32"}
    if cfg.moe is not None:  # disable capacity drops for exact parity
        rep["moe"] = dataclasses.replace(
            cfg.moe,
            capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k,
        )
    cfg = dataclasses.replace(cfg, **rep)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)
    ref = forward_lm(params, cfg, toks)
    cache = init_cache(cfg, 2, T, dtype=jnp.float32)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    errs = []
    for t in range(T):
        logits, cache = step(cache, toks[:, t], jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(
            logits - ref[:, t, :].astype(jnp.float32)))))
    assert max(errs) < 1e-3, (arch, max(errs))


def test_whisper_encdec_decode_parity():
    cfg = get_config("whisper-large-v3", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, T_enc, T_dec = 2, 32, 12
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, T_enc, cfg.d_model)) * 0.3
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T_dec), 0,
                              cfg.vocab_size)
    ref = forward_encdec(params, cfg, frames, toks)
    cache = init_cache(cfg, B, T_dec, enc_len=T_enc, dtype=jnp.float32)
    cache = prefill_cross_cache(params, cfg, frames, cache)
    errs = []
    for t in range(T_dec):
        logits, cache = decode_step(params, cfg, cache, toks[:, t],
                                    jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(
            logits - ref[:, t, :].astype(jnp.float32)))))
    assert max(errs) < 1e-3


def test_vocab_padding_internvl():
    """internvl2 smoke has an odd vocab (517) — padded logits must mask out
    the phantom ids only via the loss; embedding rows exist."""
    cfg = get_config("internvl2-2b", smoke=True)
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert params["embed"].shape[0] == cfg.padded_vocab


def test_long_500k_skip_rule():
    """DESIGN §5: pure full-attention archs skip long_500k; SSM/hybrid/
    windowed archs run it."""
    from repro.configs import runnable_cells

    cells = {(a, s): st for a, s, st in runnable_cells(include_skips=True)}
    assert cells[("mamba2-780m", "long_500k")] == "run"
    assert cells[("jamba-v0.1-52b", "long_500k")] == "run"
    assert cells[("gemma3-4b", "long_500k")] == "run"
    assert cells[("mixtral-8x22b", "long_500k")] == "run"
    assert cells[("llama4-scout-17b-a16e", "long_500k")] == "run"
    for a in ("llama3-8b", "qwen2.5-3b", "starcoder2-3b", "whisper-large-v3",
              "internvl2-2b"):
        assert cells[(a, "long_500k")] == "skip"


def test_published_config_dimensions():
    """Spot-check the exact published dims made it into the configs."""
    c = get_config("mixtral-8x22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (56, 6144, 48, 8, 16384, 32768)
    assert c.moe.num_experts == 8 and c.moe.top_k == 2
    c = get_config("llama3-8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 4096, 32, 8, 14336, 128256)
    c = get_config("gemma3-4b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (34, 2560, 8, 4, 10240, 262144)
    c = get_config("mamba2-780m")
    assert c.ssm.d_state == 128 and c.d_ff == 0 and c.num_layers == 48
    c = get_config("jamba-v0.1-52b")
    kinds = [s.kind for s in c.pattern]
    assert kinds.count("full") == 1 and kinds.count("mamba") == 7
    assert c.moe.num_experts == 16 and c.moe.top_k == 2
