"""Solver correctness: CG / AP / SGD against the dense Cholesky solution,
warm-start behaviour, budget accounting, and the termination rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gp.hyperparams import HyperParams
from repro.solvers import HOperator, SolverConfig, solve

TOL = 0.005


def _op(gp, backend="streamed"):
    return HOperator(x=gp["x"], params=gp["params"], backend=backend,
                     bm=64, bn=64)


@pytest.mark.parametrize(
    "name,kw",
    [
        ("cg", dict(precond_rank=20)),
        ("cg", dict(precond_rank=0)),
        ("ap", dict(block_size=32)),
        ("sgd", dict(batch_size=32, learning_rate=2.0)),
    ],
)
def test_solver_reaches_tolerance(gp_problem, batched_system, name, kw):
    cfg = SolverConfig(name=name, tolerance=TOL, max_epochs=3000, **kw)
    res = solve(_op(gp_problem), batched_system["b"], None, cfg,
                key=jax.random.PRNGKey(1))
    assert float(res.res_y) <= TOL * 1.01
    assert float(res.res_z) <= TOL * 1.01
    # solution must actually solve the system (residual, not just estimate)
    r = batched_system["b"] - _op(gp_problem).mvm(res.v)
    rel = jnp.linalg.norm(r, axis=0) / jnp.linalg.norm(batched_system["b"], axis=0)
    assert float(jnp.max(rel)) < 0.05


@pytest.mark.parametrize("name,kw", [
    ("cg", dict(precond_rank=20)),
    ("ap", dict(block_size=32)),
])
def test_warm_start_reduces_iterations(gp_problem, batched_system, name, kw):
    """Paper §4: initialising at a nearby solution cuts solver iterations."""
    cfg = SolverConfig(name=name, tolerance=TOL, max_epochs=3000, **kw)
    op = _op(gp_problem)
    cold = solve(op, batched_system["b"], None, cfg, key=jax.random.PRNGKey(1))
    # warm start at the exact solution mildly perturbed
    v0 = batched_system["v_true"] * (1.0 + 1e-3)
    warm = solve(op, batched_system["b"], v0, cfg, key=jax.random.PRNGKey(1))
    assert int(warm.iters) < int(cold.iters)


def test_budget_accounting_epochs(gp_problem, batched_system):
    """1 CG iter = 1 epoch; AP/SGD iter = block/n epochs (paper §5 fn.3)."""
    op = _op(gp_problem)
    n = gp_problem["n"]
    cfg = SolverConfig(name="cg", tolerance=0.0, max_epochs=7, precond_rank=0)
    res = solve(op, batched_system["b"], None, cfg)
    assert int(res.iters) == 7 and float(res.epochs) == 7.0

    cfg = SolverConfig(name="ap", tolerance=0.0, max_epochs=2, block_size=32)
    res = solve(op, batched_system["b"], None, cfg)
    assert int(res.iters) == 2 * n // 32
    assert abs(float(res.epochs) - 2.0) < 1e-6

    cfg = SolverConfig(name="sgd", tolerance=0.0, max_epochs=2, batch_size=32,
                       learning_rate=1.0)
    res = solve(op, batched_system["b"], None, cfg, key=jax.random.PRNGKey(0))
    assert int(res.iters) == 2 * n // 32


def test_early_stopping_respects_budget_and_warm_start_accumulates(
    gp_problem, batched_system
):
    """Paper §5: with a tiny budget the solver stops early; carrying the
    result as the next call's init accumulates progress."""
    op = _op(gp_problem)
    cfg = SolverConfig(name="ap", tolerance=TOL, max_epochs=1, block_size=32)
    res1 = solve(op, batched_system["b"], None, cfg)
    assert float(res1.res_z) > TOL  # budget hit first
    res2 = solve(op, batched_system["b"], res1.v, cfg)
    res3 = solve(op, batched_system["b"], res2.v, cfg)
    assert float(res2.res_z) < float(res1.res_z)
    assert float(res3.res_z) < float(res2.res_z)


def test_pallas_backend_matches_streamed(gp_problem, batched_system):
    """Both backends must solve the SAME system to the same tolerance; the
    iterates may differ at fp32 rounding scale (CG paths diverge slightly),
    so compare residuals of each solution, not iterates elementwise."""
    cfg = SolverConfig(name="cg", tolerance=TOL, max_epochs=100, precond_rank=0)
    op = _op(gp_problem, "streamed")
    r1 = solve(op, batched_system["b"], None, cfg)
    r2 = solve(_op(gp_problem, "pallas"), batched_system["b"], None, cfg)
    bnorm = jnp.linalg.norm(batched_system["b"], axis=0)
    for res in (r1, r2):
        rel = jnp.linalg.norm(batched_system["b"] - op.mvm(res.v), axis=0) / bnorm
        assert float(jnp.max(rel)) < 5 * TOL
    np.testing.assert_allclose(np.asarray(r1.v), np.asarray(r2.v),
                               rtol=5e-2, atol=1e-2)


@pytest.mark.parametrize("kind", ["rbf", "matern12", "matern32", "matern52"])
def test_per_kernel_precond_defaults_parity(kind):
    """AUTO_RANK resolves the per-kernel rank/jitter table. Parity contract
    on the synthetic suite: the per-kernel default must still reach tolerance
    and keep preconditioning effective (>= 2x fewer CG iterations than no
    preconditioner), while its rank — the O(n k (d + k)) setup cost — never
    exceeds the flat Matérn-calibrated 100 it replaces."""
    from repro.data.synthetic import make_gp_regression
    from repro.solvers import AUTO_RANK, PRECOND_DEFAULTS, default_precond

    x, y = make_gp_regression(jax.random.PRNGKey(3), 192, 2, noise=0.3)
    params = HyperParams.create(2, lengthscale=0.8, signal=1.0, noise=0.3,
                                kernel=kind)
    op = HOperator(x=x, params=params, bm=64, bn=64)
    b = jnp.concatenate(
        [y[:, None], jax.random.normal(jax.random.PRNGKey(4), (192, 4))],
        axis=1,
    )
    iters = {}
    for rank in (0, AUTO_RANK):
        cfg = SolverConfig(name="cg", tolerance=TOL, max_epochs=3000,
                           precond_rank=rank)
        res = solve(op, b, None, cfg)
        assert float(res.res_y) <= TOL * 1.01
        iters[rank] = int(res.iters)
    assert 2 * iters[AUTO_RANK] <= iters[0], iters
    # setup-cost parity vs. the flat default, and eigendecay ordering:
    # smoother kernels (faster spectral decay) get smaller default ranks
    assert default_precond(kind).rank <= 150
    ranks = {k: v.rank for k, v in PRECOND_DEFAULTS.items()}
    assert (ranks["rbf"] < ranks["matern52"] < ranks["matern32"]
            <= ranks["matern12"])


def test_pivoted_cholesky_preconditioner_quality(gp_problem):
    """P^-1 H should be much better conditioned than H."""
    from repro.solvers.precond import build_preconditioner

    op = _op(gp_problem)
    pre = build_preconditioner(op, 50)
    h = gp_problem["h"]
    ph = pre.apply(h)  # P^-1 H
    ev = np.linalg.eigvals(np.asarray(ph)).real
    cond_pre = ev.max() / ev.min()
    ev_h = np.linalg.eigvalsh(np.asarray(h))
    cond_h = ev_h.max() / ev_h.min()
    assert cond_pre < cond_h / 5.0
