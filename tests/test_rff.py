"""RFF prior machinery: kernel approximation, Matérn-3/2 spectral sampling,
and the deterministic warm-start reparameterisation contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import kernel_matrix
from repro.gp.rff import init_rff, prior_sample_at, rff_features


def test_rff_covariance_approximates_matern():
    d = 3
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (40, d))
    p = HyperParams.create(d, lengthscale=0.8, signal=1.2)
    st = init_rff(jax.random.PRNGKey(1), 8000, d, 1)
    phi = rff_features(x, st, p)
    k_hat = phi @ phi.T
    k = kernel_matrix(x, x, p)
    assert float(jnp.max(jnp.abs(k_hat - k))) < 0.08 * float(p.signal) ** 2


def test_rff_covariance_rbf():
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (30, d))
    p = HyperParams.create(d)
    st = init_rff(jax.random.PRNGKey(1), 8000, d, 1, kind="rbf")
    phi = rff_features(x, st, p)
    k = kernel_matrix(x, x, p, kind="rbf")
    assert float(jnp.max(jnp.abs(phi @ phi.T - k))) < 0.08


def test_prior_sample_moments():
    """f(x) = phi(x) w has E[f]=0 and Cov ~ K."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    p = HyperParams.create(d)
    st = init_rff(jax.random.PRNGKey(1), 2000, d, 4096)
    f = prior_sample_at(x, st, p)  # (16, 4096)
    assert float(jnp.max(jnp.abs(jnp.mean(f, axis=1)))) < 0.1
    emp = (f @ f.T) / f.shape[1]
    k = kernel_matrix(x, x, p)
    assert float(jnp.max(jnp.abs(emp - k))) < 0.25


def test_lengthscale_reparameterisation_deterministic():
    """Fixed base draws: targets change smoothly and deterministically with
    theta (Appendix B: 'selecting a particular instance of a prior sample')."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    st = init_rff(jax.random.PRNGKey(1), 128, d, 2)
    p1 = HyperParams.create(d, lengthscale=1.0)
    p2 = HyperParams.create(d, lengthscale=1.0)
    f1 = prior_sample_at(x, st, p1)
    f2 = prior_sample_at(x, st, p2)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    p3 = HyperParams.create(d, lengthscale=1.01)
    f3 = prior_sample_at(x, st, p3)
    assert 0 < float(jnp.max(jnp.abs(f3 - f1))) < 0.5


def test_matern_frequency_tails_heavier_than_gaussian():
    """Matérn-3/2 spectral density is a t_3 — heavier tails than RBF."""
    d = 1
    st_m = init_rff(jax.random.PRNGKey(3), 20000, d, 1, kind="matern32")
    st_g = init_rff(jax.random.PRNGKey(3), 20000, d, 1, kind="rbf")
    p = HyperParams.create(d)
    from repro.gp.rff import rff_frequencies

    om = np.abs(np.asarray(rff_frequencies(st_m, p)))[:, 0]
    og = np.abs(np.asarray(rff_frequencies(st_g, p)))[:, 0]
    assert np.quantile(om, 0.99) > 2.0 * np.quantile(og, 0.99)
