"""RFF prior machinery: kernel approximation, Matérn-3/2 spectral sampling,
and the deterministic warm-start reparameterisation contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import kernel_matrix
from repro.gp.rff import init_rff, prior_sample_at, rff_features


def test_rff_covariance_approximates_matern():
    d = 3
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (40, d))
    p = HyperParams.create(d, lengthscale=0.8, signal=1.2)
    st = init_rff(jax.random.PRNGKey(1), 8000, d, 1)
    phi = rff_features(x, st, p)
    k_hat = phi @ phi.T
    k = kernel_matrix(x, x, p)
    assert float(jnp.max(jnp.abs(k_hat - k))) < 0.08 * float(p.signal) ** 2


def test_rff_covariance_rbf():
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (30, d))
    p = HyperParams.create(d)
    st = init_rff(jax.random.PRNGKey(1), 8000, d, 1, kind="rbf")
    phi = rff_features(x, st, p)
    k = kernel_matrix(x, x, p, kind="rbf")
    assert float(jnp.max(jnp.abs(phi @ phi.T - k))) < 0.08


def test_prior_sample_moments():
    """f(x) = phi(x) w has E[f]=0 and Cov ~ K."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    p = HyperParams.create(d)
    st = init_rff(jax.random.PRNGKey(1), 2000, d, 4096)
    f = prior_sample_at(x, st, p)  # (16, 4096)
    assert float(jnp.max(jnp.abs(jnp.mean(f, axis=1)))) < 0.1
    emp = (f @ f.T) / f.shape[1]
    k = kernel_matrix(x, x, p)
    assert float(jnp.max(jnp.abs(emp - k))) < 0.25


def test_lengthscale_reparameterisation_deterministic():
    """Fixed base draws: targets change smoothly and deterministically with
    theta (Appendix B: 'selecting a particular instance of a prior sample')."""
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (16, d))
    st = init_rff(jax.random.PRNGKey(1), 128, d, 2)
    p1 = HyperParams.create(d, lengthscale=1.0)
    p2 = HyperParams.create(d, lengthscale=1.0)
    f1 = prior_sample_at(x, st, p1)
    f2 = prior_sample_at(x, st, p2)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    p3 = HyperParams.create(d, lengthscale=1.01)
    f3 = prior_sample_at(x, st, p3)
    assert 0 < float(jnp.max(jnp.abs(f3 - f1))) < 0.5


def test_m12_mixture_draws_are_stratified():
    """Matérn-1/2 mixture draws are stratified inverse-CDF (QMC): exactly
    one draw per probability stratum of the chi^2_1 law, every seed — the
    tail-coverage property iid Cauchy-spectrum sampling cannot give."""
    from jax.scipy.stats import norm

    from repro.kernels.registry import get_kernel

    m = 512
    for seed in (0, 1, 2):
        u = np.sort(np.asarray(
            get_kernel("matern12").mixture_sample(jax.random.PRNGKey(seed), m)
        ))
        # chi^2_1 CDF: F(u) = 2 Phi(sqrt(u)) - 1; draw i must land in
        # stratum (i/m, (i+1)/m).
        f = 2.0 * np.asarray(norm.cdf(jnp.sqrt(u))) - 1.0
        bins = np.floor(f * m).astype(int)
        np.testing.assert_array_equal(np.clip(bins, 0, m - 1), np.arange(m))
    # still random: different seeds jitter within strata
    u0 = get_kernel("matern12").mixture_sample(jax.random.PRNGKey(0), m)
    u1 = get_kernel("matern12").mixture_sample(jax.random.PRNGKey(1), m)
    assert float(jnp.max(jnp.abs(u0 - u1))) > 0
    # strictly positive and finite at every stratum: the two clamps guard
    # u -> 0 (infinite mixture scale) and the top stratum's (1+p)/2
    # rounding to 1.0 in f32 (ndtri -> inf).
    for seed in range(8):
        u = get_kernel("matern12").mixture_sample(
            jax.random.PRNGKey(seed), 4096)
        assert bool(jnp.all(jnp.isfinite(u))) and bool(jnp.all(u > 0))


def test_per_kernel_default_feature_counts():
    """init_rff resolves num_pairs=None / AUTO to the kernel's default; the
    Cauchy-tailed matern12 gets more features than the light-tailed rest."""
    from repro.gp.rff import AUTO_NUM_PAIRS, default_num_pairs

    assert default_num_pairs("matern12") > default_num_pairs("rbf")
    assert default_num_pairs("not-registered-yet") == 1000
    st = init_rff(jax.random.PRNGKey(0), None, 2, 1, kind="matern12")
    assert st.z.shape[0] == default_num_pairs("matern12")
    st = init_rff(jax.random.PRNGKey(0), AUTO_NUM_PAIRS, 2, 1, kind="rbf")
    assert st.z.shape[0] == default_num_pairs("rbf")
    st = init_rff(jax.random.PRNGKey(0), 64, 2, 1, kind="matern12")
    assert st.z.shape[0] == 64  # explicit counts still win
    # the production sweep path actually uses the per-kernel defaults
    from repro.configs.gp_iterative import KERNEL_SWEEP

    by_kind = {a.kind: a.num_rff_pairs for a in KERNEL_SWEEP}
    assert by_kind["matern12"] == default_num_pairs("matern12")
    assert by_kind["rbf"] == default_num_pairs("rbf")


def test_matern_frequency_tails_heavier_than_gaussian():
    """Matérn-3/2 spectral density is a t_3 — heavier tails than RBF."""
    d = 1
    st_m = init_rff(jax.random.PRNGKey(3), 20000, d, 1, kind="matern32")
    st_g = init_rff(jax.random.PRNGKey(3), 20000, d, 1, kind="rbf")
    p = HyperParams.create(d)
    from repro.gp.rff import rff_frequencies

    om = np.abs(np.asarray(rff_frequencies(st_m, p)))[:, 0]
    og = np.abs(np.asarray(rff_frequencies(st_g, p)))[:, 0]
    assert np.quantile(om, 0.99) > 2.0 * np.quantile(og, 0.99)
