"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dev dependency (listed in the ``dev`` extra): skip this module —
# instead of aborting the whole collection — when it is absent.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.gp.hyperparams import HyperParams, softplus, softplus_inverse
from repro.gp.kernels_math import (
    kernel_matrix,
    kernel_mvm_streamed,
    regularised_kernel_matrix,
    scaled_sqdist,
)

_settings = settings(max_examples=25, deadline=None)

dims = st.integers(min_value=1, max_value=6)
sizes = st.integers(min_value=2, max_value=40)
scales = st.floats(min_value=0.2, max_value=3.0)


@_settings
@given(sizes, dims, scales, st.integers(0, 2**31 - 1))
def test_kernel_matrix_symmetric_psd(n, d, ls, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    p = HyperParams.create(d, lengthscale=ls, noise=0.3)
    h = np.asarray(regularised_kernel_matrix(x, p))
    np.testing.assert_allclose(h, h.T, rtol=1e-5, atol=1e-5)
    ev = np.linalg.eigvalsh(h)
    assert ev.min() > 0.0  # positive definite (noise regularised)


@_settings
@given(sizes, dims, scales, st.integers(0, 2**31 - 1))
def test_kernel_diag_is_signal_sq_plus_noise(n, d, sig, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    p = HyperParams.create(d, signal=sig, noise=0.5)
    h = np.asarray(regularised_kernel_matrix(x, p))
    np.testing.assert_allclose(
        np.diag(h), sig**2 + 0.25, rtol=1e-4, atol=1e-4
    )


@_settings
@given(sizes, sizes, dims, st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_streamed_mvm_matches_dense(n, m, d, s, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x1 = jax.random.normal(k1, (n, d))
    x2 = jax.random.normal(k2, (m, d))
    v = jax.random.normal(k3, (m, s))
    p = HyperParams.create(d)
    out = kernel_mvm_streamed(x1, x2, v, p, block_rows=7)
    ref = kernel_matrix(x1, x2, p) @ v
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@_settings
@given(st.floats(min_value=1e-3, max_value=50.0))
def test_softplus_roundtrip(theta):
    nu = softplus_inverse(jnp.asarray(theta, jnp.float32))
    back = float(softplus(nu))
    assert abs(back - theta) / theta < 1e-4


@_settings
@given(sizes, dims, st.integers(0, 2**31 - 1))
def test_scaled_sqdist_nonneg_and_zero_diag(n, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    ls = jnp.ones((d,))
    r2 = np.asarray(scaled_sqdist(x, x, ls))
    assert (r2 >= 0).all()
    np.testing.assert_allclose(np.diag(r2), 0.0, atol=1e-4)


@_settings
@given(st.integers(2, 16), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_solver_invariant_residual_matches_solution(n_blocks, d, seed):
    """For any solved system, the reported relative residual must agree with
    a recomputed residual (no drift in the solver's internal tracking)."""
    from repro.solvers import HOperator, SolverConfig, solve

    n = 16 * n_blocks
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    p = HyperParams.create(d, noise=0.5)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    op = HOperator(x=x, params=p, backend="streamed", bm=32, bn=32)
    cfg = SolverConfig(name="cg", tolerance=0.01, max_epochs=500,
                       precond_rank=0)
    res = solve(op, b, None, cfg)
    r = b - op.mvm(res.v)
    rel = np.asarray(jnp.linalg.norm(r, axis=0) /
                     (jnp.linalg.norm(b, axis=0) + 1e-10))
    assert abs(rel[0] - float(res.res_y)) < 5e-3
