"""Adaptive solver-budget tests: the decay-model fit on synthetic rings,
the controller's fallback/observe contract, the fit/fit_batch plumbing
(None-parity, chunk round-trips, lane parity, validation), and the
launch.batch preconditioner-rank grid partitioning."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OuterConfig
from repro.core.driver import fit, fit_batch
from repro.data.synthetic import make_gp_regression
from repro.solvers import SolverConfig, numerics_of
from repro.solvers.adaptive import (
    STALL_DECAY,
    budget_allocate,
    budget_observe,
    fit_decay,
    make_budget_policy,
    predict_epochs,
    resolve_horizon,
)


# -- decay-model fit on synthetic rings ---------------------------------------
def _geometric_ring(h, n, slope, intercept):
    """A rotated ring written exactly as `history_record` writes it: slot
    (m-1) % h holds the residuals after iteration m, for m = 1..n."""
    hist = np.full((h, 2), np.nan, np.float32)
    for m in range(1, n + 1):
        r = np.exp(intercept + slope * m)
        hist[(m - 1) % h] = [r, r]
    return jnp.asarray(hist), jnp.asarray(n, jnp.int32)


def test_fit_decay_recovers_exact_geometric_decay():
    slope, intercept = -0.3, -1.0
    hist, iters = _geometric_ring(16, 10, slope, intercept)
    f = fit_decay(hist, iters)
    assert int(f.n_pts) == 10
    np.testing.assert_allclose(float(f.slope), slope, rtol=1e-5)
    np.testing.assert_allclose(float(f.intercept), intercept, rtol=1e-4)
    assert float(f.rms) < 1e-5
    np.testing.assert_allclose(float(f.log_first), intercept + slope * 1,
                               rtol=1e-5)
    np.testing.assert_allclose(float(f.log_last), intercept + slope * 10,
                               rtol=1e-5)


def test_fit_decay_wrapped_ring_uses_surviving_iterations():
    # 11 writes into 8 slots: iterations 4..11 survive, 1..3 overwritten.
    slope, intercept = -0.25, -0.5
    hist, iters = _geometric_ring(8, 11, slope, intercept)
    f = fit_decay(hist, iters)
    assert int(f.n_pts) == 8
    np.testing.assert_allclose(float(f.slope), slope, rtol=1e-5)
    np.testing.assert_allclose(float(f.log_first), intercept + slope * 4,
                               rtol=1e-5)
    np.testing.assert_allclose(float(f.log_last), intercept + slope * 11,
                               rtol=1e-5)


def test_fit_decay_short_and_empty_rings():
    # One point is not a model: slope pinned to 0, callers must fall back.
    hist, iters = _geometric_ring(8, 1, -0.3, -1.0)
    f1 = fit_decay(hist, iters)
    assert int(f1.n_pts) == 1 and float(f1.slope) == 0.0
    # Empty ring (solver converged at entry): no points, NaN endpoints.
    hist0, iters0 = _geometric_ring(8, 0, -0.3, -1.0)
    f0 = fit_decay(hist0, iters0)
    assert int(f0.n_pts) == 0
    assert np.isnan(float(f0.log_first)) and np.isnan(float(f0.log_last))


def test_fit_decay_is_jit_and_vmap_safe():
    h1, n1 = _geometric_ring(8, 6, -0.4, -1.0)
    h2, n2 = _geometric_ring(8, 11, -0.1, -2.0)
    stacked = jax.jit(jax.vmap(fit_decay))(
        jnp.stack([h1, h2]), jnp.stack([n1, n2])
    )
    np.testing.assert_allclose(np.asarray(stacked.slope), [-0.4, -0.1],
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(stacked.n_pts), [6, 8])


def test_predict_epochs_and_fallback_on_flat_slope():
    hist, iters = _geometric_ring(16, 10, -0.5, 0.0)
    f = fit_decay(hist, iters)
    # 2 nats to descend at 0.5 nats/iter, 1 epoch per iter => 4 epochs.
    got = predict_epochs(f, jnp.asarray(1.0), jnp.asarray(0.0),
                         jnp.asarray(-2.0))
    np.testing.assert_allclose(float(got), 4.0, rtol=1e-4)
    flat = f._replace(slope=jnp.asarray(0.0))
    assert np.isinf(float(predict_epochs(flat, jnp.asarray(1.0),
                                         jnp.asarray(0.0),
                                         jnp.asarray(-2.0))))


# -- controller: allocate / observe -------------------------------------------
def _numerics(max_epochs=20.0, tolerance=1e-3):
    return numerics_of(SolverConfig(name="cg", max_epochs=max_epochs,
                                    tolerance=tolerance, precond_rank=0))


def test_budget_allocate_fixed_fallback_before_first_fit():
    policy = make_budget_policy(ceiling=7.0)
    alloc, pred = budget_allocate(policy, _numerics(max_epochs=20.0))
    assert float(alloc) == 7.0  # min(ceiling, max_epochs), no model yet
    assert np.isnan(float(pred))
    # Ceiling above the configured budget: the budget wins.
    alloc2, _ = budget_allocate(make_budget_policy(ceiling=50.0),
                                _numerics(max_epochs=20.0))
    assert float(alloc2) == 20.0


def test_budget_allocate_uses_calibrated_rate():
    policy = make_budget_policy(safety=1.5)._replace(
        fits_seen=jnp.asarray(1, jnp.int32),
        slope=jnp.asarray(-0.5),  # nats per epoch
        last_res=jnp.asarray(0.1),
    )
    alloc, pred = budget_allocate(policy, _numerics(max_epochs=100.0))
    # need = log(0.1 / 1e-3) nats at 0.5 nats/epoch, x1.5 safety.
    want = np.log(0.1 / 1e-3) / 0.5 * 1.5
    np.testing.assert_allclose(float(alloc), want, rtol=1e-4)
    np.testing.assert_allclose(float(pred), want, rtol=1e-4)
    # The remaining pool caps the allocation.
    low_pool = policy._replace(pool=jnp.asarray(3.0))
    alloc3, _ = budget_allocate(low_pool, _numerics(max_epochs=100.0))
    assert float(alloc3) == 3.0


def test_budget_observe_seeds_emas_and_decrements_pool():
    policy = make_budget_policy(pool=100.0)
    hist, iters = _geometric_ring(16, 8, -0.3, -1.0)
    r_end = float(np.exp(-1.0 - 0.3 * 8))
    new, decision = budget_observe(
        policy, hist, iters, epochs=jnp.asarray(8.0),
        res_y=jnp.asarray(r_end), res_z=jnp.asarray(r_end),
        tolerance=jnp.asarray(1e-3),
    )
    # First valid fit SEEDS the slope EMA (no blend with the 0 init).
    np.testing.assert_allclose(float(new.slope), -0.3, rtol=1e-4)
    assert int(new.fits_seen) == 1 and int(new.steps_seen) == 1
    np.testing.assert_allclose(float(new.pool), 92.0)
    np.testing.assert_allclose(float(new.last_res), r_end, rtol=1e-5)
    assert set(decision) == {"realised", "res", "slope", "noise",
                             "perturbation", "grad_noise", "pool",
                             "epochs_per_iter"}
    np.testing.assert_allclose(float(decision["epochs_per_iter"]), 1.0)


def test_budget_observe_stall_shrinks_assumed_rate():
    # A 1-point ring cannot re-fit; the residual ending far above both the
    # step target and the previous end marks the assumed rate optimistic.
    policy = make_budget_policy()._replace(
        fits_seen=jnp.asarray(1, jnp.int32),
        steps_seen=jnp.asarray(1, jnp.int32),
        slope=jnp.asarray(-0.4),
        last_res=jnp.asarray(0.01),
    )
    hist, iters = _geometric_ring(8, 1, 0.0, np.log(0.05))
    new, _ = budget_observe(
        policy, hist, iters, epochs=jnp.asarray(1.0),
        res_y=jnp.asarray(0.05), res_z=jnp.asarray(0.05),
        tolerance=jnp.asarray(1e-3),
    )
    np.testing.assert_allclose(float(new.slope), -0.4 * STALL_DECAY,
                               rtol=1e-6)
    assert int(new.fits_seen) == 1  # no new fit accepted


def test_resolve_horizon_substitutes_num_steps():
    p = resolve_horizon(make_budget_policy(), num_steps=24)
    assert float(p.horizon) == 24.0
    p2 = resolve_horizon(make_budget_policy(horizon=8.0), num_steps=24)
    assert float(p2.horizon) == 8.0


# -- end-to-end: fit / fit_batch plumbing -------------------------------------
BUDGET_COLS = (
    "budget_alloc", "budget_pred_to_tol", "budget_realised", "budget_res",
    "budget_slope", "budget_noise", "budget_perturbation",
    "budget_grad_noise", "budget_pool", "budget_epochs_per_iter",
)


def _problem(n=96, d=2, seed=0):
    return make_gp_regression(jax.random.PRNGKey(seed), n, d, noise=0.2)


def _cfg(record_history=16, num_steps=5):
    scfg = SolverConfig(name="cg", tolerance=1e-3, max_epochs=30.0,
                        precond_rank=0, record_history=record_history)
    return OuterConfig(estimator="pathwise", warm_start=True, num_probes=8,
                       num_rff_pairs=64, kind="matern32", solver=scfg,
                       num_steps=num_steps, bm=64, bn=64)


def test_budget_policy_none_is_bit_identical():
    x, y = _problem()
    cfg = _cfg()
    key = jax.random.PRNGKey(1)
    r0 = fit(x, y, cfg, key=key, steps_per_round=0)
    r1 = fit(x, y, cfg, key=key, steps_per_round=0, budget_policy=None)
    for name in r0.history:
        if "time" in name:  # wall-clock columns are not replayable
            continue
        np.testing.assert_array_equal(
            np.asarray(r0.history[name]), np.asarray(r1.history[name]),
            err_msg=f"history[{name!r}] changed under budget_policy=None")
    for a, b in zip(jax.tree.leaves(r0.state.params),
                    jax.tree.leaves(r1.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not any(k.startswith("budget_") for k in r0.history)


def test_adaptive_requires_residual_telemetry():
    x, y = _problem()
    policy = make_budget_policy()
    with pytest.raises(ValueError, match="record_history"):
        fit(x, y, _cfg(record_history=0), budget_policy=policy)
    with pytest.raises(ValueError, match="record_history"):
        fit_batch(x, y, _cfg(record_history=1),
                  keys=jax.random.split(jax.random.PRNGKey(0), 2),
                  budget_policy=policy)


def test_adaptive_history_schema_and_invariants():
    x, y = _problem()
    cfg = _cfg(num_steps=6)
    res = fit(x, y, cfg, key=jax.random.PRNGKey(2), steps_per_round=0,
              budget_policy=make_budget_policy(ceiling=20.0, pool=200.0))
    for name in BUDGET_COLS:
        assert name in res.history, f"missing history column {name}"
        assert res.history[name].shape == (cfg.num_steps,)
    alloc = res.history["budget_alloc"]
    assert (alloc <= 20.0 + 1e-6).all() and (alloc >= 1.0 - 1e-6).all()
    pool = res.history["budget_pool"]
    assert (np.diff(pool) <= 1e-6).all()  # pool only ever drains
    np.testing.assert_allclose(
        pool, 200.0 - np.cumsum(res.history["epochs"]), rtol=1e-5)
    # Realised epochs never exceed the step's allocation.
    assert (res.history["epochs"] <= alloc + 1e-4).all()


def test_adaptive_policy_round_trips_chunk_boundaries():
    # The controller state must ride the scan carry ACROSS chunk
    # boundaries: re-chunking the same fit cannot change the trajectory.
    x, y = _problem()
    cfg = _cfg(num_steps=6)
    policy = make_budget_policy(ceiling=20.0)
    key = jax.random.PRNGKey(3)
    r_chunked = fit(x, y, cfg, key=key, steps_per_round=2,
                    budget_policy=policy)
    r_single = fit(x, y, cfg, key=key, steps_per_round=0,
                   budget_policy=policy)
    for name in ("budget_alloc", "budget_pool", "budget_slope", "res_z"):
        np.testing.assert_allclose(
            r_chunked.history[name], r_single.history[name],
            rtol=1e-5, atol=1e-7,
            err_msg=f"history[{name!r}] depends on steps_per_round")
    for a, b in zip(jax.tree.leaves(r_chunked.state.params),
                    jax.tree.leaves(r_single.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_adaptive_lane_parity_with_single_fits():
    # Each lane of an adaptive fit_batch must allocate and converge as its
    # own single fit would — the controller calibrates per lane.
    x, y = _problem()
    cfg = _cfg(num_steps=4)
    policy = make_budget_policy(ceiling=20.0)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    batch = fit_batch(x, y, cfg, keys=keys, budget_policy=policy)
    for i, k in enumerate(keys):
        single = fit(x, y, cfg, key=k, steps_per_round=0,
                     budget_policy=policy)
        for name in ("budget_alloc", "budget_pool", "res_z"):
            np.testing.assert_allclose(
                batch[i].history[name], single.history[name],
                rtol=2e-4, atol=1e-6,
                err_msg=f"lane {i} history[{name!r}] != single fit")


# -- launch.batch: preconditioner-rank grids ----------------------------------
def _batch_args(**over):
    base = dict(tolerances=None, tolerance=0.01, sgd_lrs=None, sgd_lr=2.0,
                epoch_budgets=None, precond_ranks=None, steps=3, bm=256,
                bn=256, solver=None, block_size=64, batch_size=64)
    base.update(over)
    return types.SimpleNamespace(**base)


def test_rank_grid_tags_and_static_groups():
    from repro.launch.batch import group_cells, make_cells, sweep_archs

    archs = sweep_archs(None, smoke=True)[:1]
    args = _batch_args(precond_ranks="0,8")
    cells = make_cells(archs, [0, 1], args)
    assert len(cells) == 4  # 1 arch x 2 seeds x 2 ranks
    assert {c.tag for c in cells} == {"__rk0", "__rk8"}
    assert {c.rank for c in cells} == {0, 8}
    # Rank is STATIC (it changes preconditioner shapes): each rank is its
    # own group/executable, and no group mixes ranks.
    groups = group_cells(cells, args)
    assert len(groups) == 2
    for key, members in groups.items():
        assert len({c.rank for c in members}) == 1
        assert key.solver.precond_rank == members[0].rank
    # One-point grid: legacy artifact names (no tag), arch's own rank.
    plain = make_cells(archs, [0], _batch_args())
    assert len(plain) == 1 and plain[0].tag == ""
    assert plain[0].rank == archs[0].precond_rank
    assert len(group_cells(plain, _batch_args())) == 1
