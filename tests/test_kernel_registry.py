"""Kernel-substrate coverage: for every registered kernel, Pallas MVM
(interpret mode) vs dense reference parity, custom-VJP gradient checks
against JAX AD on the dense path, profile-derivative consistency, and RFF
covariance-recovery sanity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import kernel_matrix
from repro.gp.rff import init_rff, rff_features
from repro.kernels import (
    available_kernels,
    get_kernel,
    h_mvm,
    h_mvm_ref,
    kernel_mvm,
    kernel_mvm_ref,
)
from repro.solvers.operator import HOperator

ALL_KERNELS = ("rbf", "matern12", "matern32", "matern52")
SMOOTH_KERNELS = ("rbf", "matern32", "matern52")  # differentiable at r=0


def test_registry_contains_the_kernel_family():
    assert set(ALL_KERNELS) <= set(available_kernels())


def test_unknown_kernel_raises_with_available_list():
    with pytest.raises(ValueError, match="matern32"):
        get_kernel("laplace")


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_profile_is_unit_at_zero_and_decreasing(kind):
    spec = get_kernel(kind)
    r2 = jnp.linspace(0.0, 25.0, 200)
    k = np.asarray(spec.kappa_from_r2(r2))
    assert abs(k[0] - 1.0) < 1e-5
    assert (np.diff(k) <= 1e-7).all()
    assert (k >= 0).all()


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_dkappa_matches_autodiff_of_profile(kind):
    spec = get_kernel(kind)
    r2 = jnp.linspace(0.05, 16.0, 50)
    ad = jax.vmap(jax.grad(lambda t: spec.kappa_from_r2(t)))(r2)
    np.testing.assert_allclose(
        np.asarray(spec.dkappa_dr2(r2)), np.asarray(ad), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("kind", ALL_KERNELS)
@pytest.mark.parametrize(
    "n,m,d,s,bm,bn",
    [
        (64, 64, 3, 4, 32, 32),
        (100, 132, 7, 5, 32, 64),  # non-divisible rows (padding path)
    ],
)
def test_pallas_forward_matches_dense(kind, n, m, d, s, bm, bn):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + m), 3)
    x1 = jax.random.normal(k1, (n, d))
    x2 = jax.random.normal(k2, (m, d))
    v = jax.random.normal(k3, (m, s))
    p = HyperParams.create(d, lengthscale=0.8, signal=1.3, noise=0.2,
                           kernel=kind)
    out = kernel_mvm(x1, x2, v, p, bm=bm, bn=bn)
    ref = kernel_mvm_ref(x1, x2, v, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_pallas_vjp_matches_dense_ad(kind):
    """Custom-VJP grads (inputs, v, hypers) vs JAX AD through the oracle.

    Disjoint point sets: Matérn-1/2 is non-smooth at coincident points.
    """
    n, m, d, s = 48, 40, 3, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x1 = jax.random.normal(k1, (n, d))
    x2 = 3.0 + jax.random.normal(k2, (m, d))
    v = jax.random.normal(k3, (m, s))
    p = HyperParams.create(d, lengthscale=0.7, signal=1.1, noise=0.3,
                           kernel=kind)

    def loss_pallas(x1, x2, v, p):
        return jnp.sum(jnp.sin(kernel_mvm(x1, x2, v, p, bm=16, bn=16)))

    def loss_ref(x1, x2, v, p):
        return jnp.sum(jnp.sin(kernel_mvm_ref(x1, x2, v, p)))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(x1, x2, v, p)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x1, x2, v, p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_m12_dkappa_exact_zero_on_clamped_diagonal():
    """Matérn-1/2 subgradient at coincident points: the registered dkappa
    must be EXACTLY zero on the clamped region (r2 <= floor) — the floored
    slope -1/(2*sqrt(floor)) ~ -5e5 it used to return there is what biased
    lengthscale gradients on duplicated inputs — and the true (negative)
    slope above it."""
    spec = get_kernel("matern12")
    for r2 in (0.0, 1e-14, 1e-13, 1e-12):
        assert float(spec.dkappa_dr2(jnp.float32(r2))) == 0.0, r2
    assert float(spec.dkappa_dr2(jnp.float32(1e-10))) < -1e3  # steep, not 0
    assert float(spec.dkappa_dr2(jnp.float32(1.0))) < -0.1


def _m12_mvm_direct(x1, x2, v, p):
    """f32 oracle with per-pair differences: duplicate rows land at r2
    EXACTLY 0 (no expanded-quadratic round-off) and the where-gate gives
    them an exactly-zero gradient contribution."""
    diff = (x1[:, None, :] - x2[None, :, :]) / p.lengthscales
    r2 = jnp.sum(diff * diff, -1)
    safe = jnp.where(r2 > 0, r2, 1.0)
    kappa = jnp.where(r2 > 0, jnp.exp(-jnp.sqrt(safe)), 1.0)
    return (p.signal**2 * kappa) @ v


def test_m12_lengthscale_grads_unbiased_on_duplicate_rows():
    """Regression (ROADMAP: Matérn-1/2 gradients at coincident points):
    on data containing duplicate rows, lengthscale gradients through the
    production MVM paths must match the direct-difference oracle. With the
    pre-fix floored dkappa slope the fused-backward-tile error here was
    ~2.1 on a gradient of magnitude ~7 (a 30% bias); subgradient-aware
    dkappa brings it to fp32 round-off."""
    base = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (24, 2)) * 4) / 4.0
    x = jnp.concatenate([base, base], axis=0)  # every row duplicated exactly
    v = jax.random.normal(jax.random.PRNGKey(1), (48, 3))
    p = HyperParams.create(2, lengthscale=0.9, signal=1.2, noise=0.3,
                           kernel="matern12")

    def loss(fn):
        return lambda pp: jnp.sum(jnp.sin(fn(x, x, v, pp)))

    g_oracle = jax.grad(loss(_m12_mvm_direct))(p)
    for fn in (lambda a, b, c, pp: kernel_mvm(a, b, c, pp, bm=16, bn=16),
               kernel_mvm_ref):
        g = jax.grad(loss(fn))(p)
        for leaf, ref in zip(jax.tree.leaves(g), jax.tree.leaves(g_oracle)):
            np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref),
                                       rtol=1e-2, atol=5e-3)


@pytest.mark.parametrize("kind", SMOOTH_KERNELS)
def test_pallas_vjp_symmetric_inputs(kind):
    """x1 is x2 (the GP case): gradients flow through both roles."""
    n, d, s = 40, 2, 3
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (n, d))
    v = jax.random.normal(k2, (n, s))
    p = HyperParams.create(d, kernel=kind)

    g1 = jax.grad(lambda x: jnp.sum(kernel_mvm(x, x, v, p, bm=8, bn=8) ** 2))(x)
    g2 = jax.grad(lambda x: jnp.sum(kernel_mvm_ref(x, x, v, p) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_hoperator_pallas_backend_matches_dense(kind):
    n, d, s = 96, 3, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (n, d))
    v = jax.random.normal(k2, (n, s))
    p = HyperParams.create(d, lengthscale=0.9, noise=0.4, kernel=kind)
    out_p = HOperator(x=x, params=p, backend="pallas", bm=32, bn=32).mvm(v)
    out_d = HOperator(x=x, params=p, backend="dense").mvm(v)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_h_mvm_adds_noise_diagonal(kind):
    n, d, s = 64, 3, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(k1, (n, d))
    v = jax.random.normal(k2, (n, s))
    p = HyperParams.create(d, noise=0.5, kernel=kind)
    np.testing.assert_allclose(
        np.asarray(h_mvm(x, v, p, bm=32, bn=32)),
        np.asarray(h_mvm_ref(x, v, p)),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("kind", ALL_KERNELS)
def test_rff_covariance_recovery(kind):
    """phi(x) phi(x)^T ~= K(x, x) for the kernel's spectral sampler.

    Bounds calibrated to m=8000 pairs at these seeds. Matérn-1/2's
    Cauchy-tailed spectrum used to converge slowest and carried the loosest
    bound; with the stratified mixture draws its tail coverage is exact by
    construction and its bound is now the TIGHTEST of the family.
    """
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(0), (30, d))
    p = HyperParams.create(d, lengthscale=0.9, signal=1.1, kernel=kind)
    st = init_rff(jax.random.PRNGKey(1), 8000, d, 1, kind=kind)
    phi = rff_features(x, st, p)
    k_hat = phi @ phi.T
    k = kernel_matrix(x, x, p)
    bound = 0.05 if kind == "matern12" else 0.1
    assert float(jnp.max(jnp.abs(k_hat - k))) < bound * float(p.signal) ** 2


def test_hyperparams_kernel_field_survives_tree_maps():
    p = HyperParams.create(3, kernel="rbf")
    q = jax.tree.map(lambda a: a + 1.0, p)
    assert q.kernel == "rbf"
    assert len(jax.tree.leaves(p)) == 3  # kernel is aux data, not a leaf
    g = jax.grad(lambda q: jnp.sum(kernel_mvm_ref(
        jnp.ones((4, 3)), jnp.zeros((4, 3)), jnp.ones((4, 2)), q)))(p)
    assert g.kernel == "rbf"


def test_kind_override_beats_params_kernel():
    d = 2
    x = jax.random.normal(jax.random.PRNGKey(5), (16, d))
    p = HyperParams.create(d, kernel="matern32")
    k_rbf = kernel_matrix(x, x, p, kind="rbf")
    p_rbf = HyperParams.create(d, kernel="rbf")
    np.testing.assert_allclose(np.asarray(k_rbf),
                               np.asarray(kernel_matrix(x, x, p_rbf)),
                               rtol=1e-6, atol=1e-6)
