"""Multi-device distribution tests.

These run in SUBPROCESSES with XLA_FLAGS forcing 8 host devices (the parent
pytest process must keep seeing 1 device for the smoke tests), mirroring the
dry-run pattern.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ring_mvm_matches_dense():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.ring import ring_kernel_mvm
    from repro.gp.hyperparams import HyperParams
    from repro.gp.kernels_math import kernel_matrix
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, d, s = 64, 3, 5
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    params = HyperParams.create(d, noise=0.3)
    sh = NamedSharding(mesh, P(("data", "model"), None))
    xs = jax.device_put(x, sh); vs = jax.device_put(v, sh)
    out = jax.jit(lambda a, b: ring_kernel_mvm(a, b, params, mesh))(xs, vs)
    ref = kernel_matrix(x, x, params) @ v
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("RING_OK")
    """)
    assert "RING_OK" in out


def test_ring_mvm_gradients_match_dense():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.ring import ring_h_mvm
    from repro.gp.hyperparams import HyperParams
    from repro.gp.kernels_math import regularised_kernel_matrix
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, d, s = 32, 2, 3
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))
    params = HyperParams.create(d, noise=0.4)
    sh = NamedSharding(mesh, P(("data", "model"), None))
    xs = jax.device_put(x, sh); vs = jax.device_put(v, sh)

    def quad_ring(p):
        hv = ring_h_mvm(xs, vs, p, mesh)
        return jnp.sum(vs * hv)
    def quad_dense(p):
        return jnp.sum(v * (regularised_kernel_matrix(x, p) @ v))

    g1 = jax.jit(jax.grad(quad_ring))(params)
    g2 = jax.grad(quad_dense)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)
    print("RING_GRAD_OK")
    """)
    assert "RING_GRAD_OK" in out


def test_gp_distributed_step_improves_residual():
    """Two warm-started budgeted distributed steps: residual decreases
    (the paper's accumulation effect, on a real 8-device mesh)."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.gp_step import GPStepState, make_gp_outer_step
    from repro.gp.hyperparams import HyperParams
    from repro.gp.rff import init_rff
    from repro.train.adam import adam_init
    from repro.data.synthetic import make_gp_regression

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, d, s = 64, 2, 4
    x, y = make_gp_regression(jax.random.PRNGKey(0), n, d, noise=0.3)
    rff = init_rff(jax.random.PRNGKey(1), 64, d, s)
    w_eps = jax.random.normal(jax.random.PRNGKey(2), (n, s))
    params = HyperParams.create(d)
    sh = NamedSharding(mesh, P(("data", "model"), None))
    sh1 = NamedSharding(mesh, P(("data", "model")))
    state = GPStepState(params=params, adam=adam_init(params),
                        carry_v=jax.device_put(jnp.zeros((n, 1+s)), sh),
                        res_y=jnp.zeros(()), res_z=jnp.zeros(()))
    xs = jax.device_put(x, sh); ys = jax.device_put(y, sh1)
    weps = jax.device_put(w_eps, sh)
    step = jax.jit(make_gp_outer_step(mesh, s, solver_epochs=5))
    s1 = step(state, xs, ys, rff, weps)
    s2 = step(s1, xs, ys, rff, weps)
    r1, r2 = float(s1.res_z), float(s2.res_z)
    print("RES", r1, r2)
    assert np.isfinite(r1) and np.isfinite(r2)
    assert r2 < r1  # warm-started progress accumulates
    print("GP_STEP_OK")
    """)
    assert "GP_STEP_OK" in out


def test_valid_spec_drops_nondividing_axes():
    import jax

    from repro.distributed.sharding import valid_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = valid_spec(mesh, (10, 7), (("pod", "data"), "model"))
    assert spec == __import__("jax").sharding.PartitionSpec(("data",), "model")


def test_smoke_sees_one_device():
    """Guard: the pytest process must NOT inherit the 512-device flag."""
    import jax

    assert len(jax.devices()) == 1
