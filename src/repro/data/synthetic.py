"""Synthetic GP-regression datasets with UCI-compatible (n, d) signatures.

UCI files cannot be redistributed offline, so the default data source draws
targets from a ground-truth GP (plus optional nonstationary warp) at the
exact (n, d) of each paper dataset. Any real UCI CSV dropped into
``data/uci/<name>.csv`` (last column = target) takes precedence.

Standardisation and the 90/10 split protocol follow the UCI benchmark
convention the paper uses (inputs and targets z-scored on the train split).
"""
from __future__ import annotations

import os
import zlib
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Paper datasets: name -> (n, d)  [Appendix B]
UCI_SHAPES = {
    "pol": (13_500, 26),
    "elevators": (14_940, 18),
    "bike": (15_642, 17),
    "protein": (41_157, 9),
    "keggdirected": (43_945, 20),
    "3droad": (391_387, 3),
    "song": (463_811, 90),
    "buzz": (524_925, 77),
    "houseelectric": (1_844_352, 11),
}


class Dataset(NamedTuple):
    x_train: jax.Array
    y_train: jax.Array
    x_test: jax.Array
    y_test: jax.Array
    name: str = "synthetic"


def make_gp_regression(
    key: jax.Array,
    n: int,
    d: int,
    noise: float = 0.1,
    lengthscale: Optional[float] = None,
    num_features: int = 512,
    dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Draw (x, y) with y from an approximate Matérn-3/2 GP prior + noise.

    Uses an RFF prior sample so generation is O(n * m) and scales to the
    paper's 1.8M-row regime. The default lengthscale grows with sqrt(d) so
    the latent function has learnable structure at any input dimension
    (pairwise distances of uniform points scale with sqrt(d)).
    """
    from repro.gp.hyperparams import HyperParams
    from repro.gp.rff import init_rff, prior_sample_at

    if lengthscale is None:
        lengthscale = 1.6 * float(d) ** 0.5
    kx, kf, kn = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), dtype=dtype, minval=-2.0, maxval=2.0)
    params = HyperParams.create(d, lengthscale=lengthscale, signal=1.0,
                                noise=noise, dtype=dtype)
    rff = init_rff(kf, num_features, d, 1, dtype=dtype)
    f = prior_sample_at(x, rff, params)[:, 0]
    y = f + noise * jax.random.normal(kn, (n,), dtype=dtype)
    return x, y


def standardise(train: np.ndarray, *others: np.ndarray):
    mu = train.mean(axis=0, keepdims=True)
    sd = train.std(axis=0, keepdims=True) + 1e-8
    return tuple((a - mu) / sd for a in (train, *others))


def load_dataset(
    name: str,
    key: Optional[jax.Array] = None,
    split: int = 0,
    train_frac: float = 0.9,
    max_n: Optional[int] = None,
    uci_dir: str = "data/uci",
    dtype=jnp.float32,
) -> Dataset:
    """Load ``name`` (UCI CSV if present, else synthetic at the UCI shape).

    ``split`` selects one of the 10 deterministic shuffles (paper: mean over
    10 splits). ``max_n`` truncates for CPU-feasible experiments.
    """
    if name not in UCI_SHAPES:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(UCI_SHAPES)}")
    n, d = UCI_SHAPES[name]
    csv = os.path.join(uci_dir, f"{name}.csv")
    if os.path.exists(csv):
        raw = np.loadtxt(csv, delimiter=",", skiprows=1)
        xy = raw
    else:
        # Deterministic across processes: Python's str hash is salted per
        # interpreter (PYTHONHASHSEED), which silently gave every process a
        # DIFFERENT synthetic dataset and broke cross-process parity checks
        # (benchmarks/sharded_sweep asserts 1-vs-N-device cell parity).
        if key is None:
            key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2**31))
        gen_n = min(n, max_n) if max_n else n
        x, y = make_gp_regression(key, gen_n, d, dtype=dtype)
        xy = np.concatenate([np.asarray(x), np.asarray(y)[:, None]], axis=1)

    if max_n:
        xy = xy[:max_n]
    rng = np.random.RandomState(1000 + split)
    perm = rng.permutation(xy.shape[0])
    xy = xy[perm]
    n_train = int(train_frac * xy.shape[0])
    xtr, xte = xy[:n_train, :-1], xy[n_train:, :-1]
    ytr, yte = xy[:n_train, -1], xy[n_train:, -1]
    xtr, xte = standardise(xtr, xte)
    (ytr, yte) = standardise(ytr[:, None], yte[:, None])
    return Dataset(
        x_train=jnp.asarray(xtr, dtype=dtype),
        y_train=jnp.asarray(ytr[:, 0], dtype=dtype),
        x_test=jnp.asarray(xte, dtype=dtype),
        y_test=jnp.asarray(yte[:, 0], dtype=dtype),
        name=name,
    )


def pad_to_block_multiple(
    x: jax.Array, y: jax.Array, block: int, far: float = 1e6
) -> tuple[jax.Array, jax.Array, int]:
    """Pad (x, y) so n is a multiple of ``block``.

    Pseudo-points are placed at ``far`` (kernel row ~ exactly 0 against real
    points for any plausible lengthscale) with y=0, so H is block-diagonal
    between the real and phantom sets; the phantom solutions stay ~0 and do
    not affect real rows. Returns (x_pad, y_pad, n_real).
    """
    n, d = x.shape
    rem = (-n) % block
    if rem == 0:
        return x, y, n
    # Spread the phantom points out so the phantom block itself is
    # well-conditioned (diagonal ~ s^2 + sigma^2, off-diagonal ~ 0).
    offsets = far * (1.0 + jnp.arange(rem, dtype=x.dtype))[:, None]
    x_pad = jnp.concatenate([x, jnp.ones((rem, d), x.dtype) * offsets], axis=0)
    y_pad = jnp.concatenate([y, jnp.zeros((rem,), y.dtype)], axis=0)
    return x_pad, y_pad, n


def make_lm_batch(
    key: jax.Array, batch: int, seq_len: int, vocab: int
) -> dict:
    """Synthetic LM token batch: inputs + next-token labels + mask."""
    tokens = jax.random.randint(key, (batch, seq_len + 1), 0, vocab, dtype=jnp.int32)
    return {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:],
        "mask": jnp.ones((batch, seq_len), dtype=jnp.float32),
    }
