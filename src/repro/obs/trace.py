"""Trace IDs, span timing, and a JSON-lines structured event log (stdlib).

One request = one trace ID. The transport mints it at ingress (honouring an
inbound ``X-Trace-Id`` header after :func:`sanitize_trace_id`), stores it in
a ``contextvars.ContextVar`` so everything on the request's call path —
admission decisions, engine spans, refresh triggers — can stamp events
without threading the ID through every signature, and echoes it back on the
response. Offline, ``tools/trace_report.py`` groups the JSONL events back
into per-trace waterfalls.

Event log format: one JSON object per line, always carrying ``ts`` (epoch
seconds), ``kind`` and — when one is current or given — ``trace_id``.
Span events add ``span`` (name) and ``dur_ms``. Everything else is
kind-specific payload. Writers are per-process (the path template may
contain ``{pid}``), append-only, line-buffered behind a lock, so replica
processes never interleave partial lines.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import re
import secrets
import threading
import time
from typing import Optional

# Header carrying the trace ID over HTTP, both directions.
TRACE_HEADER = "X-Trace-Id"

# Accepted inbound trace IDs: short, printable, shell/log-safe. Anything
# else is REPLACED with a fresh ID (never echoed back raw — log injection).
_TRACE_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._\-]{0,127}$")

_current_trace: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_trace_id", default=None
)

# Environment variable that auto-configures the process event log (used by
# replica workers and CI smoke jobs; ``{pid}`` expands per process).
LOG_ENV_VAR = "REPRO_OBS_LOG"


def new_trace_id() -> str:
    """A fresh 16-hex-char trace ID (64 random bits)."""
    return secrets.token_hex(8)


def sanitize_trace_id(raw: Optional[str]) -> Optional[str]:
    """``raw`` if it is a safe trace ID, else None (caller mints a new one).

    Inbound header values are attacker-controlled; anything not matching
    the conservative charset/length rule is dropped rather than quoted.
    """
    if raw is None:
        return None
    raw = raw.strip()
    return raw if _TRACE_ID_RE.match(raw) else None


def current_trace_id() -> Optional[str]:
    """The trace ID bound to the current context (None outside a request)."""
    return _current_trace.get()


@contextlib.contextmanager
def trace_context(trace_id: Optional[str]):
    """Bind ``trace_id`` as the current trace for the with-block.

    ``None`` mints a fresh ID. Yields the bound ID. Context-local, so
    concurrent handler threads never see each other's IDs.
    """
    tid = trace_id if trace_id is not None else new_trace_id()
    token = _current_trace.set(tid)
    try:
        yield tid
    finally:
        _current_trace.reset(token)


class EventLog:
    """Append-only JSON-lines event writer (one per process).

    Args:
      path: file path; ``{pid}`` expands to the process ID so several
        processes given the same template never share a file.
      stream: an open text stream instead of a path (tests, stdout).
      max_bytes: when > 0 and ``path``-backed, rotate once the file would
        exceed this size: the live file moves to ``<path>.1`` (existing
        rotations shift to ``.2`` … ``.backups``, the oldest dropped) and a
        fresh file is opened. Rotation happens between lines, under the
        writer lock, so no event is ever split across files.
      backups: how many rotated files to keep (>= 1 when rotating).
    Exactly one of ``path`` / ``stream`` must be given; rotation requires
    ``path``.
    """

    def __init__(self, path: Optional[str] = None, stream=None,
                 max_bytes: int = 0, backups: int = 3):
        if (path is None) == (stream is None):
            raise ValueError("pass exactly one of path= or stream=")
        if max_bytes and path is None:
            raise ValueError("rotation (max_bytes) requires path=")
        if max_bytes and backups < 1:
            raise ValueError("backups must be >= 1 when rotating")
        self.path = None
        self.max_bytes = int(max_bytes)
        self.backups = int(backups)
        self.rotations = 0  #: guarded by self._lock
        if path is not None:
            path = path.replace("{pid}", str(os.getpid()))
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self.path = path
            self._fh = open(path, "a", encoding="utf-8")  #: guarded by self._lock
            self._owns = True
        else:
            self._fh = stream  #: guarded by self._lock
            self._owns = False
        self._lock = threading.Lock()
        self.events_written = 0  #: guarded by self._lock

    def _rotate_locked(self, incoming: int) -> None:
        """Rotate if writing ``incoming`` more bytes would exceed the cap."""
        try:
            size = self._fh.tell()
        except (OSError, ValueError):
            return
        if size == 0 or size + incoming <= self.max_bytes:
            return
        self._fh.close()
        for i in range(self.backups, 1, -1):
            src = f"{self.path}.{i - 1}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i}")
        os.replace(self.path, f"{self.path}.1")
        self._fh = open(self.path, "a", encoding="utf-8")
        self.rotations += 1

    def emit(self, kind: str, trace_id: Optional[str] = None, **fields) -> dict:
        """Write one event line; returns the event dict.

        ``trace_id`` defaults to the context's current trace (omitted from
        the line when there is none). ``fields`` must be JSON-serialisable.
        """
        event = {"ts": time.time(), "kind": str(kind)}
        tid = trace_id if trace_id is not None else current_trace_id()
        if tid is not None:
            event["trace_id"] = tid
        event.update(fields)
        line = json.dumps(event, separators=(",", ":"))
        with self._lock:
            if self.max_bytes and self._owns:
                self._rotate_locked(len(line) + 1)
            self._fh.write(line + "\n")
            self._fh.flush()
            self.events_written += 1
        return event

    def close(self) -> None:
        """Flush and close the underlying file (no-op for borrowed streams)."""
        with self._lock:
            if self._owns:
                self._fh.close()


_log_lock = threading.Lock()
_LOG: Optional[EventLog] = None
_env_checked = False


def configure(path: Optional[str] = None, stream=None,
              max_bytes: int = 0, backups: int = 3) -> Optional[EventLog]:
    """Install (or clear) the process-wide event log.

    ``configure(path=...)`` or ``configure(stream=...)`` installs a writer
    (``max_bytes``/``backups`` forward to :class:`EventLog` rotation);
    ``configure()`` with neither closes and clears it (events become
    no-ops again). Returns the installed log (or None).
    """
    global _LOG, _env_checked
    with _log_lock:
        if _LOG is not None and _LOG._owns:
            _LOG.close()
        _LOG = (
            EventLog(path=path, stream=stream, max_bytes=max_bytes,
                     backups=backups)
            if (path is not None or stream is not None) else None
        )
        _env_checked = True  # explicit configure wins over the env var
        return _LOG


def get_event_log() -> Optional[EventLog]:
    """The process-wide event log, auto-configured from ``$REPRO_OBS_LOG``.

    Returns None when no log is configured — callers must treat that as
    "observability off" and skip, which is what :func:`emit` does.
    """
    global _LOG, _env_checked
    if _LOG is None and not _env_checked:
        with _log_lock:
            if _LOG is None and not _env_checked:
                path = os.environ.get(LOG_ENV_VAR)
                if path:
                    _LOG = EventLog(path=path)
                _env_checked = True
    return _LOG


def emit(kind: str, trace_id: Optional[str] = None, **fields) -> Optional[dict]:
    """Emit an event to the process-wide log; no-op (None) when unconfigured."""
    log = get_event_log()
    if log is None:
        return None
    return log.emit(kind, trace_id=trace_id, **fields)


class Span:
    """A named, timed unit of work inside a trace (yielded by :func:`span`).

    Extra fields can be attached while the span is open::

        with span("engine.submit", bucket=64) as sp:
            ...
            sp.fields["rows"] = m

    ``dur_ms`` is filled in at exit, just before the event is written.
    """

    def __init__(self, name: str, fields: dict):
        self.name = name
        self.fields = fields
        self.t0 = time.perf_counter()
        self.dur_ms: Optional[float] = None


@contextlib.contextmanager
def span(name: str, log: Optional[EventLog] = None,
         trace_id: Optional[str] = None, **fields):
    """Time a block and emit a ``span`` event (no-op when no log is active).

    The event carries ``span`` (the name), ``dur_ms``, the current (or
    given) trace ID, and any extra ``fields`` — including ones attached to
    the yielded :class:`Span` while it is open. An exception inside the
    block still emits the span, with ``error`` set to the exception type,
    then propagates.
    """
    sp = Span(name, dict(fields))
    error = None
    try:
        yield sp
    except BaseException as e:
        error = type(e).__name__
        raise
    finally:
        sp.dur_ms = (time.perf_counter() - sp.t0) * 1e3
        target = log if log is not None else get_event_log()
        if target is not None:
            payload = dict(sp.fields)
            if error is not None:
                payload["error"] = error
            target.emit("span", trace_id=trace_id, span=name,
                        dur_ms=sp.dur_ms, **payload)
