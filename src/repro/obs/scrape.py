"""Prometheus text-format parsing + the fleet scraper (stdlib only).

The exact inverse of :mod:`repro.obs.metrics`'s renderer: :func:`parse_prometheus`
turns exposition text back into typed families with un-escaped label values,
so ``parse(render(registry))`` recovers every family, sample and label bit
for bit (property-tested in ``tests/test_fleet.py``).

On top of the parser sits :class:`FleetScraper` — the sensing half of the
fleet observability plane. It polls N replica ``/metrics`` + ``/stats``
endpoints on an interval (one thread, or caller-driven via
:meth:`FleetScraper.scrape_once` for deterministic tests), re-exports every
scraped family into one aggregate exposition with a ``replica`` label
appended to each sample, and tracks per-replica liveness:

  * a scrape failure increments the replica's consecutive-miss count; at
    ``stale_after_misses`` misses ``gp_fleet_replica_up`` flips to 0 (the
    autoscaler's primary down signal);
  * once ``ttl_s`` seconds pass without a successful scrape, the replica's
    re-exported series are **dropped** from the aggregate (stale samples
    must not freeze dashboards at their last value);
  * removing a target (scale-down) drops everything, including its ``up``
    series — a drained replica is not a dead replica.

Scrape outcomes themselves are first-class availability events: the SLO
engine (:mod:`repro.obs.slo`) counts failed scrapes against the
availability error budget, which is how a dead replica pages even when no
client traffic is hitting it.
"""
from __future__ import annotations

import json
import math
import threading
import time
import urllib.request
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.metrics import _fmt, escape_help, escape_label_value

# Suffixes whose samples roll up into a declared histogram family.
_HIST_SUFFIXES = ("_bucket", "_sum", "_count")

DEFAULT_TIMEOUT_S = 5.0
DEFAULT_STALE_AFTER_MISSES = 2
DEFAULT_TTL_S = 30.0


def unescape_label_value(value: str) -> str:
    """Inverse of :func:`repro.obs.metrics.escape_label_value`.

    A single left-to-right scan, so ``\\\\n`` decodes to backslash + ``n``
    (not newline) exactly as the escaper produced it.
    """
    out: List[str] = []
    i, n = 0, len(value)
    while i < n:
        c = value[i]
        if c == "\\" and i + 1 < n:
            nxt = value[i + 1]
            if nxt == "\\":
                out.append("\\")
                i += 2
                continue
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
            if nxt == '"':
                out.append('"')
                i += 2
                continue
        out.append(c)
        i += 1
    return "".join(out)


def unescape_help(text: str) -> str:
    """Inverse of :func:`repro.obs.metrics.escape_help` (backslash, newline)."""
    out: List[str] = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c == "\\" and i + 1 < n:
            nxt = text[i + 1]
            if nxt == "\\":
                out.append("\\")
                i += 2
                continue
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
        out.append(c)
        i += 1
    return "".join(out)


def parse_value(token: str) -> float:
    """Exposition value token -> float (``+Inf``/``-Inf``/``NaN`` per spec)."""
    if token == "+Inf":
        return math.inf
    if token == "-Inf":
        return -math.inf
    if token == "NaN":
        return math.nan
    return float(token)


@dataclass
class Sample:
    """One exposition sample: full sample name, label dict, value.

    ``name`` keeps histogram suffixes (``_bucket``/``_sum``/``_count``);
    the owning :class:`Family` is the declared base family.
    """

    name: str
    labels: Dict[str, str]
    value: float


@dataclass
class Family:
    """One metric family: TYPE/HELP metadata plus its samples in file order."""

    name: str
    kind: str = "untyped"
    help: str = ""
    samples: List[Sample] = field(default_factory=list)


def _parse_labels(text: str, line: str) -> Tuple[Dict[str, str], int]:
    """Parse ``{k="v",...}`` starting at ``text[0] == '{'``.

    Returns (labels, index just past the closing brace). Escapes inside
    quoted values are decoded; a quote or comma inside a value never splits
    a pair. Raises ValueError (with the offending line) on malformed input.
    """
    labels: Dict[str, str] = {}
    i = 1
    n = len(text)
    while True:
        while i < n and text[i] in " \t":
            i += 1
        if i < n and text[i] == "}":
            return labels, i + 1
        j = i
        while j < n and text[j] not in '="{},':
            j += 1
        name = text[i:j].strip()
        if not name or j >= n or text[j] != "=":
            raise ValueError(f"malformed label pair in line {line!r}")
        i = j + 1
        if i >= n or text[i] != '"':
            raise ValueError(f"unquoted label value in line {line!r}")
        i += 1
        buf: List[str] = []
        while i < n:
            c = text[i]
            if c == "\\" and i + 1 < n:
                buf.append(c)
                buf.append(text[i + 1])
                i += 2
                continue
            if c == '"':
                break
            buf.append(c)
            i += 1
        if i >= n or text[i] != '"':
            raise ValueError(f"unterminated label value in line {line!r}")
        labels[name] = unescape_label_value("".join(buf))
        i += 1
        while i < n and text[i] in " \t":
            i += 1
        if i < n and text[i] == ",":
            i += 1
            continue
        if i < n and text[i] == "}":
            return labels, i + 1
        raise ValueError(f"malformed label block in line {line!r}")


def _family_for(name: str, families: Dict[str, Family]) -> Family:
    """The family a sample named ``name`` belongs to (creating untyped)."""
    fam = families.get(name)
    if fam is not None and fam.kind != "histogram":
        return fam
    for suffix in _HIST_SUFFIXES:
        if name.endswith(suffix):
            base = families.get(name[: -len(suffix)])
            if base is not None and base.kind == "histogram":
                return base
    if fam is not None:  # histogram family sampled under its bare name
        return fam
    fam = Family(name=name)
    families[name] = fam
    return fam


def parse_prometheus(text: str) -> Dict[str, Family]:
    """Parse exposition text (format 0.0.4) into ``{family_name: Family}``.

    Strict about structure (malformed lines raise ValueError — the only
    producer we scrape is our own renderer) but tolerant about ordering:
    HELP/TYPE may precede or be absent, unknown families default to
    ``untyped``. Histogram ``_bucket``/``_sum``/``_count`` samples attach
    to their declared base family.
    """
    families: Dict[str, Family] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                kind = parts[3] if len(parts) > 3 else "untyped"
                fam = families.setdefault(parts[2], Family(name=parts[2]))
                fam.kind = kind
            elif len(parts) >= 3 and parts[1] == "HELP":
                fam = families.setdefault(parts[2], Family(name=parts[2]))
                fam.help = unescape_help(parts[3] if len(parts) > 3 else "")
            continue  # other comments are skipped per the spec
        # Sample line: name[{labels}] value
        i = 0
        n = len(line)
        while i < n and line[i] not in "{ \t":
            i += 1
        name = line[:i]
        if not name:
            raise ValueError(f"sample line without metric name: {raw!r}")
        labels: Dict[str, str] = {}
        rest = line[i:]
        if rest.startswith("{"):
            labels, consumed = _parse_labels(rest, raw)
            rest = rest[consumed:]
        tokens = rest.split()
        if not tokens:
            raise ValueError(f"sample line without value: {raw!r}")
        value = parse_value(tokens[0])  # optional timestamp token ignored
        _family_for(name, families).samples.append(Sample(name, labels, value))
    return families


def render_families(families: Dict[str, Family],
                    extra_label: Optional[Tuple[str, str]] = None) -> List[str]:
    """Render parsed families back to exposition lines (sorted by family).

    ``extra_label`` appends one ``(name, value)`` pair to every sample —
    the fleet scraper's ``replica`` label. Sample order within a family is
    preserved (the renderer emitted them sorted already).
    """
    out: List[str] = []
    for fname in sorted(families):
        fam = families[fname]
        if fam.help:
            out.append(f"# HELP {fam.name} {escape_help(fam.help)}")
        out.append(f"# TYPE {fam.name} {fam.kind}")
        for s in fam.samples:
            pairs = [(k, v) for k, v in s.labels.items()]
            if extra_label is not None:
                pairs.append(extra_label)
            body = ",".join(
                f'{k}="{escape_label_value(v)}"' for k, v in pairs
            )
            label_str = "{" + body + "}" if body else ""
            out.append(f"{s.name}{label_str} {_fmt(s.value)}")
    return out


def _http_get(url: str, timeout: float) -> bytes:
    """One GET; raises OSError/urllib errors on any failure."""
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        if resp.status != 200:
            raise OSError(f"GET {url} -> {resp.status}")
        return resp.read()


@dataclass
class ReplicaState:
    """Everything the scraper knows about one target replica."""

    url: str
    families: Dict[str, Family] = field(default_factory=dict)
    stats: Optional[dict] = None  # last successful GET /stats JSON
    up: bool = False
    ever_up: bool = False
    consecutive_misses: int = 0
    ok_scrapes: int = 0
    err_scrapes: int = 0
    last_ok: Optional[float] = None  # injectable-clock time of last success
    last_ok_ts: Optional[float] = None  # wall-clock of last success
    last_scrape_ms: float = 0.0
    last_error: Optional[str] = None
    dropped: bool = False  # TTL expired: series removed from the aggregate


class FleetScraper:
    """Poll replica ``/metrics`` + ``/stats``; aggregate into one exposition.

    Args:
      targets: initial ``{replica_name: base_url}`` map.
      interval_s: polling interval of the background thread (callers may
        instead drive :meth:`scrape_once` themselves).
      timeout_s: per-request HTTP timeout.
      stale_after_misses: consecutive failed scrapes before
        ``gp_fleet_replica_up`` flips to 0.
      ttl_s: seconds without a successful scrape before the replica's
        re-exported series are dropped from the aggregate.
      clock: injectable monotonic clock (tests).
      fetch: injectable ``fetch(url, timeout) -> bytes`` (tests).

    Thread safety: one internal lock guards the target map and all scrape
    state; :meth:`render` and :meth:`health` snapshot under it.
    """

    def __init__(
        self,
        targets: Optional[Dict[str, str]] = None,
        interval_s: float = 1.0,
        timeout_s: float = DEFAULT_TIMEOUT_S,
        stale_after_misses: int = DEFAULT_STALE_AFTER_MISSES,
        ttl_s: float = DEFAULT_TTL_S,
        clock: Callable[[], float] = time.monotonic,
        fetch: Callable[[str, float], bytes] = _http_get,
    ):
        if stale_after_misses < 1:
            raise ValueError("stale_after_misses must be >= 1")
        self.interval_s = float(interval_s)
        self.timeout_s = float(timeout_s)
        self.stale_after_misses = int(stale_after_misses)
        self.ttl_s = float(ttl_s)
        self._clock = clock
        self._fetch = fetch
        self._lock = threading.Lock()
        self._replicas: Dict[str, ReplicaState] = {}  #: guarded by self._lock
        self.scrape_rounds = 0  #: guarded by self._lock
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if targets:
            self.set_targets(targets)

    # -- target management ----------------------------------------------------
    def set_targets(self, targets: Dict[str, str]) -> None:
        """Replace the target set; removed replicas drop all their series."""
        with self._lock:
            for name in list(self._replicas):
                if name not in targets:
                    del self._replicas[name]
            for name, url in targets.items():
                state = self._replicas.get(name)
                if state is None:
                    self._replicas[name] = ReplicaState(url=url)
                elif state.url != url:  # respawned on a new port: fresh state
                    self._replicas[name] = ReplicaState(url=url)

    def targets(self) -> Dict[str, str]:
        """The current ``{replica_name: base_url}`` map."""
        with self._lock:
            return {n: s.url for n, s in self._replicas.items()}

    # -- scraping -------------------------------------------------------------
    def scrape_once(self) -> Dict[str, bool]:
        """One polling round over every target; returns ``{name: ok}``.

        Each target is scraped independently: ``/metrics`` is parsed and
        cached, ``/stats`` JSON is cached for :meth:`health`. Failures feed
        the staleness machinery documented on the class.
        """
        with self._lock:
            snapshot = [(n, s.url) for n, s in self._replicas.items()]
        results: Dict[str, bool] = {}
        for name, url in snapshot:
            t0 = time.perf_counter()
            err: Optional[str] = None
            families: Optional[Dict[str, Family]] = None
            stats: Optional[dict] = None
            try:
                families = parse_prometheus(
                    self._fetch(url + "/metrics", self.timeout_s).decode(
                        "utf-8")
                )
                stats = json.loads(
                    self._fetch(url + "/stats", self.timeout_s) or b"{}"
                )
            except Exception as e:  # any transport/parse failure is a miss
                err = f"{type(e).__name__}: {e}"
            dur_ms = (time.perf_counter() - t0) * 1e3
            now = self._clock()
            with self._lock:
                state = self._replicas.get(name)
                if state is None or state.url != url:
                    continue  # target changed mid-round
                state.last_scrape_ms = dur_ms
                if err is None:
                    state.families = families or {}
                    state.stats = stats
                    state.up = True
                    state.ever_up = True
                    state.dropped = False
                    state.consecutive_misses = 0
                    state.ok_scrapes += 1
                    state.last_ok = now
                    state.last_ok_ts = time.time()
                    state.last_error = None
                else:
                    state.err_scrapes += 1
                    state.consecutive_misses += 1
                    state.last_error = err
                    if state.consecutive_misses >= self.stale_after_misses \
                            or not state.ever_up:
                        state.up = False
                results[name] = err is None
        self._expire_stale()
        with self._lock:
            self.scrape_rounds += 1
        return results

    def _expire_stale(self) -> None:
        """Drop series of replicas past TTL (called after each round).

        Takes ``self._lock`` itself — deliberately *not* named
        ``*_locked``, which in this repo means the caller must already
        hold the lock.
        """
        now = self._clock()
        with self._lock:
            for state in self._replicas.values():
                ref = state.last_ok
                if state.dropped or state.up:
                    continue
                if ref is None or (now - ref) > self.ttl_s:
                    state.families = {}
                    state.stats = None
                    state.dropped = ref is not None
        # A never-scraped replica keeps dropped=False: it has no series to
        # drop, and its up series should still render (as 0) so the fleet
        # sees the missing member.

    # -- background thread ----------------------------------------------------
    def start(self) -> None:
        """Poll every ``interval_s`` on a daemon thread (idempotent)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.interval_s):
                self.scrape_once()

        self._thread = threading.Thread(
            target=_loop, name="fleet-scraper", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Stop the polling thread."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=self.timeout_s + self.interval_s + 5.0)
        self._thread = None

    # -- aggregate exposition -------------------------------------------------
    def _meta_lines(self) -> List[str]:
        """The scraper's own ``gp_fleet_*`` families (built from state)."""
        with self._lock:
            rows = sorted(
                (n, s.up, s.ok_scrapes, s.err_scrapes, s.last_scrape_ms,
                 s.last_ok_ts)
                for n, s in self._replicas.items()
            )
        out = [
            "# HELP gp_fleet_replica_up 1 while the replica answers scrapes, "
            "0 once stale",
            "# TYPE gp_fleet_replica_up gauge",
        ]
        for n, up, *_ in rows:
            out.append(
                f'gp_fleet_replica_up{{replica="{escape_label_value(n)}"}} '
                f"{1 if up else 0}")
        out.append("# HELP gp_fleet_scrapes_total Scrape attempts by outcome")
        out.append("# TYPE gp_fleet_scrapes_total counter")
        for n, _, ok, err, *_ in rows:
            esc = escape_label_value(n)
            out.append(
                f'gp_fleet_scrapes_total{{replica="{esc}",outcome="ok"}} {ok}')
            out.append(
                f'gp_fleet_scrapes_total{{replica="{esc}",outcome="error"}} '
                f"{err}")
        out.append(
            "# HELP gp_fleet_scrape_duration_ms Last scrape duration per "
            "replica")
        out.append("# TYPE gp_fleet_scrape_duration_ms gauge")
        for n, _, _, _, ms, _ in rows:
            out.append(
                f'gp_fleet_scrape_duration_ms{{replica='
                f'"{escape_label_value(n)}"}} {_fmt(ms)}')
        out.append(
            "# HELP gp_fleet_last_scrape_ts Wall-clock of the last "
            "successful scrape")
        out.append("# TYPE gp_fleet_last_scrape_ts gauge")
        for n, *_rest in rows:
            ts = _rest[-1]
            out.append(
                f'gp_fleet_last_scrape_ts{{replica='
                f'"{escape_label_value(n)}"}} '
                f"{_fmt(ts if ts is not None else 0.0)}")
        return out

    def render(self) -> str:
        """The aggregate fleet exposition: meta families + every scraped
        family with a ``replica`` label appended to each sample."""
        lines = self._meta_lines()
        with self._lock:
            per_replica = [
                (name, state.families)
                for name, state in sorted(self._replicas.items())
                if state.families
            ]
        # Emit each family once (first replica's metadata wins), samples
        # from every replica that exports it, in replica order.
        seen: Dict[str, Family] = {}
        order: List[str] = []
        for name, families in per_replica:
            for fname, fam in families.items():
                if fname not in seen:
                    seen[fname] = Family(fname, fam.kind, fam.help)
                    order.append(fname)
        for fname in sorted(order):
            fam = seen[fname]
            if fam.help:
                lines.append(f"# HELP {fam.name} {escape_help(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for rname, families in per_replica:
                sub = families.get(fname)
                if sub is None:
                    continue
                lines.extend(
                    render_families(
                        {fname: Family(fname, sub.kind, "", sub.samples)},
                        extra_label=("replica", rname),
                    )[1:]  # drop the TYPE line; emitted once above
                )
        return "\n".join(lines) + "\n" if lines else ""

    # -- SLO / health accessors -----------------------------------------------
    def counter_total(self, family: str,
                      where: Optional[Callable[[Dict[str, str]], bool]] = None
                      ) -> float:
        """Sum of a counter family's samples across all live series.

        ``where`` filters by label dict (e.g. 5xx statuses only). Dropped
        replicas contribute nothing — their series are gone.
        """
        total = 0.0
        with self._lock:
            for state in self._replicas.values():
                fam = state.families.get(family)
                if fam is None:
                    continue
                for s in fam.samples:
                    if where is None or where(s.labels):
                        total += s.value
        return total

    def histogram_cumulative(
        self, family: str,
        where: Optional[Callable[[Dict[str, str]], bool]] = None,
    ) -> Tuple[Tuple[float, ...], List[float]]:
        """Merged cumulative buckets of a histogram family across the fleet.

        Returns ``(bounds, cum_counts)`` where ``bounds`` are the sorted
        finite ``le`` boundaries and ``cum_counts`` has one extra final
        entry for ``+Inf``. Summing cumulative counts per boundary across
        series is exact because every series shares the bucket layout.
        """
        sums: Dict[float, float] = {}
        inf_sum = 0.0
        with self._lock:
            for state in self._replicas.values():
                fam = state.families.get(family)
                if fam is None:
                    continue
                for s in fam.samples:
                    if not s.name.endswith("_bucket") or "le" not in s.labels:
                        continue
                    if where is not None and not where(s.labels):
                        continue
                    le = parse_value(s.labels["le"])
                    if math.isinf(le):
                        inf_sum += s.value
                    else:
                        sums[le] = sums.get(le, 0.0) + s.value
        bounds = tuple(sorted(sums))
        cum = [sums[b] for b in bounds]
        cum.append(inf_sum)
        return bounds, cum

    def scrape_totals(self) -> Tuple[float, float]:
        """Cumulative (ok, error) scrape counts over the current targets.

        These are the synthetic availability probes: the SLO engine charges
        failed scrapes against the availability budget so a dead replica
        burns even with zero client traffic.
        """
        with self._lock:
            ok = float(sum(s.ok_scrapes for s in self._replicas.values()))
            err = float(sum(s.err_scrapes for s in self._replicas.values()))
        return ok, err

    def health(self) -> Dict[str, dict]:
        """Per-replica sensing snapshot — the ``/fleet/health`` contract.

        For each target: ``up``, staleness bookkeeping, and the load
        signals the balancer/autoscaler consume, lifted verbatim from the
        replica's last ``/stats`` (``service_ewma_ms``, ``inflight``,
        ``shed_rate`` = shed / (admitted + shed), ``queue_depth`` from the
        scraped engine gauge). Signals are ``None`` until first scrape.
        """
        out: Dict[str, dict] = {}
        with self._lock:
            items = list(self._replicas.items())
        for name, s in items:
            entry = {
                "url": s.url,
                "up": s.up,
                "dropped": s.dropped,
                "consecutive_misses": s.consecutive_misses,
                "ok_scrapes": s.ok_scrapes,
                "err_scrapes": s.err_scrapes,
                "last_ok_ts": s.last_ok_ts,
                "last_error": s.last_error,
                "service_ewma_ms": None,
                "inflight": None,
                "shed_rate": None,
                "queue_depth": None,
                "requests": None,
                "draining": None,
                "version": None,
            }
            stats = s.stats
            if stats:
                adm = stats.get("admission", {})
                entry["service_ewma_ms"] = adm.get("service_ewma_ms")
                entry["inflight"] = adm.get("inflight")
                admitted = adm.get("admitted", 0) or 0
                shed = adm.get("shed", 0) or 0
                denom = admitted + shed
                entry["shed_rate"] = (shed / denom) if denom else 0.0
                entry["requests"] = stats.get("engine", {}).get("requests")
                entry["draining"] = stats.get("draining")
                entry["version"] = stats.get("version")
            fam = s.families.get("gp_engine_queue_depth")
            if fam is not None and fam.samples:
                entry["queue_depth"] = fam.samples[0].value
            out[name] = entry
        return out

    def up_fraction(self) -> float:
        """Fraction of targets currently up (1.0 for an empty fleet)."""
        with self._lock:
            if not self._replicas:
                return 1.0
            return sum(1 for s in self._replicas.values() if s.up) / len(
                self._replicas)
