"""Thread-safe metrics registry with Prometheus text exposition (stdlib).

Three instrument kinds, all label-aware:

  * :class:`Counter`   — monotone; ``inc(value, **labels)``;
  * :class:`Gauge`     — point-in-time; ``set`` / ``inc`` / ``set_ewma``
    (the EWMA arm is how slow-moving signals like service time are
    exported without a separate smoothing layer);
  * :class:`Histogram` — fixed cumulative buckets + ``_sum`` / ``_count``,
    the Prometheus convention, so latency quantiles are scrape-side.

A :class:`MetricsRegistry` owns instruments by name (idempotent getters, so
every subsystem can say ``registry.counter("gp_x_total", ...)`` without
coordination) and renders the whole family set in Prometheus text
exposition format 0.0.4 — including the label-value escaping rules
(backslash, double-quote, newline) that make adversarial label values safe.

The module-level :func:`default_registry` is what the serving stack uses
when no registry is passed explicitly; :data:`NULL_REGISTRY` is a no-op
drop-in for A/B-ing instrumentation cost (see ``benchmarks/obs_overhead``).
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Prometheus-convention latency buckets (seconds); chosen to straddle the
# engine's sub-ms bucketed predicts and multi-second cold solves.
DEFAULT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

DEFAULT_EWMA_ALPHA = 0.2


def escape_label_value(value: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def escape_help(text: str) -> str:
    """Prometheus HELP-line escaping: backslash and newline only."""
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(value: float) -> str:
    """Exposition-format float: integers bare, inf/nan per the spec."""
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    if math.isnan(value):
        return "NaN"
    if float(value).is_integer() and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


def quantile_from_buckets(bounds: Sequence[float],
                          cum_counts: Sequence[float],
                          q: float) -> float:
    """Estimate the ``q``-quantile from cumulative histogram buckets.

    ``bounds`` are the finite upper bucket boundaries (ascending);
    ``cum_counts`` are the cumulative counts per boundary plus one final
    entry for the implicit ``+Inf`` bucket (``len(bounds) + 1`` entries).
    Standard Prometheus ``histogram_quantile`` semantics: linear
    interpolation within the landing bucket (from its lower boundary, 0.0
    below the first), and a quantile that lands in the ``+Inf`` bucket
    clamps to the highest finite boundary. Returns NaN for an empty
    histogram or an out-of-range ``q``.
    """
    if not 0.0 <= q <= 1.0:
        return math.nan
    if len(cum_counts) != len(bounds) + 1:
        raise ValueError("cum_counts must have len(bounds) + 1 entries")
    total = cum_counts[-1]
    if total <= 0:
        return math.nan
    target = q * total
    for i, bound in enumerate(bounds):
        if cum_counts[i] >= target:
            lo = bounds[i - 1] if i > 0 else 0.0
            below = cum_counts[i - 1] if i > 0 else 0.0
            in_bucket = cum_counts[i] - below
            if in_bucket <= 0:
                return bound
            return lo + (bound - lo) * (target - below) / in_bucket
    # Landed in +Inf: the best defensible point estimate is the largest
    # finite boundary (histogram_quantile does the same).
    return bounds[-1] if bounds else math.nan


def bucket_fraction_le(bounds: Sequence[float],
                       cum_counts: Sequence[float],
                       threshold: float) -> float:
    """Fraction of observations ``<= threshold`` from cumulative buckets.

    Same layout contract as :func:`quantile_from_buckets`. Interpolates
    linearly inside the bucket containing ``threshold``; 1.0 above the
    last finite boundary, NaN for an empty histogram. The latency-SLO
    engine uses this to count "good" (fast-enough) events.
    """
    if len(cum_counts) != len(bounds) + 1:
        raise ValueError("cum_counts must have len(bounds) + 1 entries")
    total = cum_counts[-1]
    if total <= 0:
        return math.nan
    prev_bound, prev_cum = 0.0, 0.0
    for i, bound in enumerate(bounds):
        if threshold <= bound:
            if threshold == bound:
                return cum_counts[i] / total
            width = bound - prev_bound
            if width <= 0:
                return cum_counts[i] / total
            frac = max(0.0, (threshold - prev_bound)) / width
            return (prev_cum + (cum_counts[i] - prev_cum) * frac) / total
        prev_bound, prev_cum = bound, cum_counts[i]
    return 1.0


class _Instrument:
    """Shared label bookkeeping for all instrument kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: dict) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}"
            )
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _series(self, key: Tuple[str, ...], extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        pairs = [
            f'{ln}="{escape_label_value(v)}"'
            for ln, v in zip(self.labelnames, key)
        ]
        pairs.extend(f'{ln}="{escape_label_value(v)}"' for ln, v in extra)
        return "{" + ",".join(pairs) + "}" if pairs else ""


class Counter(_Instrument):
    """Monotonically increasing counter (per label set)."""

    kind = "counter"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        """Add ``value`` (must be >= 0) to the labelled series."""
        if value < 0:
            raise ValueError(f"{self.name}: counters only go up, got {value}")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        """Current value of the labelled series (0 if never incremented)."""
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def render(self) -> list:
        """Exposition lines for every series of this counter."""
        with self._lock:
            return [
                f"{self.name}{self._series(k)} {_fmt(v)}"
                for k, v in sorted(self._values.items())
            ]


class Gauge(_Instrument):
    """Point-in-time value; supports ``set``/``inc`` and an EWMA update."""

    kind = "gauge"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, value: float, **labels) -> None:
        """Overwrite the labelled series with ``value``."""
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        """Add ``value`` (may be negative) to the labelled series."""
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def set_ewma(self, value: float, alpha: float = DEFAULT_EWMA_ALPHA,
                 **labels) -> None:
        """Fold ``value`` into an exponentially weighted moving average.

        The first observation seeds the average; later ones move it by
        ``alpha * (value - current)``. This is the standard way slow
        signals (service time, queue wait) are exported as gauges.
        """
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        key = self._key(labels)
        with self._lock:
            cur = self._values.get(key)
            self._values[key] = (
                float(value) if cur is None
                else cur + alpha * (float(value) - cur)
            )

    def value(self, **labels) -> float:
        """Current value of the labelled series (0 if never set)."""
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def render(self) -> list:
        """Exposition lines for every series of this gauge."""
        with self._lock:
            return [
                f"{self.name}{self._series(k)} {_fmt(v)}"
                for k, v in sorted(self._values.items())
            ]


class Histogram(_Instrument):
    """Fixed-bucket cumulative histogram (Prometheus ``_bucket``/``_sum``/``_count``)."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help, labelnames)
        if "le" in labelnames:
            raise ValueError("'le' is reserved for histogram buckets")
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("need at least one bucket boundary")
        self.buckets = bs
        # per label set: [count per finite bucket..., +Inf count], sum
        self._counts: Dict[Tuple[str, ...], list] = {}
        self._sums: Dict[Tuple[str, ...], float] = {}

    def observe(self, value: float, **labels) -> None:
        """Record one observation into the labelled series."""
        key = self._key(labels)
        v = float(value)
        with self._lock:
            counts = self._counts.setdefault(key, [0] * (len(self.buckets) + 1))
            for i, b in enumerate(self.buckets):
                if v <= b:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._sums[key] = self._sums.get(key, 0.0) + v

    def count(self, **labels) -> int:
        """Total observations of the labelled series."""
        with self._lock:
            return sum(self._counts.get(self._key(labels), ()))

    def render(self) -> list:
        """Exposition lines: cumulative ``_bucket`` series + ``_sum``/``_count``."""
        with self._lock:
            lines = []
            for key in sorted(self._counts):
                counts = self._counts[key]
                cum = 0
                for b, c in zip(self.buckets, counts):
                    cum += c
                    series = self._series(key, extra=(("le", _fmt(b)),))
                    lines.append(f"{self.name}_bucket{series} {cum}")
                cum += counts[-1]
                inf = self._series(key, extra=(("le", "+Inf"),))
                lines.append(f"{self.name}_bucket{inf} {cum}")
                lines.append(
                    f"{self.name}_sum{self._series(key)} {_fmt(self._sums[key])}"
                )
                lines.append(f"{self.name}_count{self._series(key)} {cum}")
            return lines


class MetricsRegistry:
    """Named instrument store; getters are idempotent, rendering is atomic.

    ``counter`` / ``gauge`` / ``histogram`` return the existing instrument
    when the name is already registered (raising if the kind or labels
    disagree), so independent subsystems can declare the same metric
    without coordinating a single init site.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls) or existing.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind} with labels {existing.labelnames}"
                    )
                return existing
            inst = cls(name, help, labelnames, **kw)
            self._metrics[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        """Get-or-create a :class:`Counter`."""
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        """Get-or-create a :class:`Gauge`."""
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        """Get-or-create a :class:`Histogram` with ``buckets`` boundaries."""
        return self._get_or_create(
            Histogram, name, help, labelnames, buckets=buckets
        )

    def get(self, name: str) -> Optional[_Instrument]:
        """The registered instrument, or None."""
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list:
        """Registered metric names, sorted."""
        with self._lock:
            return sorted(self._metrics)

    def render(self) -> str:
        """The whole registry in Prometheus text exposition format 0.0.4."""
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
        out = []
        for m in metrics:
            if m.help:
                out.append(f"# HELP {m.name} {escape_help(m.help)}")
            out.append(f"# TYPE {m.name} {m.kind}")
            out.extend(m.render())
        return "\n".join(out) + "\n" if out else ""


class _NullInstrument:
    """Accepts every instrument method as a no-op (overhead A/B baseline)."""

    def __getattr__(self, _name):
        return lambda *a, **kw: None


class NullRegistry(MetricsRegistry):
    """A registry whose instruments drop everything — the off switch.

    Pass this where a ``MetricsRegistry`` is expected to measure the cost
    of instrumentation itself (``benchmarks/obs_overhead``) or to silence
    a subsystem without touching its call sites.
    """

    def __init__(self):
        super().__init__()
        self._null = _NullInstrument()

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        return self._null

    def render(self) -> str:
        """Always empty."""
        return ""


NULL_REGISTRY = NullRegistry()

_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry serving components fall back to."""
    return _DEFAULT


def render_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """Render ``registry`` (default: the process-wide one) as exposition text."""
    return (registry if registry is not None else _DEFAULT).render()


# Content type the /metrics endpoint must reply with (version matters to
# Prometheus scrapers).
CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"
