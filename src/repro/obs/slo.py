"""SLOs, multi-window error-budget burn rates, and the alert state machine.

The decision half of the fleet observability plane (the sensing half is
:mod:`repro.obs.scrape`). Definitions follow the SRE burn-rate playbook:

  * an :class:`SLO` turns scraped counters into a cumulative ``(good, bad)``
    event pair. :class:`AvailabilitySLO` counts HTTP responses by status
    class **plus scrape probe outcomes** — a dead replica must burn budget
    even when no client traffic is flowing, so each failed scrape is a bad
    synthetic probe. :class:`LatencySLO` splits a cumulative histogram at a
    threshold via the shared bucket interpolator in :mod:`repro.obs.metrics`.
  * burn rate over a window = (bad / total in that window) / (1 - objective):
    burn 1.0 spends exactly the whole budget over the SLO period; 14.4
    exhausts a 30-day budget in ~2 days (the classic page threshold).
  * a rule fires only when **both** a fast and a slow window exceed its
    threshold — the fast window gives reaction speed, the slow window keeps
    a brief blip from paging.
  * the per-SLO state machine (OK -> WARN -> PAGE) escalates immediately
    but de-escalates with hysteresis (burn must drop below
    ``threshold * hysteresis`` in either window) so a burn hovering at the
    threshold doesn't flap. Every transition emits a ``slo_alert`` JSONL
    event through the PR 7 :class:`repro.obs.trace.EventLog` and the
    current state is exported as ``gp_slo_*`` gauges.

Wire format and worked examples: ``docs/fleet.md``.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.obs.metrics import (
    MetricsRegistry,
    bucket_fraction_le,
    quantile_from_buckets,
)
from repro.obs.trace import EventLog

# State machine levels, ordered by severity.
OK, WARN, PAGE = "OK", "WARN", "PAGE"
_LEVEL = {OK: 0, WARN: 1, PAGE: 2}
_NAME = {v: k for k, v in _LEVEL.items()}

# Classic 30-day-budget thresholds: PAGE at 14.4x (budget gone in ~2 days),
# WARN at 3x (~10 days).
DEFAULT_PAGE_BURN = 14.4
DEFAULT_WARN_BURN = 3.0
DEFAULT_HYSTERESIS = 0.8


@dataclass
class BurnRateRule:
    """One multi-window burn-rate rule: fire when BOTH windows exceed
    ``threshold``; de-escalate when EITHER drops below
    ``threshold * hysteresis``."""

    level: str  # WARN or PAGE
    threshold: float
    fast_window_s: float
    slow_window_s: float
    hysteresis: float = DEFAULT_HYSTERESIS


def default_rules(fast_window_s: float = 300.0,
                  slow_window_s: float = 3600.0) -> List[BurnRateRule]:
    """The standard WARN@3x / PAGE@14.4x rule pair over the given windows."""
    return [
        BurnRateRule(PAGE, DEFAULT_PAGE_BURN, fast_window_s, slow_window_s),
        BurnRateRule(WARN, DEFAULT_WARN_BURN, fast_window_s, slow_window_s),
    ]


class SLO:
    """Base: a named objective mapping fleet state to cumulative counts.

    Subclasses implement :meth:`totals` returning monotone cumulative
    ``(good, bad)`` event counts read from the fleet source (anything with
    the :class:`repro.obs.scrape.FleetScraper` accessor surface).
    """

    def __init__(self, name: str, objective: float,
                 rules: Optional[List[BurnRateRule]] = None):
        if not 0.0 < objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        self.name = name
        self.objective = float(objective)
        self.rules = rules if rules is not None else default_rules()

    def totals(self, fleet) -> Tuple[float, float]:
        """Cumulative (good, bad) counts — subclass responsibility."""
        raise NotImplementedError


class AvailabilitySLO(SLO):
    """Availability from ``gp_http_requests_total`` status classes + scrape
    probes.

    Bad events: responses whose status starts with ``5`` plus every failed
    scrape. Good: everything else plus successful scrapes. Counting the
    scrapes as blackbox probes is what lets a dead-but-idle replica page.
    """

    def __init__(self, name: str = "availability", objective: float = 0.99,
                 rules: Optional[List[BurnRateRule]] = None,
                 count_scrapes: bool = True):
        super().__init__(name, objective, rules)
        self.count_scrapes = count_scrapes

    def totals(self, fleet) -> Tuple[float, float]:
        """(good, bad) = non-5xx responses + ok scrapes, 5xx + failed
        scrapes."""
        bad = fleet.counter_total(
            "gp_http_requests_total",
            where=lambda lbl: str(lbl.get("status", "")).startswith("5"))
        good = fleet.counter_total(
            "gp_http_requests_total",
            where=lambda lbl: not str(lbl.get("status", "")).startswith("5"))
        if self.count_scrapes:
            ok, err = fleet.scrape_totals()
            good += ok
            bad += err
        return good, bad


class LatencySLO(SLO):
    """Latency from cumulative histogram buckets: good = observations at or
    under ``threshold_s``, interpolated inside the landing bucket."""

    def __init__(self, name: str = "latency", objective: float = 0.95,
                 threshold_s: float = 0.25,
                 family: str = "gp_http_request_seconds",
                 path: Optional[str] = None,
                 rules: Optional[List[BurnRateRule]] = None):
        super().__init__(name, objective, rules)
        self.threshold_s = float(threshold_s)
        self.family = family
        self.path = path

    def _where(self) -> Optional[Callable[[Dict[str, str]], bool]]:
        if self.path is None:
            return None
        return lambda lbl: lbl.get("path") == self.path

    def totals(self, fleet) -> Tuple[float, float]:
        """(good, bad) split of the histogram at ``threshold_s``."""
        bounds, cum = fleet.histogram_cumulative(self.family,
                                                 where=self._where())
        total = cum[-1] if cum else 0.0
        if total <= 0:
            return 0.0, 0.0
        frac = bucket_fraction_le(bounds, cum, self.threshold_s)
        if math.isnan(frac):
            return 0.0, 0.0
        good = frac * total
        return good, total - good

    def quantiles(self, fleet, qs=(0.5, 0.99)) -> Dict[float, float]:
        """Fleet-wide latency quantiles (seconds; NaN when empty)."""
        bounds, cum = fleet.histogram_cumulative(self.family,
                                                 where=self._where())
        return {q: quantile_from_buckets(bounds, cum, q) for q in qs}


@dataclass
class _SLOState:
    """Mutable evaluation state for one SLO."""

    slo: SLO
    state: str = OK
    # (ts, good, bad) cumulative snapshots, trimmed to the slowest window.
    history: Deque[Tuple[float, float, float]] = field(default_factory=deque)
    burns: Dict[str, float] = field(default_factory=dict)  # window -> burn
    last_transition_ts: Optional[float] = None


class SLOEngine:
    """Evaluate SLOs against a fleet source; run the alert state machine.

    Args:
      fleet: the sensing source (a :class:`repro.obs.scrape.FleetScraper`
        or anything with ``counter_total`` / ``histogram_cumulative`` /
        ``scrape_totals``).
      slos: the objectives to track.
      event_log: transition sink; ``None`` disables alert events.
      registry: where ``gp_slo_*`` gauges land (own registry by default so
        the monitor can concatenate it with the scraper's exposition).
      clock: injectable time source (tests).

    Call :meth:`evaluate` once per scrape round. Burn windows clamp to the
    data actually available — a 1-hour window evaluated 30s after startup
    uses the 30s of history it has, rather than reporting zero burn.
    """

    def __init__(self, fleet, slos: List[SLO],
                 event_log: Optional[EventLog] = None,
                 registry: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.fleet = fleet
        self.event_log = event_log
        self.registry = registry if registry is not None else MetricsRegistry()
        self._clock = clock
        self._states = {slo.name: _SLOState(slo=slo) for slo in slos}
        if len(self._states) != len(slos):
            raise ValueError("duplicate SLO names")
        self._g_state = self.registry.gauge(
            "gp_slo_state",
            "Alert level per SLO (0=OK, 1=WARN, 2=PAGE)", ["slo"])
        self._g_burn = self.registry.gauge(
            "gp_slo_burn_rate",
            "Error-budget burn rate per SLO and window", ["slo", "window"])
        self._g_budget = self.registry.gauge(
            "gp_slo_error_budget_remaining",
            "Fraction of total error budget left (cumulative)", ["slo"])
        self._g_quantile = self.registry.gauge(
            "gp_slo_latency_seconds",
            "Fleet-wide latency quantiles for latency SLOs",
            ["slo", "quantile"])

    # -- burn computation -----------------------------------------------------
    @staticmethod
    def _windowed_burn(history: Deque[Tuple[float, float, float]],
                       now: float, window_s: float,
                       objective: float) -> float:
        """Burn over ``[now - window_s, now]`` from cumulative snapshots.

        Uses the oldest snapshot inside the window as the baseline (the
        window clamps to available history). No events in the window means
        zero burn.
        """
        if not history:
            return 0.0
        cutoff = now - window_s
        base = None
        for ts, good, bad in history:
            if ts >= cutoff:
                base = (good, bad)
                break
        if base is None:
            base = (history[-1][1], history[-1][2])
        _, good_now, bad_now = history[-1]
        d_good = good_now - base[0]
        d_bad = bad_now - base[1]
        d_total = d_good + d_bad
        if d_total <= 0:
            return 0.0
        return (d_bad / d_total) / (1.0 - objective)

    def _desired_level(self, st: _SLOState, now: float) -> int:
        """Highest rule level whose fast AND slow burns exceed threshold."""
        desired = _LEVEL[OK]
        for rule in st.slo.rules:
            fast = self._windowed_burn(st.history, now, rule.fast_window_s,
                                       st.slo.objective)
            slow = self._windowed_burn(st.history, now, rule.slow_window_s,
                                       st.slo.objective)
            st.burns[f"fast_{rule.level.lower()}"] = fast
            st.burns[f"slow_{rule.level.lower()}"] = slow
            if fast >= rule.threshold and slow >= rule.threshold:
                desired = max(desired, _LEVEL[rule.level])
        return desired

    def _supports_level(self, st: _SLOState, now: float, level: int) -> bool:
        """Whether hysteresis-scaled thresholds still justify ``level``."""
        for rule in st.slo.rules:
            if _LEVEL[rule.level] != level:
                continue
            thresh = rule.threshold * rule.hysteresis
            fast = self._windowed_burn(st.history, now, rule.fast_window_s,
                                       st.slo.objective)
            slow = self._windowed_burn(st.history, now, rule.slow_window_s,
                                       st.slo.objective)
            if fast >= thresh and slow >= thresh:
                return True
        return False

    # -- evaluation -----------------------------------------------------------
    def evaluate(self) -> Dict[str, dict]:
        """One evaluation round: snapshot, burn, transition, export.

        Returns the per-SLO status dict also served at ``/fleet/slo``.
        """
        now = self._clock()
        out: Dict[str, dict] = {}
        for name, st in self._states.items():
            good, bad = st.slo.totals(self.fleet)
            st.history.append((now, good, bad))
            slowest = max(
                max(r.fast_window_s, r.slow_window_s) for r in st.slo.rules)
            while len(st.history) > 2 and st.history[1][0] < now - slowest:
                st.history.popleft()

            st.burns = {}
            desired = self._desired_level(st, now)
            current = _LEVEL[st.state]
            new = current
            if desired > current:
                new = desired  # escalate immediately (OK -> PAGE jumps ok)
            elif desired < current:
                # De-escalate only past hysteresis, one level at a time.
                while new > desired and not self._supports_level(st, now,
                                                                 new):
                    new -= 1
            if new != current:
                self._transition(st, _NAME[new], now)

            total = good + bad
            budget = 1.0
            if total > 0:
                allowed = (1.0 - st.slo.objective) * total
                budget = 1.0 - (bad / allowed) if allowed > 0 else 0.0
            self._g_state.set(_LEVEL[st.state], slo=name)
            self._g_budget.set(budget, slo=name)
            for window, burn in st.burns.items():
                self._g_burn.set(burn, slo=name, window=window)
            entry = {
                "state": st.state,
                "objective": st.slo.objective,
                "good": good,
                "bad": bad,
                "error_budget_remaining": budget,
                "burn_rates": dict(st.burns),
                "last_transition_ts": st.last_transition_ts,
            }
            if isinstance(st.slo, LatencySLO):
                qs = st.slo.quantiles(self.fleet)
                for q, v in qs.items():
                    self._g_quantile.set(
                        v if not math.isnan(v) else 0.0,
                        slo=name, quantile=str(q))
                entry["latency_quantiles_s"] = {
                    str(q): (None if math.isnan(v) else v)
                    for q, v in qs.items()
                }
                entry["threshold_s"] = st.slo.threshold_s
            out[name] = entry
        return out

    def _transition(self, st: _SLOState, new_state: str, now: float) -> None:
        """Apply a state change and emit the ``slo_alert`` event."""
        old = st.state
        st.state = new_state
        st.last_transition_ts = time.time()
        if self.event_log is not None:
            self.event_log.emit(
                "slo_alert",
                slo=st.slo.name,
                from_state=old,
                to_state=new_state,
                objective=st.slo.objective,
                burn_rates={k: round(v, 4) for k, v in st.burns.items()},
            )

    def status(self) -> Dict[str, dict]:
        """Last-evaluated per-SLO status without advancing the machine."""
        out = {}
        for name, st in self._states.items():
            out[name] = {
                "state": st.state,
                "objective": st.slo.objective,
                "burn_rates": dict(st.burns),
                "last_transition_ts": st.last_transition_ts,
            }
        return out

    def worst_state(self) -> str:
        """Highest alert level across all SLOs (OK for an empty set)."""
        level = 0
        for st in self._states.values():
            level = max(level, _LEVEL[st.state])
        return _NAME[level]
