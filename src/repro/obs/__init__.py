"""Stdlib-only observability: metrics, traces, structured event logs.

The serving stack and the fit driver both need to answer "what did the
solver/replica actually do over time" without attaching a debugger:

  * :mod:`repro.obs.metrics` — a thread-safe counter / gauge / histogram
    registry with label support and EWMA gauges, rendered in Prometheus
    text exposition format by the transport's ``GET /metrics`` endpoint;
  * :mod:`repro.obs.trace`   — trace-ID minting/sanitising (the
    ``X-Trace-Id`` header contract), span timing contexts, and a JSON-lines
    structured event log with a per-process writer. One trace ID follows a
    request through transport -> admission -> engine -> (append ->) refresh.

Everything here is pure stdlib (no jax import): replicas, CI jobs and the
offline ``tools/trace_report.py`` reader can use it without an accelerator
runtime. Solver-side telemetry (per-iteration residual ring buffers) lives
with the solvers (`repro.solvers.base`) because it runs inside jit; this
package is where those recordings become events and metrics on the host.
"""
from repro.obs.metrics import (
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    render_prometheus,
)
from repro.obs.trace import (
    TRACE_HEADER,
    EventLog,
    configure,
    current_trace_id,
    emit,
    get_event_log,
    new_trace_id,
    sanitize_trace_id,
    span,
    trace_context,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "default_registry",
    "render_prometheus",
    "TRACE_HEADER",
    "EventLog",
    "configure",
    "current_trace_id",
    "emit",
    "get_event_log",
    "new_trace_id",
    "sanitize_trace_id",
    "span",
    "trace_context",
]
