"""Stdlib-only observability: metrics, traces, structured event logs.

The serving stack and the fit driver both need to answer "what did the
solver/replica actually do over time" without attaching a debugger:

  * :mod:`repro.obs.metrics` — a thread-safe counter / gauge / histogram
    registry with label support and EWMA gauges, rendered in Prometheus
    text exposition format by the transport's ``GET /metrics`` endpoint;
  * :mod:`repro.obs.trace`   — trace-ID minting/sanitising (the
    ``X-Trace-Id`` header contract), span timing contexts, and a JSON-lines
    structured event log with a per-process writer. One trace ID follows a
    request through transport -> admission -> engine -> (append ->) refresh.

Everything here is pure stdlib (no jax import): replicas, CI jobs and the
offline ``tools/trace_report.py`` reader can use it without an accelerator
runtime. Solver-side telemetry (per-iteration residual ring buffers) lives
with the solvers (`repro.solvers.base`) because it runs inside jit; this
package is where those recordings become events and metrics on the host.

Fleet-level sensing sits on top of the per-process primitives:

  * :mod:`repro.obs.scrape` — the Prometheus text-format parser (exact
    inverse of the renderer) and the :class:`FleetScraper` that polls N
    replicas and aggregates their families under a ``replica`` label;
  * :mod:`repro.obs.slo`    — SLO objects, multi-window error-budget
    burn-rate rules, and the OK/WARN/PAGE alert state machine feeding
    JSONL alert events and ``gp_slo_*`` gauges.
"""
from repro.obs.metrics import (
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    bucket_fraction_le,
    default_registry,
    quantile_from_buckets,
    render_prometheus,
)
from repro.obs.scrape import (
    Family,
    FleetScraper,
    Sample,
    parse_prometheus,
)
from repro.obs.slo import (
    AvailabilitySLO,
    BurnRateRule,
    LatencySLO,
    SLOEngine,
    default_rules,
)
from repro.obs.trace import (
    TRACE_HEADER,
    EventLog,
    configure,
    current_trace_id,
    emit,
    get_event_log,
    new_trace_id,
    sanitize_trace_id,
    span,
    trace_context,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "bucket_fraction_le",
    "default_registry",
    "quantile_from_buckets",
    "render_prometheus",
    "Family",
    "FleetScraper",
    "Sample",
    "parse_prometheus",
    "AvailabilitySLO",
    "BurnRateRule",
    "LatencySLO",
    "SLOEngine",
    "default_rules",
    "TRACE_HEADER",
    "EventLog",
    "configure",
    "current_trace_id",
    "emit",
    "get_event_log",
    "new_trace_id",
    "sanitize_trace_id",
    "span",
    "trace_context",
]
