import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
)
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); REPRO_DRYRUN_DEVICES exists for fast CI runs, the
production default is 512 placeholder host devices.

For every cell this proves, without hardware:
  * the pjit sharding config is coherent (lower+compile succeeds),
  * it fits (memory_analysis -> bytes per device),
  * and yields the roofline terms (cost_analysis + HLO collective parse).

Results are written to artifacts/dryrun/<arch>__<shape>__<mesh>.json and
aggregated by benchmarks/roofline.py into EXPERIMENTS.md tables.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import (
    GP_SHAPES,
    LM_SHAPES,
    get_config,
)
from repro.launch.hlo_analysis import (
    RooflineReport,
    extract_cost,
    extract_memory,
    parse_collectives,
)
from repro.launch.mesh import make_production_mesh


def _model_flop_tokens(cfg, shape, n_active) -> float:
    """N_active-weighted token count. For enc-dec archs the encoder and
    decoder process DIFFERENT sequence lengths, so weight the two stacks'
    parameter counts by their own token counts (whisper: 4096 frames vs 448
    text tokens)."""
    b = shape.global_batch
    if not cfg.is_encdec:
        return n_active * b * shape.seq_len
    mults = 3 if cfg.mlp_activation == "swiglu" else 2
    enc_per_layer = (
        cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
        + cfg.q_dim * cfg.d_model
        + mults * cfg.d_model * cfg.d_ff
    )
    n_enc = enc_per_layer * cfg.encoder.num_layers
    n_dec = n_active - n_enc
    # cross-attention K/V projections run over the ENCODER length
    cross_kv = cfg.num_layers * 2 * cfg.d_model * cfg.kv_dim
    n_dec = n_dec - cross_kv
    return b * (
        n_enc * shape.seq_len
        + n_dec * cfg.decoder_len
        + cross_kv * shape.seq_len
    )


def _num_microbatches(shape, mesh) -> int:
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev = max(1, shape.global_batch // dp)
    m = max(1, per_dev // shape.microbatch_rows)
    while shape.global_batch % m != 0:  # scan needs exact division
        m -= 1
    return m


def apply_opts(cfg, shape, opts):
    """Apply hillclimb variant options to (cfg, shape)."""
    import dataclasses as dc

    opts = opts or {}
    if opts.get("param_dtype"):
        cfg = dc.replace(cfg, param_dtype=opts["param_dtype"])
    if opts.get("remat") is not None:
        cfg = dc.replace(cfg, remat=opts["remat"])
    if opts.get("moe_per_expert_scatter"):
        cfg = dc.replace(cfg, moe_single_scatter=False)
    if opts.get("remat_policy"):
        cfg = dc.replace(cfg, remat_policy=opts["remat_policy"])
    if shape is not None and opts.get("microbatch_rows"):
        shape = dc.replace(shape, microbatch_rows=opts["microbatch_rows"])
    return cfg, shape


def lower_lm_cell(arch: str, shape_name: str, mesh, opts=None) -> tuple:
    """Returns (lowered, model_flops, notes)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import set_global_mesh
    from repro.models import (
        abstract_params,
        batch_pspec,
        cache_shardings,
        input_specs,
        make_prefill_step,
        make_serve_step,
        make_train_step,
        param_shardings,
    )
    from repro.models.steps import opt_shardings
    from repro.train.adam import adam_init

    opts = opts or {}
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    cfg, shape = apply_opts(cfg, shape, opts)
    serving = bool(opts.get("serving_resident")) and shape.step != "train"
    set_global_mesh(mesh)
    params_abs = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh, params_abs, serving=serving)
    specs = input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    n_active = cfg.active_params_per_token_layers()
    notes = ""

    if shape.step == "train":
        m = _num_microbatches(shape, mesh)
        notes = f"microbatches={m}"
        step = make_train_step(cfg, num_microbatches=m)
        opt_abs = jax.eval_shape(adam_init, params_abs)
        o_sh = opt_shardings(mesh, p_sh, opt_abs)
        b_sh = batch_pspec(specs["batch"], mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, repl),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        model_flops = 6.0 * _model_flop_tokens(cfg, shape, n_active)
    elif shape.step == "prefill":
        step = make_prefill_step(cfg)
        b_sh = batch_pspec(specs["batch"], mesh)
        from repro.distributed.sharding import DP, TP, valid_spec

        logits_shape = jax.eval_shape(step, params_abs, specs["batch"])
        out_sh = NamedSharding(
            mesh, valid_spec(mesh, logits_shape.shape, (DP, None, TP))
        )
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=out_sh)
        lowered = jitted.lower(params_abs, specs["batch"])
        model_flops = 2.0 * _model_flop_tokens(cfg, shape, n_active)
    else:  # decode
        step = make_serve_step(cfg)
        c_sh = cache_shardings(cfg, mesh, specs["cache"])
        from repro.distributed.sharding import DP, TP, valid_spec

        tok_sh = NamedSharding(mesh, valid_spec(mesh, (shape.global_batch,), (DP,)))
        logits_abs, cache_abs2 = jax.eval_shape(
            step, params_abs, specs["cache"], specs["tokens"], specs["pos"]
        )
        log_sh = NamedSharding(
            mesh, valid_spec(mesh, logits_abs.shape, (DP, TP))
        )
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, repl),
            out_shardings=(log_sh, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(
            params_abs, specs["cache"], specs["tokens"], specs["pos"]
        )
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2.0 * n_active * tokens
    return lowered, model_flops, notes


def lower_gp_cell(shape_name: str, mesh, opts=None) -> tuple:
    import jax.numpy as jnp

    from repro.distributed.gp_step import lower_gp_outer_step

    opts = opts or {}
    tile_dtype = (jnp.bfloat16 if opts.get("gp_tile_dtype") == "bfloat16"
                  else jnp.float32)
    shape = GP_SHAPES[shape_name]
    lowered, model_flops, notes = lower_gp_outer_step(
        shape, mesh, tile_dtype=tile_dtype
    )
    return lowered, model_flops, notes


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             analyze: bool = True, opts=None, variant: str = "") -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    t0 = time.time()
    if arch == "gp-iterative":
        lowered, model_flops, notes = lower_gp_cell(shape_name, mesh, opts)
    else:
        lowered, model_flops, notes = lower_lm_cell(arch, shape_name, mesh, opts)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # Raw (scan-bodies-counted-once) numbers from the production program.
    flops, byts = extract_cost(compiled)
    memory = extract_memory(compiled)
    coll = parse_collectives(compiled.as_text(), chips)
    pieces = {"raw_production": {
        "flops": flops, "bytes": byts, "coll_bytes": coll.bytes_per_chip,
    }}

    # Trip-count-corrected composition (roofline truth); single-pod is the
    # roofline mesh per spec, but the correction is mesh-agnostic.
    if analyze:
        from repro.launch.analysis import analysis_gp_cell, analysis_lm_cell

        t0 = time.time()
        if arch == "gp-iterative":
            total, piece_detail = analysis_gp_cell(shape_name, mesh, opts)
        else:
            total, piece_detail = analysis_lm_cell(arch, shape_name, mesh, opts)
        pieces.update(piece_detail)
        flops, byts = total.flops, total.bytes
        coll_bytes, coll_counts = total.coll_bytes, total.coll_counts
        notes += f"; analysis={time.time()-t0:.1f}s"
    else:
        coll_bytes, coll_counts = coll.bytes_per_chip, coll.counts

    report = RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes_per_chip=coll_bytes,
        collective_counts=coll_counts,
        collective_by_op=coll.by_op_bytes,
        model_flops=model_flops,
        notes=f"{notes}; lower={t_lower:.1f}s compile={t_compile:.1f}s",
        **memory,
    ).finalise()
    report_dict = dataclasses.asdict(report)
    report_dict["pieces"] = pieces
    report_dict["variant"] = variant
    report_dict["opts"] = opts or {}

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    )
    with open(path, "w") as f:
        json.dump(report_dict, f, indent=2)
    print(
        f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
        f"(chips={chips} peak={report.peak_bytes/2**30:.2f}GiB/chip "
        f"t_comp={report.t_compute*1e3:.2f}ms t_mem={report.t_memory*1e3:.2f}ms "
        f"t_coll={report.t_collective*1e3:.2f}ms bottleneck={report.bottleneck} "
        f"useful={report.useful_fraction:.2f} roofline={report.roofline_fraction:.2f})"
    )
    print("memory_analysis:", json.dumps(memory))
    print("cost_analysis: flops/chip=%.3e bytes/chip=%.3e" % (flops, byts))
    print("collectives:", json.dumps(coll.counts))
    return dataclasses.asdict(report)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    # Hillclimb variant knobs (EXPERIMENTS.md §Perf):
    ap.add_argument("--variant", default="", help="suffix for the report file")
    ap.add_argument("--param-dtype", default=None, choices=[None, "bfloat16"])
    ap.add_argument("--serving-resident", action="store_true",
                    help="decode/prefill: TP-resident weights (no FSDP)")
    ap.add_argument("--microbatch-rows", type=int, default=None)
    ap.add_argument("--gp-tile-dtype", default=None, choices=[None, "bfloat16"])
    ap.add_argument("--moe-per-expert-scatter", action="store_true",
                    help="naive per-expert MoE combine (A/B baseline)")
    ap.add_argument("--remat-policy", default=None, choices=[None, "full", "dots"])
    args = ap.parse_args(argv)
    opts = {
        "param_dtype": args.param_dtype,
        "serving_resident": args.serving_resident,
        "microbatch_rows": args.microbatch_rows,
        "gp_tile_dtype": args.gp_tile_dtype,
        "moe_per_expert_scatter": args.moe_per_expert_scatter,
        "remat_policy": args.remat_policy,
    }
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    ok = True
    for mk in meshes:
        try:
            # Roofline analysis pieces are derived on the single-pod mesh
            # (spec: the roofline table is single-pod; multi-pod proves the
            # "pod" axis shards).
            run_cell(args.arch, args.shape, mk, args.out,
                     analyze=(mk == "single"), opts=opts,
                     variant=args.variant)
        except Exception:
            ok = False
            print(f"[dryrun] {args.arch} x {args.shape} x {mk}: FAILED",
                  file=sys.stderr)
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
