"""Roofline-term extraction from compiled dry-run artifacts.

compute / memory terms come from ``compiled.cost_analysis()`` (per-device
SPMD module: flops and bytes are PER CHIP). The collective term is parsed
from the post-partitioning HLO text (``compiled.as_text()``): cost_analysis
does not cover communication.

Per-collective per-chip transmitted-byte model (bidirectional ring):
  all-reduce       2 * out_bytes * (G-1)/G
  all-gather       out_bytes * (G-1)/G
  reduce-scatter   out_bytes * (G-1)        (= in_bytes * (G-1)/G)
  all-to-all       out_bytes * (G-1)/G
  collective-permute  out_bytes             (one hop)

Terms (seconds, per spec §ROOFLINE):
  compute    = flops_per_chip / peak_flops          [chips cancel]
  memory     = bytes_per_chip / hbm_bw
  collective = coll_bytes_per_chip / link_bw
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_per_chip: float = 0.0
    by_op_bytes: dict = field(default_factory=dict)


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        out_bytes = _shape_bytes(dtype, dims)
        g = max(2, _group_size(line, total_devices))
        if op == "all-reduce":
            b = 2.0 * out_bytes * (g - 1) / g
        elif op == "all-gather":
            b = out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            b = out_bytes * (g - 1)
        elif op == "all-to-all":
            b = out_bytes * (g - 1) / g
        else:  # collective-permute
            b = float(out_bytes)
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.by_op_bytes[op] = stats.by_op_bytes.get(op, 0.0) + b
        stats.bytes_per_chip += b
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_counts: dict
    collective_by_op: dict
    # memory analysis (per chip, bytes)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0
    # derived terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0  # 6 * N_active * D (global)
    useful_fraction: float = 0.0  # model_flops / (flops_per_chip * chips)
    roofline_fraction: float = 0.0  # t_compute_model / max(terms)
    notes: str = ""

    def finalise(self):
        self.t_compute = self.flops_per_chip / PEAK_BF16_FLOPS
        self.t_memory = self.bytes_per_chip / HBM_BW
        self.t_collective = self.collective_bytes_per_chip / ICI_BW
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        self.bottleneck = max(terms, key=terms.get)
        total_flops = self.flops_per_chip * self.chips
        if total_flops > 0 and self.model_flops > 0:
            self.useful_fraction = self.model_flops / total_flops
            ideal = self.model_flops / (self.chips * PEAK_BF16_FLOPS)
            self.roofline_fraction = ideal / max(
                max(terms.values()), 1e-30
            )
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


def extract_cost(compiled) -> tuple[float, float]:
    """(flops, bytes_accessed) per chip from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def extract_memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    if isinstance(ma, (list, tuple)):
        ma = ma[0]
    get = lambda name: int(getattr(ma, name, 0) or 0)
    arg = get("argument_size_in_bytes")
    out = get("output_size_in_bytes")
    tmp = get("temp_size_in_bytes")
    alias = get("alias_size_in_bytes")
    return {
        "argument_bytes": arg,
        "output_bytes": out,
        "temp_bytes": tmp,
        "peak_bytes": arg + out + tmp - alias,
    }
