"""CLI training driver.

Two paths behind one entry point:

  GP (the paper):  --arch gp-iterative --dataset pol --solver ap --pathwise
                   --warm-start --budget 10
  LM substrate:    --arch llama3-8b --smoke (reduced config on local devices)

The GP path runs real optimisation on this host (CPU-feasible n); the LM
path runs the reduced smoke config — full-scale LM runs are launched on a
TPU fleet with the same train_step after the dry-run proves the sharding.
"""
from __future__ import annotations

import argparse
import json
import os

import jax


def run_gp(args):
    from repro.core import OuterConfig, fit, pick_sgd_learning_rate
    from repro.data.synthetic import load_dataset, pad_to_block_multiple
    from repro.gp.hyperparams import HyperParams
    from repro.solvers import SolverConfig
    from repro.train.adam import AdamConfig

    ds = load_dataset(args.dataset, max_n=args.max_n)
    x, y = ds.x_train, ds.y_train
    block = args.block_size if args.solver == "ap" else args.batch_size
    if args.solver in ("ap", "sgd"):
        x, y, _ = pad_to_block_multiple(x, y, block)

    solver = SolverConfig(
        name=args.solver,
        tolerance=args.tolerance,
        max_epochs=args.budget if args.budget > 0 else 1e9,
        precond_rank=args.precond_rank,
        block_size=args.block_size,
        batch_size=args.batch_size,
        learning_rate=args.sgd_lr,
    )
    cfg = OuterConfig(
        estimator="pathwise" if args.pathwise else "standard",
        warm_start=args.warm_start,
        num_probes=args.probes,
        solver=solver,
        adam=AdamConfig(learning_rate=args.lr),
        num_steps=args.steps,
        backend=args.backend,
        bm=args.tile, bn=args.tile,
    )
    key = jax.random.PRNGKey(args.seed)
    if args.solver == "sgd" and args.sgd_lr <= 0:
        lr = pick_sgd_learning_rate(x, y, HyperParams.create(x.shape[1]), cfg,
                                    key)
        print(f"[train] sgd lr grid -> {lr}")
        cfg = OuterConfig(**{**cfg.__dict__, "solver":
                             SolverConfig(**{**solver.__dict__,
                                             "learning_rate": lr})})
    res = fit(
        x, y, cfg, key=key,
        x_test=ds.x_test, y_test=ds.y_test,
        eval_every=args.eval_every,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        verbose=True,
    )
    out = {
        "dataset": ds.name,
        "solver": args.solver,
        "pathwise": args.pathwise,
        "warm_start": args.warm_start,
        "total_time_s": res.wall_time_s,
        "total_epochs": float(res.history["epochs"].sum()),
        "final_res_y": float(res.history["res_y"][-1]),
        "final_res_z": float(res.history["res_z"][-1]),
        "eval_rmse": res.history["eval_rmse"].tolist(),
        "eval_llh": res.history["eval_llh"].tolist(),
    }
    print(json.dumps(out, indent=2))
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)


def run_lm(args):
    from repro.configs import SMOKE_SHAPES, get_config
    from repro.data.synthetic import make_lm_batch
    from repro.models import init_params, make_train_step
    from repro.train.adam import adam_init

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    opt = adam_init(params)
    step = jax.jit(make_train_step(cfg, num_microbatches=1))
    shape = SMOKE_SHAPES["train_4k"]
    for i in range(args.steps):
        batch = make_lm_batch(jax.random.fold_in(key, i), shape.global_batch,
                              shape.seq_len, cfg.vocab_size)
        if cfg.is_encdec:
            batch = {
                "frames": jax.random.normal(
                    jax.random.fold_in(key, 10_000 + i),
                    (shape.global_batch, shape.seq_len, cfg.d_model)),
                "tokens": batch["tokens"][:, : cfg.decoder_len],
                "labels": batch["labels"][:, : cfg.decoder_len],
                "mask": batch["mask"][:, : cfg.decoder_len],
            }
        elif cfg.frontend.kind == "vision":
            npfx = cfg.frontend.num_prefix
            batch["patch_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 20_000 + i),
                (shape.global_batch, npfx, cfg.frontend.embed_dim))
        params, opt, loss = step(params, opt, batch)
        print(f"[train-lm] {args.arch} step {i}: loss={float(loss):.4f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gp-iterative")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=4000)
    ap.add_argument("--solver", default="cg", choices=["cg", "ap", "sgd"])
    ap.add_argument("--pathwise", action="store_true")
    ap.add_argument("--warm-start", action="store_true")
    ap.add_argument("--probes", type=int, default=64)
    ap.add_argument("--budget", type=float, default=0.0,
                    help="solver epochs per outer step; 0 = to tolerance")
    ap.add_argument("--tolerance", type=float, default=0.01)
    ap.add_argument("--precond-rank", type=int, default=100)
    ap.add_argument("--block-size", type=int, default=1000)
    ap.add_argument("--batch-size", type=int, default=500)
    ap.add_argument("--sgd-lr", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--backend", default="streamed",
                    choices=["dense", "streamed", "pallas"])
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.arch == "gp-iterative":
        run_gp(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
