"""Production mesh builders (spec: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = 256 chips/pod single-pod; (2, 16, 16) = 512 chips 2-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_lane_mesh(num_devices: int | None = None):
    """1-D mesh over a ``"lanes"`` axis for data-parallel scenario sweeps.

    Each device owns a contiguous slice of the vmap lane axis of a batched
    sweep (``core.driver.fit_batch(mesh=...)``): lanes are embarrassingly
    parallel, so a ``NamedSharding`` over this mesh turns the one-program
    grid into one program PER DEVICE worth of lanes with no collectives on
    the hot path. Defaults to every local device; CPU tests force virtual
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("lanes",))


# TPU v5e hardware model for the roofline (per chip).
PEAK_BF16_FLOPS = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
