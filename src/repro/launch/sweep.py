"""Run the full dry-run sweep: every runnable (arch x shape) x both meshes.

Each cell runs in a fresh subprocess (jax device-count lock + memory
hygiene); completed cells are skipped on re-run, so the sweep is resumable.

    PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import runnable_cells


def cell_done(out_dir: str, arch: str, shape: str, mesh: str) -> bool:
    return os.path.exists(os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args(argv)

    cells = [c for c in runnable_cells() if c[2] == "run"]
    if args.only_arch:
        cells = [c for c in cells if c[0] == args.only_arch]
    meshes = args.meshes.split(",")
    failures = []
    t_start = time.time()
    for arch, shape, _ in cells:
        for mesh in meshes:
            if cell_done(args.out, arch, shape, mesh):
                print(f"[sweep] skip (done): {arch} x {shape} x {mesh}")
                continue
            t0 = time.time()
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh,
                "--out", args.out,
            ]
            print(f"[sweep] RUN {arch} x {shape} x {mesh}", flush=True)
            try:
                r = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=args.timeout,
                    env={**os.environ, "PYTHONPATH": "src"},
                )
                ok = r.returncode == 0
            except subprocess.TimeoutExpired:
                ok = False
                r = None
            dt = time.time() - t0
            if ok:
                print(f"[sweep] OK  {arch} x {shape} x {mesh} ({dt:.0f}s)",
                      flush=True)
            else:
                failures.append((arch, shape, mesh))
                tail = (r.stderr or r.stdout)[-2000:] if r else "TIMEOUT"
                print(f"[sweep] FAIL {arch} x {shape} x {mesh} ({dt:.0f}s)\n"
                      f"{tail}", flush=True)
    print(f"[sweep] finished in {(time.time()-t_start)/60:.1f} min; "
          f"{len(failures)} failures: {failures}")
    with open(os.path.join(args.out, "_sweep_status.json"), "w") as f:
        json.dump({"failures": failures}, f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
