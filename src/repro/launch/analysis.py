"""Trip-count-corrected roofline cost extraction.

XLA's ``cost_analysis`` counts a ``lax.scan``/``while`` body ONCE, so the
production (scanned) programs undercount flops/bytes/collectives by the trip
counts. This module composes per-cell costs from separately-lowered pieces:

  train   total = M * (A + (P-1) * B) + C
            A = one-microbatch value_and_grad (its period scan counted once)
            B = one period fwd+bwd           (the scan body's true cost)
            C = optimiser update
            M = microbatches, P = periods
            (+ (L_enc-1) * B_enc for the encoder stack of enc-dec archs)
  prefill total = A + (P-1) * B_fwd          (+ encoder correction)
  decode  total = A + (P-1) * B_dec
  gp      analytic tile composition (see gp_analysis)

Every piece is an AOT-lowered SPMD module on the production mesh, so the
per-chip numbers include partitioning effects and collectives. Memory comes
from the production compile (scan does not change peak-memory truth).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import GP_SHAPES, LM_SHAPES, get_config
from repro.launch.hlo_analysis import extract_cost, parse_collectives


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        return Cost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.coll_bytes + o.coll_bytes,
            {k: self.coll_counts.get(k, 0) + o.coll_counts.get(k, 0)
             for k in set(self.coll_counts) | set(o.coll_counts)},
        )

    def __mul__(self, k):
        return Cost(
            self.flops * k, self.bytes * k, self.coll_bytes * k,
            {key: v * k for key, v in self.coll_counts.items()},
        )

    __rmul__ = __mul__


def _cost_of(lowered, chips: int) -> Cost:
    compiled = lowered.compile()
    flops, byts = extract_cost(compiled)
    coll = parse_collectives(compiled.as_text(), chips)
    return Cost(flops, byts, coll.bytes_per_chip, dict(coll.counts))


def _period_shardings(cfg, mesh, params_abs, serving=False):
    """Abstract single-period params + their shardings (leading axis removed)."""
    from repro.models import param_shardings

    one = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
        params_abs["layers"],
    )
    full_sh = param_shardings(cfg, mesh, params_abs, serving=serving)["layers"]
    one_sh = jax.tree.map(
        lambda l, s: NamedSharding(mesh, P(*s.spec[1:])), one, full_sh
    )
    return one, one_sh


def analysis_lm_cell(arch: str, shape_name: str, mesh, opts=None) -> tuple[Cost, dict]:
    """Composed per-chip Cost for an LM cell + piece breakdown."""
    from repro.distributed.sharding import DP, set_global_mesh, valid_spec
    from repro.launch.dryrun import apply_opts
    from repro.models import (
        abstract_params,
        batch_pspec,
        cache_shardings,
        input_specs,
        param_shardings,
    )
    from repro.models.steps import _forward_loss, opt_shardings
    from repro.models.transformer import _apply_block
    from repro.train.adam import AdamConfig, adam_init, adam_update

    opts = opts or {}
    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    cfg, shape = apply_opts(cfg, shape, opts)
    serving = bool(opts.get("serving_resident")) and shape.step != "train"
    set_global_mesh(mesh)
    chips = mesh.devices.size
    params_abs = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh, params_abs, serving=serving)
    period_abs, period_sh = _period_shardings(
        cfg, mesh, params_abs, serving=serving
    )
    pcount = cfg.num_periods
    pieces = {}

    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    def lower_period(batch_rows: int, seq: int, train: bool) -> Cost:
        x_abs = jax.ShapeDtypeStruct((batch_rows, seq, cfg.d_model), cdt)
        x_sh = NamedSharding(
            mesh, valid_spec(mesh, x_abs.shape, (DP, None, None))
        )
        positions = jnp.arange(seq)

        def apply_period(pp, x):
            h = x
            for i, spec in enumerate(cfg.pattern):
                h = _apply_block(pp[f"block_{i}"], h, cfg, spec, positions, None)
            return h

        repl = NamedSharding(mesh, P())
        if train:
            fn = lambda pp, x: jnp.sum(
                apply_period(pp, x).astype(jnp.float32)
            )
            g = jax.value_and_grad(fn, argnums=(0, 1))
            # grads must come back SHARDED like their primals — otherwise
            # XLA replicates them and the piece's bytes/collectives are
            # inflated by the TP x FSDP factor.
            jitted = jax.jit(g, in_shardings=(period_sh, x_sh),
                             out_shardings=(repl, (period_sh, x_sh)))
        else:
            jitted = jax.jit(apply_period, in_shardings=(period_sh, x_sh),
                             out_shardings=x_sh)
        return _cost_of(jitted.lower(period_abs, x_abs), chips)

    if shape.step == "train":
        from repro.launch.dryrun import _num_microbatches

        m = _num_microbatches(shape, mesh)
        specs = input_specs(cfg, shape)["batch"]
        mb = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                (l.shape[0] // m,) + l.shape[1:], l.dtype
            ),
            specs,
        )
        mb_sh = batch_pspec(mb, mesh)
        repl = NamedSharding(mesh, P())
        grad_fn = jax.value_and_grad(lambda p, b: _forward_loss(p, cfg, b))
        a = _cost_of(
            jax.jit(grad_fn, in_shardings=(p_sh, mb_sh),
                    out_shardings=(repl, p_sh)).lower(params_abs, mb),
            chips,
        )
        rows = mb["tokens"].shape[0]
        seq = shape.seq_len if not cfg.is_encdec else cfg.decoder_len
        b_piece = lower_period(rows, seq, train=True)
        opt_abs = jax.eval_shape(adam_init, params_abs)
        o_sh = opt_shardings(mesh, p_sh, opt_abs)
        acfg = AdamConfig(learning_rate=3e-4)
        c = _cost_of(
            jax.jit(
                lambda g, o, p: adam_update(g, o, p, acfg),
                in_shardings=(p_sh, o_sh, p_sh),
                out_shardings=(p_sh, o_sh),
            ).lower(params_abs, opt_abs, params_abs),
            chips,
        )
        total = m * (a + (pcount - 1) * b_piece) + c
        if cfg.is_encdec:  # encoder stack correction (scanned once in A)
            enc_piece = lower_period_encoder(
                cfg, mesh, rows, shape.seq_len, train=True,
                period_args=(period_abs, period_sh), chips=chips,
            )
            total = total + m * (cfg.encoder.num_layers - 1) * enc_piece
            pieces["enc_body"] = dataclasses.asdict(enc_piece)
        pieces.update(
            mb_grad=dataclasses.asdict(a),
            period_body=dataclasses.asdict(b_piece),
            optimizer=dataclasses.asdict(c),
            multipliers={"microbatches": m, "periods": pcount},
        )
        return total, pieces

    if shape.step == "prefill":
        from repro.models import make_prefill_step

        specs = input_specs(cfg, shape)["batch"]
        b_sh = batch_pspec(specs, mesh)
        step_fn = make_prefill_step(cfg)
        logits_abs = jax.eval_shape(step_fn, params_abs, specs)
        from repro.distributed.sharding import TP

        out_sh = NamedSharding(
            mesh, valid_spec(mesh, logits_abs.shape, (DP, None, TP))
        )
        a = _cost_of(
            jax.jit(
                step_fn, in_shardings=(p_sh, b_sh), out_shardings=out_sh
            ).lower(params_abs, specs),
            chips,
        )
        rows = shape.global_batch
        seq = shape.seq_len if not cfg.is_encdec else cfg.decoder_len
        b_piece = lower_period(rows, seq, train=False)
        total = a + (pcount - 1) * b_piece
        if cfg.is_encdec:
            enc_piece = lower_period_encoder(
                cfg, mesh, rows, shape.seq_len, train=False,
                period_args=(period_abs, period_sh), chips=chips,
            )
            total = total + (cfg.encoder.num_layers - 1) * enc_piece
            pieces["enc_body"] = dataclasses.asdict(enc_piece)
        pieces.update(full_once=dataclasses.asdict(a),
                      period_body=dataclasses.asdict(b_piece),
                      multipliers={"periods": pcount})
        return total, pieces

    # decode
    from repro.models import make_serve_step

    specs = input_specs(cfg, shape)
    c_sh = cache_shardings(cfg, mesh, specs["cache"])
    tok_sh = NamedSharding(
        mesh, valid_spec(mesh, (shape.global_batch,), (DP,))
    )
    repl = NamedSharding(mesh, P())
    from repro.distributed.sharding import TP

    serve_fn = make_serve_step(cfg)
    logits_abs, _ = jax.eval_shape(
        serve_fn, params_abs, specs["cache"], specs["tokens"], specs["pos"]
    )
    log_sh = NamedSharding(mesh, valid_spec(mesh, logits_abs.shape, (DP, TP)))
    a = _cost_of(
        jax.jit(
            serve_fn,
            in_shardings=(p_sh, c_sh, tok_sh, repl),
            out_shardings=(log_sh, c_sh),
        ).lower(params_abs, specs["cache"], specs["tokens"], specs["pos"]),
        chips,
    )

    # one-period decode body
    period_cache = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), specs["cache"]
    )
    period_cache_sh = jax.tree.map(
        lambda l, s: NamedSharding(mesh, P(*s.spec[1:])),
        period_cache, c_sh,
    )
    x_abs = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.d_model), cdt)
    x_sh = NamedSharding(mesh, valid_spec(mesh, x_abs.shape, (DP, None, None)))

    def period_decode(pp, pc, x, pos):
        from repro.models.layers import (
            attention_decode,
            cross_attention_decode,
            mlp,
            moe_ffn,
            rms_norm,
        )
        from repro.models.ssm import mamba_decode
        from repro.models.config import MAMBA

        h = x
        for i, spec in enumerate(cfg.pattern):
            bp, bc = pp[f"block_{i}"], pc[f"block_{i}"]
            if spec.kind == MAMBA:
                y, _ = mamba_decode(
                    bp["mamba"], rms_norm(h, bp["mamba"]["ln"], cfg.norm_eps),
                    {"conv": bc["conv"], "ssm": bc["ssm"]}, cfg,
                )
            else:
                y, _ = attention_decode(
                    bp["attn"], rms_norm(h, bp["attn"]["ln"], cfg.norm_eps),
                    {"k": bc["k"], "v": bc["v"]}, pos, cfg, spec,
                )
            h = h + y
            if cfg.is_encdec and "cross" in bp:
                h = h + cross_attention_decode(
                    bp["cross"], rms_norm(h, bp["cross"]["ln"], cfg.norm_eps),
                    bc, cfg,
                )
            if "ffn" in bp:
                z = rms_norm(h, bp["ffn"]["ln"], cfg.norm_eps)
                h = h + (moe_ffn(bp["ffn"], z, cfg)
                         if (spec.moe and cfg.moe) else mlp(bp["ffn"], z, cfg))
        return h

    b_piece = _cost_of(
        jax.jit(
            period_decode,
            in_shardings=(period_sh, period_cache_sh, x_sh, repl),
            out_shardings=x_sh,
        ).lower(period_abs, period_cache, x_abs, specs["pos"]),
        chips,
    )
    total = a + (pcount - 1) * b_piece
    pieces.update(full_once=dataclasses.asdict(a),
                  period_body=dataclasses.asdict(b_piece),
                  multipliers={"periods": pcount})
    return total, pieces


def lower_period_encoder(cfg, mesh, rows, seq, train, period_args, chips):
    """One encoder layer fwd(+bwd) cost (whisper stack correction)."""
    from repro.distributed.sharding import DP, valid_spec
    from repro.models import param_shardings
    from repro.models.config import ATTN_BIDIR, LayerSpec
    from repro.models.transformer import _apply_block, abstract_params

    params_abs = abstract_params(cfg)
    enc_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
        params_abs["encoder"]["layers"],
    )
    full_sh = param_shardings(cfg, mesh, params_abs)["encoder"]["layers"]
    enc_sh = jax.tree.map(
        lambda l, s: NamedSharding(mesh, P(*s.spec[1:])), enc_abs, full_sh
    )
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x_abs = jax.ShapeDtypeStruct((rows, seq, cfg.d_model), cdt)
    x_sh = NamedSharding(mesh, valid_spec(mesh, x_abs.shape, (DP, None, None)))
    spec = LayerSpec(kind=ATTN_BIDIR)
    positions = jnp.arange(seq)

    def apply_one(pp, x):
        return _apply_block(pp["block_0"], x, cfg, spec, positions, None)

    repl = NamedSharding(mesh, P())
    if train:
        fn = lambda pp, x: jnp.sum(apply_one(pp, x).astype(jnp.float32))
        jitted = jax.jit(jax.value_and_grad(fn, argnums=(0, 1)),
                         in_shardings=(enc_sh, x_sh),
                         out_shardings=(repl, (enc_sh, x_sh)))
    else:
        jitted = jax.jit(apply_one, in_shardings=(enc_sh, x_sh),
                         out_shardings=x_sh)
    return _cost_of(jitted.lower(enc_abs, x_abs), chips)


def analysis_gp_cell(shape_name: str, mesh, opts=None) -> tuple[Cost, dict]:
    """GP cell: tile-composition analysis.

    ring sweeps = epochs (CG scan) + 1 (initial residual) + 1 (grad fwd);
    the grad backward re-runs each tile (remat) + its cotangent math
    (~2x fwd flops). Rotation traffic: (x_loc + v_loc) bytes per step,
    chips steps per sweep, one extra sweep-equivalent for AD transposes.
    """
    from repro.configs.gp_iterative import CONFIG as GP_CFG
    from repro.gp.hyperparams import HyperParams
    from repro.gp.kernels_math import profile_from_r2, scaled_sqdist

    opts = opts or {}
    tile_dtype = (jnp.bfloat16 if opts.get("gp_tile_dtype") == "bfloat16"
                  else jnp.float32)
    shape = GP_SHAPES[shape_name]
    chips = mesh.devices.size
    n_loc = shape.n // chips
    s = shape.num_probes
    d = shape.d

    params = HyperParams.create(d)

    def tile(u, w, v):
        ut = (u / params.lengthscales).astype(tile_dtype)
        wt = (w / params.lengthscales).astype(tile_dtype)
        r2 = scaled_sqdist(ut, wt, jnp.ones((), tile_dtype))
        k = profile_from_r2(GP_CFG.kind)(r2, params.signal.astype(tile_dtype))
        return jax.lax.dot(k, v.astype(tile_dtype),
                           preferred_element_type=jnp.float32)

    f32 = jnp.float32
    u_abs = jax.ShapeDtypeStruct((n_loc, d), f32)
    v_abs = jax.ShapeDtypeStruct((n_loc, 1 + s), f32)
    t_fwd = _cost_of(jax.jit(tile).lower(u_abs, u_abs, v_abs), 1)

    g = jax.grad(lambda u, w, v: jnp.sum(tile(u, w, v)), argnums=(0, 1, 2))
    t_bwd = _cost_of(jax.jit(g).lower(u_abs, u_abs, v_abs), 1)

    sweeps_fwd = shape.solver_epochs + 2
    tiles_fwd = sweeps_fwd * chips
    tiles_bwd = chips
    total = tiles_fwd * t_fwd + tiles_bwd * t_bwd

    itemsize = 2 if tile_dtype == jnp.bfloat16 else 4
    rot_bytes = (n_loc * d + n_loc * (1 + s)) * itemsize
    sweeps_comm = sweeps_fwd + 2  # AD transpose permutes
    # Per chip: ``chips`` rotation steps per sweep, each moving rot_bytes.
    total.coll_bytes += rot_bytes * chips * sweeps_comm
    total.coll_counts["collective-permute"] = (
        total.coll_counts.get("collective-permute", 0)
        + sweeps_comm * chips
    )
    # CG column dots: all-reduce of (1+s) scalars per iteration — negligible
    # bytes, counted for completeness.
    total.coll_counts["all-reduce"] = shape.solver_epochs * 3
    pieces = {
        "tile_fwd": dataclasses.asdict(t_fwd),
        "tile_bwd": dataclasses.asdict(t_bwd),
        "multipliers": {
            "tiles_fwd": tiles_fwd, "tiles_bwd": tiles_bwd,
            "rot_bytes_per_step": rot_bytes,
        },
    }
    return total, pieces
