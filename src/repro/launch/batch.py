"""One-program multi-scenario sweeps: kernel x seed grids as vmap lanes.

Partitions a ``configs.gp_iterative.KERNEL_SWEEP`` x seed grid by static
signature — kernel kind, solver name, estimator, shapes — and runs each
group as ONE process and ONE compiled executable: seeds become vmap lanes
inside a single scan-of-steps program (``core.driver.fit_batch``), instead
of the one-subprocess-per-cell pattern of ``launch.sweep``. Per-cell JSON
artifacts and the ``_sweep_status.json`` summary keep the sweep-output
conventions (done cells are skipped on re-run, so the sweep is resumable).

    PYTHONPATH=src python -m repro.launch.batch --out artifacts/batch \
        --dataset pol --max-n 512 --kernels matern12,matern32 --seeds 2 \
        --steps 5 --smoke

``--isolate`` falls back to one subprocess per cell (jax memory hygiene /
fault isolation, as in ``launch.sweep``); the artifacts are identical, so
the two modes are interchangeable and A/B-able (benchmarks/batched_sweep).
``--expect-one-compile-per-group`` asserts the one-executable contract via
jit-cache retrace counting and fails the run when it is violated.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

from repro.configs.gp_iterative import KERNEL_SWEEP, SMOKE, GPArchConfig


def cell_filename(arch_name: str, seed: int) -> str:
    return f"{arch_name}__s{seed}.json"


def cell_done(out_dir: str, arch_name: str, seed: int) -> bool:
    return os.path.exists(os.path.join(out_dir, cell_filename(arch_name, seed)))


def sweep_archs(kernels: list[str] | None, smoke: bool) -> list[GPArchConfig]:
    """KERNEL_SWEEP entries (optionally filtered), at SMOKE sizes if asked."""
    archs = list(KERNEL_SWEEP)
    if kernels:
        archs = [a for a in archs if a.kind in kernels]
        missing = set(kernels) - {a.kind for a in archs}
        if missing:
            raise KeyError(f"kernels not in KERNEL_SWEEP: {sorted(missing)}")
    if smoke:
        archs = [
            dataclasses.replace(
                a, num_probes=SMOKE.num_probes,
                num_rff_pairs=SMOKE.num_rff_pairs,
                solver_epochs=SMOKE.solver_epochs,
            )
            for a in archs
        ]
    return archs


def outer_config_for(arch: GPArchConfig, args):
    """The (static, hashable) OuterConfig of one sweep cell."""
    from repro.core import OuterConfig
    from repro.solvers import SolverConfig

    solver = args.solver or arch.solver
    scfg = SolverConfig(
        name=solver,
        tolerance=args.tolerance,
        kind=arch.kind,
        max_epochs=float(arch.solver_epochs),
        precond_rank=arch.precond_rank,
        block_size=args.block_size,
        batch_size=args.batch_size,
        learning_rate=args.sgd_lr,
    )
    return OuterConfig(
        estimator=arch.estimator,
        warm_start=arch.warm_start,
        num_probes=arch.num_probes,
        num_rff_pairs=arch.num_rff_pairs,
        kind=arch.kind,
        solver=scfg,
        num_steps=args.steps,
        bm=args.bm,
        bn=args.bn,
    )


def group_cells(archs: list[GPArchConfig], args):
    """Static signature -> member archs.

    The signature is the jit static argument itself (the hashable
    OuterConfig); cells that share it share one executable. With a shared
    dataset that means one group per kernel kind here, but the partition
    stays correct for any future per-cell config divergence.
    """
    groups: dict = {}
    for arch in archs:
        groups.setdefault(outer_config_for(arch, args), []).append(arch)
    return groups


def _load_data(archs: list[GPArchConfig], args):
    """Shared (x, y), padded for every block solver any cell will run."""
    import math

    from repro.data.synthetic import load_dataset, pad_to_block_multiple

    ds = load_dataset(args.dataset, max_n=args.max_n, split=args.split)
    x, y = ds.x_train, ds.y_train
    solvers = {args.solver or a.solver for a in archs}
    blocks = [args.block_size if s == "ap" else args.batch_size
              for s in solvers if s in ("ap", "sgd")]
    if blocks:
        x, y, _ = pad_to_block_multiple(x, y, math.lcm(*blocks))
    return x, y


def _cell_record(arch: GPArchConfig, seed: int, res, mode: str,
                 group_size: int) -> dict:
    hist = res.history
    return {
        "arch": arch.name,
        "kernel": arch.kind,
        "seed": seed,
        "mode": mode,
        "lanes": group_size,
        "wall_time_s": res.wall_time_s,
        "solver_time_s": res.solver_time_s,
        "grad_time_s": res.grad_time_s,
        "final_hypers": [float(v) for v in hist["hypers"][-1]],
        "history": {
            "res_y": [float(v) for v in hist["res_y"]],
            "res_z": [float(v) for v in hist["res_z"]],
            "iters": [int(v) for v in hist["iters"]],
            "epochs": [float(v) for v in hist["epochs"]],
            "solver_frac_iters": [float(v) for v in hist["solver_frac_iters"]],
        },
    }


def _write_cell(out_dir: str, arch: GPArchConfig, seed: int, record: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_filename(arch.name, seed)), "w") as f:
        json.dump(record, f, indent=2)


def _scan_cache_size():
    """jit-cache size of ``core.outer.outer_scan`` — the retrace counter.

    Returns None (never 0) when the private jit introspection API is
    unavailable, so one-compile-per-group checks cannot pass vacuously
    (same contract as ``serve.engine.num_compiles``)."""
    from repro.core.outer import outer_scan

    try:
        return int(outer_scan._cache_size())
    except Exception:  # noqa: BLE001 - private API; absence is not an error
        return None


def run_batched(archs, seeds, x, y, args) -> dict:
    """All groups in-process: one fit_batch (= one executable) per group.

    Every cell of a group — across member archs, not just across seeds —
    joins the same fit_batch call, so a group really is one program."""
    import jax

    from repro.core import fit_batch

    compiles0 = _scan_cache_size()
    failures, num_groups, num_cells = [], 0, 0
    groups = group_cells(archs, args)
    for cfg, members in groups.items():
        cells = [(arch, s) for arch in members for s in seeds]
        todo = [(arch, s) for arch, s in cells
                if not cell_done(args.out, arch.name, s)]
        for arch, s in cells:
            if (arch, s) not in todo:
                print(f"[batch] skip (done): {arch.name} s{s}")
        if not todo:
            continue
        num_groups += 1
        label = ",".join(sorted({arch.name for arch, _ in todo}))
        t0 = time.time()
        keys = jax.numpy.stack([jax.random.PRNGKey(s) for _, s in todo])
        try:
            results = fit_batch(x, y, cfg, keys)
        except Exception as e:  # noqa: BLE001 - sweep must keep going
            print(f"[batch] FAIL group {label}: {e}", file=sys.stderr)
            failures.extend([(arch.name, s) for arch, s in todo])
            continue
        dt = time.time() - t0
        print(f"[batch] OK {label} x {len(todo)} lanes ({dt:.1f}s)",
              flush=True)
        for (arch, s), res in zip(todo, results):
            _write_cell(args.out, arch, s,
                        _cell_record(arch, s, res, "batched", len(todo)))
            num_cells += 1
    compiles1 = _scan_cache_size()
    num_compiles = (None if compiles0 is None or compiles1 is None
                    else compiles1 - compiles0)
    return {
        "failures": failures,
        "groups": num_groups,
        "num_compiles": num_compiles,
        "cells": num_cells,
        "mode": "batched",
    }


def run_isolated(archs, seeds, args, argv_passthrough: list[str]) -> dict:
    """Subprocess-per-cell fallback (the legacy ``launch.sweep`` pattern)."""
    failures, num_cells = [], 0
    for arch in archs:
        for s in seeds:
            if cell_done(args.out, arch.name, s):
                print(f"[batch] skip (done): {arch.name} s{s}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.batch",
                "--only-cell", f"{arch.kind}:{s}",
            ] + argv_passthrough
            # Workers must import repro regardless of cwd / install mode:
            # prepend this package's src dir, keep the inherited PYTHONPATH.
            src = os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))
            inherited = os.environ.get("PYTHONPATH")
            pypath = src + (os.pathsep + inherited if inherited else "")
            t0 = time.time()
            r = subprocess.run(
                cmd, capture_output=True, text=True, timeout=args.timeout,
                env={**os.environ, "PYTHONPATH": pypath},
            )
            dt = time.time() - t0
            if r.returncode == 0:
                num_cells += 1
                print(f"[batch] OK {arch.name} s{s} ({dt:.1f}s)", flush=True)
            else:
                failures.append((arch.name, s))
                print(f"[batch] FAIL {arch.name} s{s} ({dt:.1f}s)\n"
                      f"{(r.stderr or r.stdout)[-2000:]}", flush=True)
    return {
        "failures": failures,
        "groups": num_cells,  # one executable (and process) per cell
        "num_compiles": None,  # spread over subprocesses; unknowable here
        "cells": num_cells,
        "mode": "isolated",
    }


def run_single_cell(archs, args) -> int:
    """--only-cell kernel:seed — one cell in this process (isolate worker)."""
    import jax

    from repro.core import fit

    kind, seed = args.only_cell.rsplit(":", 1)
    seed = int(seed)
    matches = [a for a in archs if a.kind == kind]
    if not matches:
        print(f"[batch] unknown cell kernel {kind!r}", file=sys.stderr)
        return 1
    arch = matches[0]
    cfg = outer_config_for(arch, args)
    x, y = _load_data([arch], args)
    res = fit(x, y, cfg, key=jax.random.PRNGKey(seed), steps_per_round=0)
    _write_cell(args.out, arch, seed,
                _cell_record(arch, seed, res, "isolated", 1))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/batch")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=512)
    ap.add_argument("--split", type=int, default=0)
    ap.add_argument("--kernels", default=None,
                    help="comma list (default: every KERNEL_SWEEP kernel)")
    ap.add_argument("--seeds", type=int, default=2,
                    help="seed grid 0..seeds-1 per kernel")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="SMOKE probe/RFF/budget sizes")
    ap.add_argument("--solver", default=None, choices=[None, "cg", "ap", "sgd"],
                    help="override the sweep's solver")
    ap.add_argument("--tolerance", type=float, default=0.01)
    ap.add_argument("--block-size", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--sgd-lr", type=float, default=2.0)
    ap.add_argument("--bm", type=int, default=256)
    ap.add_argument("--bn", type=int, default=256)
    ap.add_argument("--isolate", action="store_true",
                    help="legacy one-subprocess-per-cell sweep")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only-cell", default=None,
                    help="internal: run one kernel:seed cell in-process")
    ap.add_argument("--expect-one-compile-per-group", action="store_true",
                    help="fail unless retraces == executed groups")
    args = ap.parse_args(argv)

    kernels = args.kernels.split(",") if args.kernels else None
    archs = sweep_archs(kernels, args.smoke)
    seeds = list(range(args.seeds))

    if args.only_cell:
        return run_single_cell(archs, args)

    t0 = time.time()
    if args.isolate:
        # Reconstruct the cell-relevant flags for the worker subprocesses.
        passthrough = [
            "--out", args.out, "--dataset", args.dataset,
            "--max-n", str(args.max_n), "--split", str(args.split),
            "--steps", str(args.steps), "--tolerance", str(args.tolerance),
            "--block-size", str(args.block_size),
            "--batch-size", str(args.batch_size),
            "--sgd-lr", str(args.sgd_lr),
            "--bm", str(args.bm), "--bn", str(args.bn),
        ]
        if args.smoke:
            passthrough.append("--smoke")
        if args.solver:
            passthrough += ["--solver", args.solver]
        status = run_isolated(archs, seeds, args, passthrough)
    else:
        x, y = _load_data(archs, args)
        status = run_batched(archs, seeds, x, y, args)

    status["wall_time_s"] = time.time() - t0
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "_sweep_status.json"), "w") as f:
        json.dump(status, f, indent=2)
    print(f"[batch] {status['cells']} cells in {status['wall_time_s']:.1f}s "
          f"({status['groups']} groups, compiles={status['num_compiles']}, "
          f"{len(status['failures'])} failures)")

    ok = not status["failures"]
    if args.expect_one_compile_per_group and not args.isolate:
        if status["num_compiles"] is None:
            # Introspection unavailable must FAIL the check, not pass it
            # vacuously (cf. serve.engine.num_compiles contract).
            print("[batch] RETRACE CHECK UNAVAILABLE: jit cache "
                  "introspection missing", file=sys.stderr)
            ok = False
        elif status["num_compiles"] != status["groups"]:
            print(f"[batch] RETRACE VIOLATION: {status['num_compiles']} "
                  f"compiles for {status['groups']} groups", file=sys.stderr)
            ok = False
        else:
            print(f"[batch] one executable per group verified "
                  f"({status['groups']} groups == {status['num_compiles']} "
                  f"compiles)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
