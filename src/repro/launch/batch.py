"""One-program multi-scenario sweeps: kernel x seed x solver-config grids
as vmap lanes.

Partitions a ``configs.gp_iterative.KERNEL_SWEEP`` x seed x numerics grid
by STATIC signature — kernel kind, solver name, estimator, shapes — and
runs each group as ONE process and ONE compiled executable: seeds become
vmap lanes inside a single scan-of-steps program (``core.driver.fit_batch``)
instead of the one-subprocess-per-cell pattern of ``launch.sweep``, and
numeric solver settings (tolerance / epoch budget / SGD lr — a sweep over
the paper's early-stopping and compute-budget knobs) ride as a lane-stacked
traced ``SolverNumerics`` pytree, so a tolerance x lr grid does NOT retrace.
A ``--precond-ranks`` grid is the static counterexample: rank changes the
preconditioner's shapes, so each rank is its own group (and executable) and
its cells carry an ``__rk<r>`` artifact tag.
Per-cell JSON artifacts and the ``_sweep_status.json`` summary keep the
sweep-output conventions (done cells are skipped on re-run, so the sweep is
resumable).

    PYTHONPATH=src python -m repro.launch.batch --out artifacts/batch \
        --dataset pol --max-n 512 --kernels matern12,matern32 --seeds 2 \
        --steps 5 --smoke --tolerances 0.01,0.05 --sgd-lrs 0.5,1.0

``--shard-lanes`` additionally shards the lane axis of every group across
the local devices (1-D lane mesh, ``launch.mesh.make_lane_mesh``): the same
one-executable program runs data-parallel over lanes, which is how a TPU
slice runs the whole grid at full occupancy. Groups whose lane count does
not divide the device count fall back to the unsharded path with a note.

``--isolate`` falls back to one subprocess per cell (jax memory hygiene /
fault isolation, as in ``launch.sweep``); the artifacts are identical, so
the two modes are interchangeable and A/B-able (benchmarks/batched_sweep,
benchmarks/sharded_sweep). ``--expect-one-compile-per-group`` asserts the
one-executable contract via jit-cache retrace counting and fails the run
when it is violated.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import NamedTuple, Optional

from repro.configs.gp_iterative import KERNEL_SWEEP, SMOKE, GPArchConfig


class Cell(NamedTuple):
    """One sweep cell: an arch at one seed and one solver setting.

    ``rank`` (preconditioner rank) is the one STATIC solver axis a sweep
    may grid over: unlike the traced tolerance/lr/budget axes it changes
    array shapes, so cells differing in rank land in different static
    groups (one executable per rank — the minimal form of the ROADMAP
    per-lane-preconditioner follow-up, which needs shape bucketing to go
    further).
    """

    arch: GPArchConfig
    seed: int
    tolerance: float
    lr: float
    epochs: float
    rank: int  # preconditioner rank (static: partitions groups)
    tag: str  # filename suffix for the numeric axes ("" for 1-point grids)


def cell_filename(arch_name: str, seed: int, tag: str = "") -> str:
    return f"{arch_name}__s{seed}{tag}.json"


def cell_done(out_dir: str, arch_name: str, seed: int, tag: str = "") -> bool:
    return os.path.exists(
        os.path.join(out_dir, cell_filename(arch_name, seed, tag))
    )


def sweep_archs(kernels: list[str] | None, smoke: bool) -> list[GPArchConfig]:
    """KERNEL_SWEEP entries (optionally filtered), at SMOKE sizes if asked."""
    archs = list(KERNEL_SWEEP)
    if kernels:
        archs = [a for a in archs if a.kind in kernels]
        missing = set(kernels) - {a.kind for a in archs}
        if missing:
            raise KeyError(f"kernels not in KERNEL_SWEEP: {sorted(missing)}")
    if smoke:
        archs = [
            dataclasses.replace(
                a, num_probes=SMOKE.num_probes,
                num_rff_pairs=SMOKE.num_rff_pairs,
                solver_epochs=SMOKE.solver_epochs,
            )
            for a in archs
        ]
    return archs


def _parse_grid(text: Optional[str], default: float) -> list[float]:
    if not text:
        return [default]
    return [float(v) for v in text.split(",")]


def make_cells(archs: list[GPArchConfig], seeds: list[int], args) -> list[Cell]:
    """arch x seed x tolerance x lr x epoch-budget x precond-rank grid, with
    filename tags only for the solver axes that actually have more than one
    point (so plain kernel x seed sweeps keep their legacy artifact names)."""
    tols = _parse_grid(args.tolerances, args.tolerance)
    lrs = _parse_grid(args.sgd_lrs, args.sgd_lr)
    budgets = _parse_grid(getattr(args, "epoch_budgets", None), 0.0)
    # Preconditioner ranks are ints and STATIC (see Cell); None defers to
    # each arch's own precond_rank.
    ranks_text = getattr(args, "precond_ranks", None)
    ranks = ([int(v) for v in ranks_text.split(",")] if ranks_text
             else [None])
    cells = []
    seen: set = set()  # colliding grid points (e.g. "0.01,0.01", or an
    # explicit budget equal to the arch default with 0 also given) would
    # otherwise run redundant lanes AND write the same artifact path twice.
    for arch in archs:
        for seed in seeds:
            for tol in tols:
                for lr in lrs:
                    for ep in budgets:
                        for rk in ranks:
                            epochs = ep or float(arch.solver_epochs)
                            rank = rk if rk is not None else arch.precond_rank
                            parts = []
                            if len(tols) > 1:
                                parts.append(f"tol{tol:g}")
                            if len(lrs) > 1:
                                parts.append(f"lr{lr:g}")
                            if len(budgets) > 1:
                                parts.append(f"ep{epochs:g}")
                            if len(ranks) > 1:
                                parts.append(f"rk{rank:g}")
                            tag = "".join("__" + p for p in parts)
                            cell = Cell(arch, seed, tol, lr, epochs, rank,
                                        tag)
                            if cell not in seen:
                                seen.add(cell)
                                cells.append(cell)
    # Distinct cells must not share an artifact path (the %g tags keep 6
    # significant digits): a silent collision would overwrite one cell's
    # JSON with another's and make the loser unrecoverable on resume.
    by_path: dict = {}
    for c in cells:
        path = cell_filename(c.arch.name, c.seed, c.tag)
        if path in by_path:
            raise ValueError(
                f"grid cells {by_path[path][2:-1]} and {c[2:-1]} collide on "
                f"artifact name {path!r}; choose grid values that differ "
                f"within 6 significant digits"
            )
        by_path[path] = c
    return cells


def solver_config_for(arch: GPArchConfig, args, cell: Optional[Cell] = None):
    """The FULL per-cell SolverConfig (numeric values included)."""
    from repro.solvers import SolverConfig

    solver = args.solver or arch.solver
    return SolverConfig(
        name=solver,
        tolerance=cell.tolerance if cell else args.tolerance,
        kind=arch.kind,
        max_epochs=float(cell.epochs if cell else arch.solver_epochs),
        precond_rank=cell.rank if cell else arch.precond_rank,
        block_size=args.block_size,
        batch_size=args.batch_size,
        learning_rate=cell.lr if cell else args.sgd_lr,
    )


def outer_config_for(arch: GPArchConfig, args, cell: Optional[Cell] = None,
                     static: bool = False):
    """The OuterConfig of one sweep cell.

    ``static=True`` strips the solver's numeric fields to their canonical
    defaults (``solvers.strip_numerics``): the result is the hashable GROUP
    KEY — and the jit static argument — under which every numeric cell of
    the grid shares one executable, with the actual numbers delivered as a
    lane-stacked traced ``SolverNumerics``.
    """
    from repro.core import OuterConfig
    from repro.solvers import strip_numerics

    scfg = solver_config_for(arch, args, cell)
    if static:
        scfg = strip_numerics(scfg)
    return OuterConfig(
        estimator=arch.estimator,
        warm_start=arch.warm_start,
        num_probes=arch.num_probes,
        num_rff_pairs=arch.num_rff_pairs,
        kind=arch.kind,
        solver=scfg,
        num_steps=args.steps,
        bm=args.bm,
        bn=args.bn,
    )


def cell_numerics(cell: Cell, args):
    """The cell's traced numeric settings (scalar-leaf SolverNumerics)."""
    from repro.solvers import numerics_of

    return numerics_of(solver_config_for(cell.arch, args, cell))


def group_cells(cells: list[Cell], args):
    """Static signature -> member cells.

    The signature is the jit static argument itself (the hashable
    numerics-stripped OuterConfig); cells that share it share one
    executable. With a shared dataset that means one group per kernel kind
    x preconditioner rank — the tolerance/lr/budget grid rides as traced
    lane data, while a ``--precond-ranks`` grid partitions (rank changes
    the preconditioner's shapes, so mixing ranks in one lane group is
    impossible without shape bucketing) — and the partition stays correct
    for any future per-cell static divergence.
    """
    groups: dict = {}
    for cell in cells:
        key = outer_config_for(cell.arch, args, cell, static=True)
        groups.setdefault(key, []).append(cell)
    return groups


def _load_data(archs: list[GPArchConfig], args):
    """Shared (x, y), padded for every block solver any cell will run."""
    import math

    from repro.data.synthetic import load_dataset, pad_to_block_multiple

    ds = load_dataset(args.dataset, max_n=args.max_n, split=args.split)
    x, y = ds.x_train, ds.y_train
    solvers = {args.solver or a.solver for a in archs}
    blocks = [args.block_size if s == "ap" else args.batch_size
              for s in solvers if s in ("ap", "sgd")]
    if blocks:
        x, y, _ = pad_to_block_multiple(x, y, math.lcm(*blocks))
    return x, y


def _cell_record(cell: Cell, res, mode: str, group_size: int) -> dict:
    hist = res.history
    return {
        "arch": cell.arch.name,
        "kernel": cell.arch.kind,
        "seed": cell.seed,
        "tolerance": cell.tolerance,
        "learning_rate": cell.lr,
        "max_epochs": cell.epochs,
        "precond_rank": cell.rank,
        "mode": mode,
        "lanes": group_size,
        "wall_time_s": res.wall_time_s,
        "solver_time_s": res.solver_time_s,
        "grad_time_s": res.grad_time_s,
        "final_hypers": [float(v) for v in hist["hypers"][-1]],
        "history": {
            "res_y": [float(v) for v in hist["res_y"]],
            "res_z": [float(v) for v in hist["res_z"]],
            "iters": [int(v) for v in hist["iters"]],
            "epochs": [float(v) for v in hist["epochs"]],
            "solver_frac_iters": [float(v) for v in hist["solver_frac_iters"]],
        },
    }


def _write_cell(out_dir: str, cell: Cell, record: dict):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, cell_filename(cell.arch.name, cell.seed, cell.tag)
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=2)


def _scan_cache_size():
    """jit-cache size of ``core.outer.outer_scan`` — the retrace counter.

    Returns None (never 0) when the private jit introspection API is
    unavailable, so one-compile-per-group checks cannot pass vacuously
    (same contract as ``serve.engine.num_compiles``)."""
    from repro.core.outer import outer_scan

    try:
        return int(outer_scan._cache_size())
    except Exception:  # noqa: BLE001 - private API; absence is not an error
        return None


def run_batched(cells, x, y, args) -> dict:
    """All groups in-process: one fit_batch (= one executable) per group.

    Every cell of a group — across member archs AND across the numeric
    tolerance/lr/budget grid, not just across seeds — joins the same
    fit_batch call, so a group really is one program. ``--shard-lanes``
    additionally places the lane axis on a 1-D device mesh."""
    import jax

    from repro.core import fit_batch
    from repro.solvers import stack_numerics

    mesh = None
    if args.shard_lanes:
        from repro.launch.mesh import make_lane_mesh

        mesh = make_lane_mesh()
        print(f"[batch] lane mesh: {mesh.devices.size} device(s)")

    compiles0 = _scan_cache_size()
    failures, num_groups, num_cells = [], 0, 0
    sharded_groups = 0
    groups = group_cells(cells, args)
    for cfg, members in groups.items():
        todo = [c for c in members
                if not cell_done(args.out, c.arch.name, c.seed, c.tag)]
        for c in members:
            if c not in todo:
                print(f"[batch] skip (done): {c.arch.name} s{c.seed}{c.tag}")
        if not todo:
            continue
        num_groups += 1
        label = ",".join(sorted({c.arch.name for c in todo}))
        t0 = time.time()
        keys = jax.numpy.stack([jax.random.PRNGKey(c.seed) for c in todo])
        nums = stack_numerics([cell_numerics(c, args) for c in todo])
        group_mesh = mesh
        if mesh is not None and len(todo) % mesh.devices.size != 0:
            print(f"[batch] note: group {label} has {len(todo)} lanes, not "
                  f"a multiple of {mesh.devices.size} devices; running "
                  f"unsharded")
            group_mesh = None
        try:
            results = fit_batch(x, y, cfg, keys, numerics=nums,
                                mesh=group_mesh)
        except Exception as e:  # noqa: BLE001 - sweep must keep going
            print(f"[batch] FAIL group {label}: {e}", file=sys.stderr)
            failures.extend(
                [(c.arch.name, c.seed, c.tag) for c in todo])
            continue
        dt = time.time() - t0
        if group_mesh is not None:
            sharded_groups += 1
        shard_note = (f", sharded x{mesh.devices.size}"
                      if group_mesh is not None else "")
        print(f"[batch] OK {label} x {len(todo)} lanes ({dt:.1f}s"
              f"{shard_note})", flush=True)
        for c, res in zip(todo, results):
            _write_cell(args.out, c, _cell_record(c, res, "batched",
                                                  len(todo)))
            num_cells += 1
    compiles1 = _scan_cache_size()
    num_compiles = (None if compiles0 is None or compiles1 is None
                    else compiles1 - compiles0)
    return {
        "failures": failures,
        "groups": num_groups,
        "num_compiles": num_compiles,
        "cells": num_cells,
        "mode": "batched",
        # Only claim sharding that actually happened: a mesh was built AND
        # at least one executed group used it (groups whose lane count does
        # not divide the device count fall back to unsharded).
        "shard_devices": (mesh.devices.size
                          if mesh is not None and sharded_groups else 0),
        "sharded_groups": sharded_groups,
    }


def run_isolated(cells, args, argv_passthrough: list[str]) -> dict:
    """Subprocess-per-cell fallback (the legacy ``launch.sweep`` pattern).

    Each cell's numeric settings travel as plain worker flags — one process
    AND one executable per numeric cell, which is exactly the compile cost
    the traced-numerics batched path amortises away
    (benchmarks/sharded_sweep A/Bs the two)."""
    failures, num_cells = [], 0
    for c in cells:
        if cell_done(args.out, c.arch.name, c.seed, c.tag):
            print(f"[batch] skip (done): {c.arch.name} s{c.seed}{c.tag}")
            continue
        cmd = [
            sys.executable, "-m", "repro.launch.batch",
            "--only-cell", f"{c.arch.kind}:{c.seed}",
            "--tolerance", str(c.tolerance),
            "--sgd-lr", str(c.lr),
            "--solver-epochs", str(c.epochs),
            "--precond-rank", str(c.rank),
        ] + (["--cell-tag", c.tag] if c.tag else []) + argv_passthrough
        # Workers must import repro regardless of cwd / install mode:
        # prepend this package's src dir, keep the inherited PYTHONPATH.
        src = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        inherited = os.environ.get("PYTHONPATH")
        pypath = src + (os.pathsep + inherited if inherited else "")
        t0 = time.time()
        r = subprocess.run(
            cmd, capture_output=True, text=True, timeout=args.timeout,
            env={**os.environ, "PYTHONPATH": pypath},
        )
        dt = time.time() - t0
        if r.returncode == 0:
            num_cells += 1
            print(f"[batch] OK {c.arch.name} s{c.seed}{c.tag} ({dt:.1f}s)",
                  flush=True)
        else:
            failures.append((c.arch.name, c.seed, c.tag))
            print(f"[batch] FAIL {c.arch.name} s{c.seed}{c.tag} ({dt:.1f}s)\n"
                  f"{(r.stderr or r.stdout)[-2000:]}", flush=True)
    return {
        "failures": failures,
        "groups": num_cells,  # one executable (and process) per cell
        "num_compiles": None,  # spread over subprocesses; unknowable here
        "cells": num_cells,
        "mode": "isolated",
        "shard_devices": 0,
        "sharded_groups": 0,
    }


def run_single_cell(archs, args) -> int:
    """--only-cell kernel:seed — one cell in this process (isolate worker).

    The cell's numeric settings arrive as the worker's --tolerance /
    --sgd-lr / --solver-epochs scalars and are baked into the static config
    (a single cell has nothing to group with)."""
    import jax

    from repro.core import fit

    kind, seed = args.only_cell.rsplit(":", 1)
    seed = int(seed)
    matches = [a for a in archs if a.kind == kind]
    if not matches:
        print(f"[batch] unknown cell kernel {kind!r}", file=sys.stderr)
        return 1
    arch = matches[0]
    epochs = float(args.solver_epochs) if args.solver_epochs else float(
        arch.solver_epochs)
    rank = (args.precond_rank if args.precond_rank is not None
            else arch.precond_rank)
    cell = Cell(arch, seed, args.tolerance, args.sgd_lr, epochs, rank,
                args.cell_tag)
    cfg = outer_config_for(arch, args, cell)
    x, y = _load_data([arch], args)
    res = fit(x, y, cfg, key=jax.random.PRNGKey(seed), steps_per_round=0)
    _write_cell(args.out, cell, _cell_record(cell, res, "isolated", 1))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/batch")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=512)
    ap.add_argument("--split", type=int, default=0)
    ap.add_argument("--kernels", default=None,
                    help="comma list (default: every KERNEL_SWEEP kernel)")
    ap.add_argument("--seeds", type=int, default=2,
                    help="seed grid 0..seeds-1 per kernel")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="SMOKE probe/RFF/budget sizes")
    ap.add_argument("--solver", default=None, choices=[None, "cg", "ap", "sgd"],
                    help="override the sweep's solver")
    ap.add_argument("--tolerance", type=float, default=0.01)
    ap.add_argument("--tolerances", default=None,
                    help="comma floats: solver-tolerance grid (traced — "
                         "every point shares the group's one executable)")
    ap.add_argument("--block-size", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--sgd-lr", type=float, default=2.0)
    ap.add_argument("--sgd-lrs", default=None,
                    help="comma floats: SGD learning-rate grid (traced)")
    ap.add_argument("--epoch-budgets", default=None,
                    help="comma floats: solver epoch-budget grid (traced); "
                         "0 means the arch's default budget")
    ap.add_argument("--precond-ranks", default=None,
                    help="comma ints: preconditioner-rank grid (STATIC — "
                         "rank changes shapes, so each rank is its own "
                         "group/executable; cells gain an __rk<r> tag)")
    ap.add_argument("--shard-lanes", action="store_true",
                    help="shard each group's lane axis across local devices "
                         "(1-D lane mesh)")
    ap.add_argument("--bm", type=int, default=256)
    ap.add_argument("--bn", type=int, default=256)
    ap.add_argument("--isolate", action="store_true",
                    help="legacy one-subprocess-per-cell sweep")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only-cell", default=None,
                    help="internal: run one kernel:seed cell in-process")
    ap.add_argument("--solver-epochs", type=float, default=0.0,
                    help="internal (isolate worker): the cell's epoch budget")
    ap.add_argument("--precond-rank", type=int, default=None,
                    help="internal (isolate worker): the cell's "
                         "preconditioner rank")
    ap.add_argument("--cell-tag", default="",
                    help="internal (isolate worker): artifact filename tag")
    ap.add_argument("--expect-one-compile-per-group", action="store_true",
                    help="fail unless retraces == executed groups")
    args = ap.parse_args(argv)

    kernels = args.kernels.split(",") if args.kernels else None
    archs = sweep_archs(kernels, args.smoke)
    seeds = list(range(args.seeds))

    if args.only_cell:
        return run_single_cell(archs, args)

    cells = make_cells(archs, seeds, args)
    t0 = time.time()
    if args.isolate:
        # Reconstruct the cell-relevant flags for the worker subprocesses
        # (numeric settings are appended per cell by run_isolated).
        passthrough = [
            "--out", args.out, "--dataset", args.dataset,
            "--max-n", str(args.max_n), "--split", str(args.split),
            "--steps", str(args.steps),
            "--block-size", str(args.block_size),
            "--batch-size", str(args.batch_size),
            "--bm", str(args.bm), "--bn", str(args.bn),
        ]
        if args.smoke:
            passthrough.append("--smoke")
        if args.solver:
            passthrough += ["--solver", args.solver]
        status = run_isolated(cells, args, passthrough)
    else:
        x, y = _load_data(archs, args)
        status = run_batched(cells, x, y, args)

    status["wall_time_s"] = time.time() - t0
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "_sweep_status.json"), "w") as f:
        json.dump(status, f, indent=2)
    print(f"[batch] {status['cells']} cells in {status['wall_time_s']:.1f}s "
          f"({status['groups']} groups, compiles={status['num_compiles']}, "
          f"{len(status['failures'])} failures)")

    ok = not status["failures"]
    if args.expect_one_compile_per_group and not args.isolate:
        if status["num_compiles"] is None:
            # Introspection unavailable must FAIL the check, not pass it
            # vacuously (cf. serve.engine.num_compiles contract).
            print("[batch] RETRACE CHECK UNAVAILABLE: jit cache "
                  "introspection missing", file=sys.stderr)
            ok = False
        elif status["num_compiles"] != status["groups"]:
            print(f"[batch] RETRACE VIOLATION: {status['num_compiles']} "
                  f"compiles for {status['groups']} groups", file=sys.stderr)
            ok = False
        else:
            print(f"[batch] one executable per group verified "
                  f"({status['groups']} groups == {status['num_compiles']} "
                  f"compiles)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
