"""CLI serving driver (reduced configs on local devices).

LM archs: autoregressive generation with the KV/SSM cache serve_step.
GP arch: pathwise-conditioning prediction server on `repro.serve` — fit,
export a `ServableGP`, drive the shape-bucketed engine (zero linear solves
per request, eq. 16 amortisation; zero retraces after warmup). `--compat`
keeps the legacy per-request loop (jit hoisted out of the loop, tail block
padded).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import init_cache, init_params, make_serve_step
    from repro.models.transformer import prefill_cross_cache

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    b, steps = args.batch, args.tokens
    max_len = args.max_len
    enc_len = 32 if cfg.is_encdec else 0
    cache = init_cache(cfg, b, max_len, enc_len=enc_len)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (b, enc_len, cfg.d_model)) * 0.3
        cache = prefill_cross_cache(params, cfg, frames, cache)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    toks = jnp.zeros((b,), jnp.int32)
    t0 = time.perf_counter()
    out = []
    for pos in range(steps):
        logits, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {steps} steps x batch {b} in {dt:.2f}s "
          f"({steps*b/dt:.1f} tok/s); sample row: "
          f"{[int(t[0]) for t in out[:16]]}")


def _fit_gp(args):
    from repro.core import OuterConfig, fit
    from repro.data.synthetic import load_dataset
    from repro.solvers import SolverConfig

    ds = load_dataset(args.dataset, max_n=args.max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=32,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=args.train_steps, bm=512, bn=512,
    )
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(args.seed))
    return ds, cfg, res.state


def serve_gp_compat(args, ds, cfg, state):
    """Legacy per-request loop, minimally fixed: the `pathwise_predict` jit
    is built ONCE outside the request loop, and the tail block is padded to
    the fixed request width so ragged shapes never retrace."""
    from functools import partial

    from repro.core import pathwise_predict, predictive_metrics

    width = 64
    predict = jax.jit(partial(
        pathwise_predict, kind=None, bm=cfg.bm, bn=cfg.bn
    ))
    n_test = ds.x_test.shape[0]
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test)
        xq = ds.x_test[lo : lo + width]
        take = xq.shape[0]
        if take < width:  # pad the tail block instead of wrapping/retracing
            xq = jnp.pad(xq, ((0, width - take), (0, 0)))
        pred = predict(ds.x_train, xq, state.carry_v, state.probes,
                       state.params)
        jax.block_until_ready(pred.mean)
    dt = time.perf_counter() - t0
    m = predictive_metrics(ds.y_test[:width],
                           pathwise_predict(ds.x_train, ds.x_test[:width],
                                            state.carry_v, state.probes,
                                            state.params),
                           state.params)
    print(f"[serve-gp compat] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s) — ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")


def _metrics_smoke_probe(endpoints, xq):
    """Observability leg of the CI smoke: a /predict carrying an explicit
    ``X-Trace-Id`` must echo it back, and GET /metrics must serve Prometheus
    text exposing the request/admission/engine metric families."""
    import json as _json
    import urllib.request

    import numpy as np

    from repro.obs import trace as obs_trace

    required = (
        "gp_http_requests_total",
        "gp_admission_decisions_total",
        "gp_engine_batch_seconds",
        "gp_engine_queue_depth",
    )
    probe = _json.dumps({"x": np.asarray(xq).tolist()}).encode()
    for ep in endpoints:
        tid = "smoke-" + obs_trace.new_trace_id()
        req = urllib.request.Request(
            ep + "/predict", data=probe,
            headers={"Content-Type": "application/json",
                     obs_trace.TRACE_HEADER: tid})
        with urllib.request.urlopen(req, timeout=30) as resp:
            echoed = resp.headers.get(obs_trace.TRACE_HEADER)
        if echoed != tid:
            raise SystemExit(
                f"[obs-smoke] {ep} trace header not echoed: sent {tid!r}, "
                f"got {echoed!r}")
        with urllib.request.urlopen(ep + "/metrics", timeout=10) as resp:
            ctype = resp.headers.get("Content-Type", "")
            text = resp.read().decode()
        if "version=0.0.4" not in ctype:
            raise SystemExit(f"[obs-smoke] {ep}/metrics content type {ctype!r}")
        missing = [f for f in required if f"# TYPE {f} " not in text]
        if missing:
            raise SystemExit(
                f"[obs-smoke] {ep}/metrics missing families {missing}; "
                f"got {len(text)} bytes")
        print(f"[obs-smoke] {ep}: trace echo ok, /metrics ok "
              f"({len(text.splitlines())} lines)")


def _http_smoke_probe(endpoints, xq, metrics=False):
    """The CI smoke sequence against live endpoints: /healthz and /predict
    must 200 with finite predictions; a flood past the admission cap must
    shed 429 WITH a Retry-After hint. Raises SystemExit on any violation."""
    import numpy as np

    from repro.serve.cluster.replica import _http_json

    for ep in endpoints:
        status, body = _http_json(ep + "/healthz")
        if status != 200:
            raise SystemExit(f"[http-smoke] {ep}/healthz -> {status}: {body}")
        status, body = _http_json(ep + "/predict",
                                  {"x": np.asarray(xq).tolist()})
        if status != 200:
            raise SystemExit(f"[http-smoke] {ep}/predict -> {status}: {body}")
        mean = np.asarray(body["mean"])
        if mean.shape != (xq.shape[0],) or not np.all(np.isfinite(mean)):
            raise SystemExit(f"[http-smoke] non-finite/misshapen mean: {body}")
        print(f"[http-smoke] {ep}: healthz ok, predict ok "
              f"(version={body.get('version')})")

    # Flood one endpoint past the admission cap: sequential requests drain
    # the token bucket, so with burst B requests B+1.. must shed.
    import urllib.error
    import urllib.request
    import json as _json

    ep = endpoints[0]
    codes, retry_after = [], None
    probe = _json.dumps({"x": np.asarray(xq[:1]).tolist()}).encode()
    for _ in range(10):
        req = urllib.request.Request(
            ep + "/predict", data=probe,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=30) as resp:
                codes.append(resp.status)
        except urllib.error.HTTPError as e:
            codes.append(e.code)
            if e.code == 429 and retry_after is None:
                retry_after = e.headers.get("Retry-After")
    if 429 not in codes:
        raise SystemExit(f"[http-smoke] flood never shed: {codes}")
    if retry_after is None or int(retry_after) < 1:
        raise SystemExit(f"[http-smoke] 429 without Retry-After: {codes}")
    stats_status, stats = _http_json(ep + "/stats")
    if stats_status != 200 or stats["admission"]["shed"] < codes.count(429):
        raise SystemExit(f"[http-smoke] stats disagree with flood: {stats}")
    if "schema_version" not in stats or "ts" not in stats:
        raise SystemExit(f"[http-smoke] /stats missing ts/schema_version: "
                         f"{sorted(stats)}")
    print(f"[http-smoke] flood codes={codes} Retry-After={retry_after} "
          f"shed={stats['admission']['shed']} — OK")
    if metrics:
        _metrics_smoke_probe(endpoints, xq)


def serve_gp_http(args, ds, cfg, state):
    """HTTP cluster serving: publish the artifact, run 1..N replicas.

    ``--replicas 1`` without ``--artifact-store`` serves in-process (no
    extra processes, still the full transport/admission stack). With a
    store, replicas are spawned worker processes that poll ``LATEST`` and
    pick up every later publish without a restart.
    """
    from repro.serve import MultiModelServer, export_servable
    from repro.serve.cluster import (
        AdmissionController,
        ReplicaSupervisor,
        ServeFrontend,
        publish_servable,
        start_http_server,
    )

    host, port = args.http.rsplit(":", 1)
    port = int(port)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    model = export_servable(state, ds.x_train)
    width = min(16, ds.x_test.shape[0])
    xq = ds.x_test[:width]

    if args.replicas > 1 and not args.artifact_store:
        raise SystemExit("--replicas > 1 needs --artifact-store (the store "
                         "is how worker processes receive the model)")

    if args.artifact_store:
        version = publish_servable(args.artifact_store, model)
        print(f"[serve-http] published {version} -> {args.artifact_store}")
        sup = ReplicaSupervisor(
            args.artifact_store, num_replicas=args.replicas, host=host,
            base_port=port, buckets=buckets, bm=cfg.bm, bn=cfg.bn,
            rate_qps=args.admission_qps, burst=args.admission_burst,
            max_inflight=args.max_inflight,
            request_log_dir=args.request_log,
        )
        endpoints = sup.start()
        print(f"[serve-http] {args.replicas} replica(s): {endpoints}")
        try:
            if args.http_smoke:
                _http_smoke_probe(endpoints, xq, metrics=args.metrics)
            elif args.serve_seconds:
                time.sleep(args.serve_seconds)
            else:
                while True:
                    time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            sup.stop()
        return

    if args.request_log:
        # In-process replica: one log file, same layout the supervisor uses.
        import os

        from repro.obs import trace as obs_trace

        os.makedirs(args.request_log, exist_ok=True)
        obs_trace.configure(
            path=os.path.join(args.request_log, "replica_0.jsonl"))

    server = MultiModelServer(buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    server.register("default", model, warmup=True)
    admission = AdmissionController(
        buckets=buckets, rate_qps=args.admission_qps,
        burst=args.admission_burst, max_inflight=args.max_inflight,
    )
    online = None
    if args.refresh_every:
        # In-place refresh replica: expose the refresher's counters
        # (escalations, coupling residuals, capacity growth) on GET /stats.
        from repro.serve import OnlineGP

        online = OnlineGP(ds.x_train, ds.y_train, state, cfg)
    frontend = ServeFrontend(server, admission, refresh_source=online)
    httpd, _ = start_http_server(frontend, host=host, port=port)
    endpoint = f"http://{host}:{httpd.port}"
    print(f"[serve-http] in-process replica: {endpoint}")
    try:
        if args.http_smoke:
            _http_smoke_probe([endpoint], xq, metrics=args.metrics)
        elif args.serve_seconds:
            time.sleep(args.serve_seconds)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()


def serve_gp(args, ds=None, cfg=None, state=None):
    """Engine-based serving: fit -> export `ServableGP` -> bucketed engine.

    Steady state is zero retraces (all bucket executables compiled by
    `warmup`) and zero linear solves (eq. 16 amortisation via the frozen
    correction matrix).
    """
    import numpy as np

    from repro.core import predictive_metrics
    from repro.serve import BucketedEngine, OnlineGP, export_servable

    if ds is None:
        ds, cfg, state = _fit_gp(args)
    if args.compat:
        return serve_gp_compat(args, ds, cfg, state)
    if args.http:
        return serve_gp_http(args, ds, cfg, state)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    model = export_servable(state, ds.x_train)
    engine = BucketedEngine(model, buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    compiles = engine.warmup()

    width = 64
    n_test = ds.x_test.shape[0]
    lat = []
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test - 1)
        xq = ds.x_test[lo : lo + width]
        ts = time.perf_counter()
        pred = engine.submit(xq)
        jax.block_until_ready(pred.mean)
        lat.append(time.perf_counter() - ts)
    dt = time.perf_counter() - t0
    now = engine.num_compiles()
    retraces = None if (compiles is None or now is None) else now - compiles

    if args.refresh_every and n_test > 0:
        blk = min(width, n_test)
        online = OnlineGP(ds.x_train, ds.y_train, state, cfg)
        online.append(ds.x_test[:blk], ds.y_test[:blk])
        report = online.refresh_into(engine, budget_epochs=10.0)
        print(f"[serve-gp] online refresh: +{blk} rows -> n={report.n}, "
              f"{report.epochs:.1f} epochs, res_y={report.res_y:.3f}")

    m = predictive_metrics(
        ds.y_test[:width], engine.submit(ds.x_test[:width]), state.params
    )
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    retrace_msg = "n/a (no cache introspection)" if retraces is None else retraces
    print(f"[serve-gp] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s, p50={p50:.1f}ms p99={p99:.1f}ms) "
          f"— buckets={buckets}, retraces after warmup={retrace_msg}, "
          f"ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")
    if retraces:
        raise SystemExit(f"steady-state serving retraced {retraces}x")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gp-iterative")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=2000)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", default="16,64,256",
                    help="comma-separated GP engine row buckets")
    ap.add_argument("--compat", action="store_true",
                    help="legacy per-request GP loop (jit hoisted, tail padded)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="if set, run one warm online refresh after serving")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="serve GP predictions over HTTP (port 0 = ephemeral)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="worker processes behind --http (>1 needs "
                         "--artifact-store; replica i binds PORT+i)")
    ap.add_argument("--artifact-store", default=None, metavar="DIR",
                    help="publish the fitted artifact here and serve from it "
                         "(replicas poll LATEST and hot-swap new publishes)")
    ap.add_argument("--admission-qps", type=float, default=None,
                    help="admitted requests/s per bucket class (None = no "
                         "rate limit)")
    ap.add_argument("--admission-burst", type=float, default=None,
                    help="token-bucket burst (default 2x qps)")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="concurrent in-compute requests before shedding")
    ap.add_argument("--serve-seconds", type=float, default=0,
                    help="serve for S seconds then exit (0 = run forever)")
    ap.add_argument("--http-smoke", action="store_true",
                    help="probe /healthz + /predict + overload shedding "
                         "against the live server, then exit (CI smoke)")
    ap.add_argument("--metrics", action="store_true",
                    help="with --http-smoke: also assert X-Trace-Id echo and "
                         "the Prometheus families on GET /metrics")
    ap.add_argument("--request-log", default=None, metavar="DIR",
                    help="write per-replica structured JSONL request logs "
                         "(request/admission/engine span events) under DIR")
    args = ap.parse_args(argv)
    if args.arch == "gp-iterative":
        serve_gp(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
