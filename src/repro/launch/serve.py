"""CLI serving driver (reduced configs on local devices).

LM archs: autoregressive generation with the KV/SSM cache serve_step.
GP arch: pathwise-conditioning prediction server — amortised posterior
samples from the training carry, zero extra linear solves per request
(the paper's §3 amortisation).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import init_cache, init_params, make_serve_step
    from repro.models.transformer import prefill_cross_cache

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    b, steps = args.batch, args.tokens
    max_len = args.max_len
    enc_len = 32 if cfg.is_encdec else 0
    cache = init_cache(cfg, b, max_len, enc_len=enc_len)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (b, enc_len, cfg.d_model)) * 0.3
        cache = prefill_cross_cache(params, cfg, frames, cache)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    toks = jnp.zeros((b,), jnp.int32)
    t0 = time.perf_counter()
    out = []
    for pos in range(steps):
        logits, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {steps} steps x batch {b} in {dt:.2f}s "
          f"({steps*b/dt:.1f} tok/s); sample row: "
          f"{[int(t[0]) for t in out[:16]]}")


def serve_gp(args):
    from repro.core import OuterConfig, fit, pathwise_predict, predictive_metrics
    from repro.data.synthetic import load_dataset
    from repro.solvers import SolverConfig

    ds = load_dataset(args.dataset, max_n=args.max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=32,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=args.train_steps, bm=512, bn=512,
    )
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(args.seed))
    state = res.state
    # "Serving": batched posterior queries, re-using the solver carry.
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * 64) % max(1, ds.x_test.shape[0] - 64)
        xq = ds.x_test[lo : lo + 64]
        pred = pathwise_predict(ds.x_train, xq, state.carry_v, state.probes,
                                state.params, bm=cfg.bm, bn=cfg.bn)
        jax.block_until_ready(pred.mean)
    dt = time.perf_counter() - t0
    m = predictive_metrics(ds.y_test[:64],
                           pathwise_predict(ds.x_train, ds.x_test[:64],
                                            state.carry_v, state.probes,
                                            state.params),
                           state.params)
    print(f"[serve-gp] {args.requests} batched requests in {dt:.2f}s "
          f"({args.requests*64/dt:.1f} q/s) — ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gp-iterative")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=2000)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.arch == "gp-iterative":
        serve_gp(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
