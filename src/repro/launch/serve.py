"""CLI serving driver (reduced configs on local devices).

LM archs: autoregressive generation with the KV/SSM cache serve_step.
GP arch: pathwise-conditioning prediction server on `repro.serve` — fit,
export a `ServableGP`, drive the shape-bucketed engine (zero linear solves
per request, eq. 16 amortisation; zero retraces after warmup). `--compat`
keeps the legacy per-request loop (jit hoisted out of the loop, tail block
padded).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import init_cache, init_params, make_serve_step
    from repro.models.transformer import prefill_cross_cache

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    b, steps = args.batch, args.tokens
    max_len = args.max_len
    enc_len = 32 if cfg.is_encdec else 0
    cache = init_cache(cfg, b, max_len, enc_len=enc_len)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (b, enc_len, cfg.d_model)) * 0.3
        cache = prefill_cross_cache(params, cfg, frames, cache)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    toks = jnp.zeros((b,), jnp.int32)
    t0 = time.perf_counter()
    out = []
    for pos in range(steps):
        logits, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {steps} steps x batch {b} in {dt:.2f}s "
          f"({steps*b/dt:.1f} tok/s); sample row: "
          f"{[int(t[0]) for t in out[:16]]}")


def _fit_gp(args):
    from repro.core import OuterConfig, fit
    from repro.data.synthetic import load_dataset
    from repro.solvers import SolverConfig

    ds = load_dataset(args.dataset, max_n=args.max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=32,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=args.train_steps, bm=512, bn=512,
    )
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(args.seed))
    return ds, cfg, res.state


def serve_gp_compat(args, ds, cfg, state):
    """Legacy per-request loop, minimally fixed: the `pathwise_predict` jit
    is built ONCE outside the request loop, and the tail block is padded to
    the fixed request width so ragged shapes never retrace."""
    from functools import partial

    from repro.core import pathwise_predict, predictive_metrics

    width = 64
    predict = jax.jit(partial(
        pathwise_predict, kind=None, bm=cfg.bm, bn=cfg.bn
    ))
    n_test = ds.x_test.shape[0]
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test)
        xq = ds.x_test[lo : lo + width]
        take = xq.shape[0]
        if take < width:  # pad the tail block instead of wrapping/retracing
            xq = jnp.pad(xq, ((0, width - take), (0, 0)))
        pred = predict(ds.x_train, xq, state.carry_v, state.probes,
                       state.params)
        jax.block_until_ready(pred.mean)
    dt = time.perf_counter() - t0
    m = predictive_metrics(ds.y_test[:width],
                           pathwise_predict(ds.x_train, ds.x_test[:width],
                                            state.carry_v, state.probes,
                                            state.params),
                           state.params)
    print(f"[serve-gp compat] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s) — ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")


def _metrics_smoke_probe(endpoints, xq):
    """Observability leg of the CI smoke: a /predict carrying an explicit
    ``X-Trace-Id`` must echo it back, and GET /metrics must serve Prometheus
    text exposing the request/admission/engine metric families."""
    import json as _json
    import urllib.request

    import numpy as np

    from repro.obs import trace as obs_trace

    required = (
        "gp_http_requests_total",
        "gp_admission_decisions_total",
        "gp_engine_batch_seconds",
        "gp_engine_queue_depth",
    )
    probe = _json.dumps({"x": np.asarray(xq).tolist()}).encode()
    for ep in endpoints:
        tid = "smoke-" + obs_trace.new_trace_id()
        req = urllib.request.Request(
            ep + "/predict", data=probe,
            headers={"Content-Type": "application/json",
                     obs_trace.TRACE_HEADER: tid})
        with urllib.request.urlopen(req, timeout=30) as resp:
            echoed = resp.headers.get(obs_trace.TRACE_HEADER)
        if echoed != tid:
            raise SystemExit(
                f"[obs-smoke] {ep} trace header not echoed: sent {tid!r}, "
                f"got {echoed!r}")
        with urllib.request.urlopen(ep + "/metrics", timeout=10) as resp:
            ctype = resp.headers.get("Content-Type", "")
            text = resp.read().decode()
        if "version=0.0.4" not in ctype:
            raise SystemExit(f"[obs-smoke] {ep}/metrics content type {ctype!r}")
        missing = [f for f in required if f"# TYPE {f} " not in text]
        if missing:
            raise SystemExit(
                f"[obs-smoke] {ep}/metrics missing families {missing}; "
                f"got {len(text)} bytes")
        print(f"[obs-smoke] {ep}: trace echo ok, /metrics ok "
              f"({len(text.splitlines())} lines)")


def _fleet_smoke_probe(sup, monitor, monitor_ep, endpoints, xq):
    """The fleet-observability CI smoke against a live cluster + monitor.

    Sequence: every replica must show up on ``/fleet/health``; after a
    burst of traffic the aggregated ``/fleet/metrics`` ``/predict``
    counters must EQUAL the per-replica ``/metrics`` totals (exact — the
    scraper re-exports samples verbatim); ``/fleet/health`` EWMA/shed-rate
    must match each replica's own ``/stats``; then one replica is
    hard-killed and ``gp_fleet_replica_up`` must flip to 0 within a couple
    of scrape intervals, with the availability burn-rate rule escalating
    to PAGE. Raises SystemExit on any violation.
    """
    import urllib.request

    import numpy as np

    from repro.obs.scrape import parse_prometheus
    from repro.serve.cluster.replica import _http_json

    interval = monitor.interval_s

    def wait_for(pred, timeout_s, what):
        deadline = time.monotonic() + timeout_s
        t0 = time.monotonic()
        while time.monotonic() < deadline:
            try:
                if pred():
                    return time.monotonic() - t0
            except OSError:
                pass
            time.sleep(max(0.05, interval / 4))
        raise SystemExit(f"[fleet-smoke] timed out waiting for {what}")

    names = [f"replica_{i}" for i in range(len(endpoints))]

    # 1. Every replica reports up on /fleet/health.
    def all_up():
        status, h = _http_json(monitor_ep + "/fleet/health")
        return status == 200 and h["num_up"] == len(endpoints)

    wait_for(all_up, 30 * interval + 30, "all replicas up on /fleet/health")
    print(f"[fleet-smoke] {len(endpoints)} replicas up on /fleet/health")

    # 2. Traffic: a burst of predicts against every replica, then stop —
    # quiescent counters are what makes the exactness check exact.
    probe = {"x": np.asarray(xq).tolist()}
    for _ in range(5):
        for ep in endpoints:
            status, body = _http_json(ep + "/predict", probe)
            if status not in (200, 429):
                raise SystemExit(
                    f"[fleet-smoke] {ep}/predict -> {status}: {body}")

    def parse_url(url):
        with urllib.request.urlopen(url, timeout=10) as resp:
            return parse_prometheus(resp.read().decode("utf-8"))

    def predict_total(fams, where=None):
        fam = fams.get("gp_http_requests_total")
        total = 0.0
        for s in (fam.samples if fam else ()):
            if s.labels.get("path") != "/predict":
                continue
            if where is None or where(s.labels):
                total += s.value
        return total

    direct = {
        name: predict_total(parse_url(ep + "/metrics"))
        for name, ep in zip(names, endpoints)
    }

    # 3. /fleet/metrics totals must EQUAL the per-replica counters once the
    # scraper's cache catches up (a couple of intervals at most).
    def fleet_matches():
        fams = parse_url(monitor_ep + "/fleet/metrics")
        got = {
            name: predict_total(
                fams, where=lambda lbl, n=name: lbl.get("replica") == n)
            for name in names
        }
        return got == direct

    wait_for(fleet_matches, 10 * interval + 30,
             f"/fleet/metrics to equal per-replica totals {direct}")
    print(f"[fleet-smoke] /fleet/metrics == per-replica /metrics: {direct}")

    # 4. /fleet/health load signals must match each replica's own /stats.
    def health_matches():
        _, h = _http_json(monitor_ep + "/fleet/health")
        for name, ep in zip(names, endpoints):
            entry = h["replicas"].get(name)
            if entry is None:
                return False
            _, stats = _http_json(ep + "/stats")
            adm = stats["admission"]
            admitted, shed = adm.get("admitted", 0), adm.get("shed", 0)
            want_shed = shed / (admitted + shed) if (admitted + shed) else 0.0
            got_ewma = entry["service_ewma_ms"]
            if got_ewma is None or \
                    abs(got_ewma - adm["service_ewma_ms"]) > 1e-9:
                return False
            if abs((entry["shed_rate"] or 0.0) - want_shed) > 1e-9:
                return False
        return True

    wait_for(health_matches, 10 * interval + 30,
             "/fleet/health EWMA/shed-rate to match replica /stats")
    print("[fleet-smoke] /fleet/health EWMA + shed-rate match /stats")

    # 5. Availability must settle at OK before the chaos step.
    def avail_ok():
        _, s = _http_json(monitor_ep + "/fleet/slo")
        return s["slos"].get("availability", {}).get("state") == "OK"

    wait_for(avail_ok, 60 * interval + 30, "availability SLO to settle OK")

    # 6. Chaos: hard-kill the last replica. Up must flip within ~2 scrape
    # intervals; the availability burn rate must escalate OK -> PAGE.
    victim = len(endpoints) - 1
    sup.kill(victim)
    t_kill = time.monotonic()

    def victim_down():
        _, h = _http_json(monitor_ep + "/fleet/health")
        entry = h["replicas"].get(names[victim])
        return entry is not None and not entry["up"]

    took = wait_for(victim_down, 4 * interval + 15,
                    f"gp_fleet_replica_up 0 for {names[victim]}")
    print(f"[fleet-smoke] {names[victim]} marked down "
          f"{took:.1f}s after kill (interval {interval}s)")

    def paged():
        _, s = _http_json(monitor_ep + "/fleet/slo")
        return s["slos"].get("availability", {}).get("state") == "PAGE"

    slow = max(r.slow_window_s
               for slo in monitor.slo_engine._states.values()
               for r in slo.slo.rules)
    wait_for(paged, slow + 60 * interval + 30,
             "availability burn-rate PAGE after replica kill")
    print(f"[fleet-smoke] availability PAGE "
          f"{time.monotonic() - t_kill:.1f}s after kill — OK")


def _http_smoke_probe(endpoints, xq, metrics=False):
    """The CI smoke sequence against live endpoints: /healthz and /predict
    must 200 with finite predictions; a flood past the admission cap must
    shed 429 WITH a Retry-After hint. Raises SystemExit on any violation."""
    import numpy as np

    from repro.serve.cluster.replica import _http_json

    for ep in endpoints:
        status, body = _http_json(ep + "/healthz")
        if status != 200:
            raise SystemExit(f"[http-smoke] {ep}/healthz -> {status}: {body}")
        status, body = _http_json(ep + "/predict",
                                  {"x": np.asarray(xq).tolist()})
        if status != 200:
            raise SystemExit(f"[http-smoke] {ep}/predict -> {status}: {body}")
        mean = np.asarray(body["mean"])
        if mean.shape != (xq.shape[0],) or not np.all(np.isfinite(mean)):
            raise SystemExit(f"[http-smoke] non-finite/misshapen mean: {body}")
        print(f"[http-smoke] {ep}: healthz ok, predict ok "
              f"(version={body.get('version')})")

    # Flood one endpoint past the admission cap: sequential requests drain
    # the token bucket, so with burst B requests B+1.. must shed.
    import urllib.error
    import urllib.request
    import json as _json

    ep = endpoints[0]
    codes, retry_after = [], None
    probe = _json.dumps({"x": np.asarray(xq[:1]).tolist()}).encode()
    for _ in range(10):
        req = urllib.request.Request(
            ep + "/predict", data=probe,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=30) as resp:
                codes.append(resp.status)
        except urllib.error.HTTPError as e:
            codes.append(e.code)
            if e.code == 429 and retry_after is None:
                retry_after = e.headers.get("Retry-After")
    if 429 not in codes:
        raise SystemExit(f"[http-smoke] flood never shed: {codes}")
    if retry_after is None or int(retry_after) < 1:
        raise SystemExit(f"[http-smoke] 429 without Retry-After: {codes}")
    stats_status, stats = _http_json(ep + "/stats")
    if stats_status != 200 or stats["admission"]["shed"] < codes.count(429):
        raise SystemExit(f"[http-smoke] stats disagree with flood: {stats}")
    if "schema_version" not in stats or "ts" not in stats:
        raise SystemExit(f"[http-smoke] /stats missing ts/schema_version: "
                         f"{sorted(stats)}")
    print(f"[http-smoke] flood codes={codes} Retry-After={retry_after} "
          f"shed={stats['admission']['shed']} — OK")
    if metrics:
        _metrics_smoke_probe(endpoints, xq)


def serve_gp_http(args, ds, cfg, state):
    """HTTP cluster serving: publish the artifact, run 1..N replicas.

    ``--replicas 1`` without ``--artifact-store`` serves in-process (no
    extra processes, still the full transport/admission stack). With a
    store, replicas are spawned worker processes that poll ``LATEST`` and
    pick up every later publish without a restart.
    """
    from repro.serve import MultiModelServer, export_servable
    from repro.serve.cluster import (
        AdmissionController,
        ReplicaSupervisor,
        ServeFrontend,
        publish_servable,
        start_http_server,
    )

    host, port = args.http.rsplit(":", 1)
    port = int(port)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    model = export_servable(state, ds.x_train)
    width = min(16, ds.x_test.shape[0])
    xq = ds.x_test[:width]

    if args.replicas > 1 and not args.artifact_store:
        raise SystemExit("--replicas > 1 needs --artifact-store (the store "
                         "is how worker processes receive the model)")
    if args.fleet_smoke and not (args.artifact_store and args.monitor):
        raise SystemExit("--fleet-smoke needs --artifact-store (supervised "
                         "replicas) and --monitor HOST:PORT")

    if args.artifact_store:
        version = publish_servable(args.artifact_store, model)
        print(f"[serve-http] published {version} -> {args.artifact_store}")
        sup = ReplicaSupervisor(
            args.artifact_store, num_replicas=args.replicas, host=host,
            base_port=port, buckets=buckets, bm=cfg.bm, bn=cfg.bn,
            rate_qps=args.admission_qps, burst=args.admission_burst,
            max_inflight=args.max_inflight,
            request_log_dir=args.request_log,
        )
        endpoints = sup.start()
        print(f"[serve-http] {args.replicas} replica(s): {endpoints}")

        monitor = monitor_server = None
        if args.monitor:
            import os

            from repro.obs.trace import EventLog
            from repro.serve.cluster.monitor import (
                FleetMonitor,
                default_slos,
                start_monitor_server,
            )

            mhost, mport = args.monitor.rsplit(":", 1)
            interval = args.monitor_interval
            slos = None
            if args.fleet_smoke:
                # Short windows so the burn-rate PAGE fires within the
                # smoke's patience rather than the production 5min/1h.
                interval = min(interval, 0.5)
                slos = default_slos(fast_window_s=6 * interval,
                                    slow_window_s=18 * interval)
            mlog = None
            if args.request_log:
                os.makedirs(args.request_log, exist_ok=True)
                mlog = EventLog(
                    path=os.path.join(args.request_log, "monitor.jsonl"))
            monitor = FleetMonitor(
                supervisor=sup, interval_s=interval, slos=slos,
                event_log=mlog)
            monitor_server, _ = start_monitor_server(
                monitor, host=mhost, port=int(mport))
            monitor_ep = f"http://{mhost}:{monitor_server.port}"
            print(f"[serve-http] fleet monitor: {monitor_ep}/fleet/"
                  f"{{metrics,slo,health}} (interval {interval}s)")

        try:
            if args.fleet_smoke:
                if monitor is None:
                    raise SystemExit("--fleet-smoke needs --monitor HOST:PORT")
                _fleet_smoke_probe(sup, monitor, monitor_ep, endpoints, xq)
            elif args.http_smoke:
                _http_smoke_probe(endpoints, xq, metrics=args.metrics)
            elif args.serve_seconds:
                time.sleep(args.serve_seconds)
            else:
                while True:
                    time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            if monitor_server is not None:
                monitor_server.shutdown()
                monitor.stop()
            sup.stop()
        return

    if args.request_log:
        # In-process replica: one log file, same layout the supervisor uses.
        import os

        from repro.obs import trace as obs_trace

        os.makedirs(args.request_log, exist_ok=True)
        obs_trace.configure(
            path=os.path.join(args.request_log, "replica_0.jsonl"))

    server = MultiModelServer(buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    server.register("default", model, warmup=True)
    admission = AdmissionController(
        buckets=buckets, rate_qps=args.admission_qps,
        burst=args.admission_burst, max_inflight=args.max_inflight,
    )
    online = None
    if args.refresh_every:
        # In-place refresh replica: expose the refresher's counters
        # (escalations, coupling residuals, capacity growth) on GET /stats.
        from repro.serve import OnlineGP

        online = OnlineGP(ds.x_train, ds.y_train, state, cfg)
    frontend = ServeFrontend(server, admission, refresh_source=online)
    httpd, _ = start_http_server(frontend, host=host, port=port)
    endpoint = f"http://{host}:{httpd.port}"
    print(f"[serve-http] in-process replica: {endpoint}")
    try:
        if args.http_smoke:
            _http_smoke_probe([endpoint], xq, metrics=args.metrics)
        elif args.serve_seconds:
            time.sleep(args.serve_seconds)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()


def serve_gp(args, ds=None, cfg=None, state=None):
    """Engine-based serving: fit -> export `ServableGP` -> bucketed engine.

    Steady state is zero retraces (all bucket executables compiled by
    `warmup`) and zero linear solves (eq. 16 amortisation via the frozen
    correction matrix).
    """
    import numpy as np

    from repro.core import predictive_metrics
    from repro.serve import BucketedEngine, OnlineGP, export_servable

    if ds is None:
        ds, cfg, state = _fit_gp(args)
    if args.compat:
        return serve_gp_compat(args, ds, cfg, state)
    if args.http:
        return serve_gp_http(args, ds, cfg, state)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    model = export_servable(state, ds.x_train)
    engine = BucketedEngine(model, buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    compiles = engine.warmup()

    width = 64
    n_test = ds.x_test.shape[0]
    lat = []
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test - 1)
        xq = ds.x_test[lo : lo + width]
        ts = time.perf_counter()
        pred = engine.submit(xq)
        jax.block_until_ready(pred.mean)
        lat.append(time.perf_counter() - ts)
    dt = time.perf_counter() - t0
    now = engine.num_compiles()
    retraces = None if (compiles is None or now is None) else now - compiles

    if args.refresh_every and n_test > 0:
        blk = min(width, n_test)
        online = OnlineGP(ds.x_train, ds.y_train, state, cfg)
        online.append(ds.x_test[:blk], ds.y_test[:blk])
        report = online.refresh_into(engine, budget_epochs=10.0)
        print(f"[serve-gp] online refresh: +{blk} rows -> n={report.n}, "
              f"{report.epochs:.1f} epochs, res_y={report.res_y:.3f}")

    m = predictive_metrics(
        ds.y_test[:width], engine.submit(ds.x_test[:width]), state.params
    )
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    retrace_msg = "n/a (no cache introspection)" if retraces is None else retraces
    print(f"[serve-gp] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s, p50={p50:.1f}ms p99={p99:.1f}ms) "
          f"— buckets={buckets}, retraces after warmup={retrace_msg}, "
          f"ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")
    if retraces:
        raise SystemExit(f"steady-state serving retraced {retraces}x")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gp-iterative")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=2000)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", default="16,64,256",
                    help="comma-separated GP engine row buckets")
    ap.add_argument("--compat", action="store_true",
                    help="legacy per-request GP loop (jit hoisted, tail padded)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="if set, run one warm online refresh after serving")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="serve GP predictions over HTTP (port 0 = ephemeral)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="worker processes behind --http (>1 needs "
                         "--artifact-store; replica i binds PORT+i)")
    ap.add_argument("--artifact-store", default=None, metavar="DIR",
                    help="publish the fitted artifact here and serve from it "
                         "(replicas poll LATEST and hot-swap new publishes)")
    ap.add_argument("--admission-qps", type=float, default=None,
                    help="admitted requests/s per bucket class (None = no "
                         "rate limit)")
    ap.add_argument("--admission-burst", type=float, default=None,
                    help="token-bucket burst (default 2x qps)")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="concurrent in-compute requests before shedding")
    ap.add_argument("--serve-seconds", type=float, default=0,
                    help="serve for S seconds then exit (0 = run forever)")
    ap.add_argument("--http-smoke", action="store_true",
                    help="probe /healthz + /predict + overload shedding "
                         "against the live server, then exit (CI smoke)")
    ap.add_argument("--metrics", action="store_true",
                    help="with --http-smoke: also assert X-Trace-Id echo and "
                         "the Prometheus families on GET /metrics")
    ap.add_argument("--request-log", default=None, metavar="DIR",
                    help="write per-replica structured JSONL request logs "
                         "(request/admission/engine span events) under DIR")
    ap.add_argument("--monitor", default=None, metavar="HOST:PORT",
                    help="run the fleet monitor alongside the supervisor "
                         "(scrapes every replica, serves /fleet/metrics, "
                         "/fleet/slo, /fleet/health; port 0 = ephemeral)")
    ap.add_argument("--monitor-interval", type=float, default=1.0,
                    help="monitor scrape/evaluate period in seconds")
    ap.add_argument("--fleet-smoke", action="store_true",
                    help="probe the fleet plane (aggregate==per-replica "
                         "counters, health contract, kill-one-replica "
                         "staleness + burn-rate PAGE), then exit (CI smoke)")
    args = ap.parse_args(argv)
    if args.arch == "gp-iterative":
        serve_gp(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
