"""CLI serving driver (reduced configs on local devices).

LM archs: autoregressive generation with the KV/SSM cache serve_step.
GP arch: pathwise-conditioning prediction server on `repro.serve` — fit,
export a `ServableGP`, drive the shape-bucketed engine (zero linear solves
per request, eq. 16 amortisation; zero retraces after warmup). `--compat`
keeps the legacy per-request loop (jit hoisted out of the loop, tail block
padded).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import init_cache, init_params, make_serve_step
    from repro.models.transformer import prefill_cross_cache

    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    b, steps = args.batch, args.tokens
    max_len = args.max_len
    enc_len = 32 if cfg.is_encdec else 0
    cache = init_cache(cfg, b, max_len, enc_len=enc_len)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (b, enc_len, cfg.d_model)) * 0.3
        cache = prefill_cross_cache(params, cfg, frames, cache)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    toks = jnp.zeros((b,), jnp.int32)
    t0 = time.perf_counter()
    out = []
    for pos in range(steps):
        logits, cache = step(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: {steps} steps x batch {b} in {dt:.2f}s "
          f"({steps*b/dt:.1f} tok/s); sample row: "
          f"{[int(t[0]) for t in out[:16]]}")


def _fit_gp(args):
    from repro.core import OuterConfig, fit
    from repro.data.synthetic import load_dataset
    from repro.solvers import SolverConfig

    ds = load_dataset(args.dataset, max_n=args.max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=32,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=args.train_steps, bm=512, bn=512,
    )
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(args.seed))
    return ds, cfg, res.state


def serve_gp_compat(args, ds, cfg, state):
    """Legacy per-request loop, minimally fixed: the `pathwise_predict` jit
    is built ONCE outside the request loop, and the tail block is padded to
    the fixed request width so ragged shapes never retrace."""
    from functools import partial

    from repro.core import pathwise_predict, predictive_metrics

    width = 64
    predict = jax.jit(partial(
        pathwise_predict, kind=None, bm=cfg.bm, bn=cfg.bn
    ))
    n_test = ds.x_test.shape[0]
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test)
        xq = ds.x_test[lo : lo + width]
        take = xq.shape[0]
        if take < width:  # pad the tail block instead of wrapping/retracing
            xq = jnp.pad(xq, ((0, width - take), (0, 0)))
        pred = predict(ds.x_train, xq, state.carry_v, state.probes,
                       state.params)
        jax.block_until_ready(pred.mean)
    dt = time.perf_counter() - t0
    m = predictive_metrics(ds.y_test[:width],
                           pathwise_predict(ds.x_train, ds.x_test[:width],
                                            state.carry_v, state.probes,
                                            state.params),
                           state.params)
    print(f"[serve-gp compat] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s) — ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")


def serve_gp(args, ds=None, cfg=None, state=None):
    """Engine-based serving: fit -> export `ServableGP` -> bucketed engine.

    Steady state is zero retraces (all bucket executables compiled by
    `warmup`) and zero linear solves (eq. 16 amortisation via the frozen
    correction matrix).
    """
    import numpy as np

    from repro.core import predictive_metrics
    from repro.serve import BucketedEngine, OnlineGP, export_servable

    if ds is None:
        ds, cfg, state = _fit_gp(args)
    if args.compat:
        return serve_gp_compat(args, ds, cfg, state)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    model = export_servable(state, ds.x_train)
    engine = BucketedEngine(model, buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    compiles = engine.warmup()

    width = 64
    n_test = ds.x_test.shape[0]
    lat = []
    t0 = time.perf_counter()
    for i in range(args.requests):
        lo = (i * width) % max(1, n_test - 1)
        xq = ds.x_test[lo : lo + width]
        ts = time.perf_counter()
        pred = engine.submit(xq)
        jax.block_until_ready(pred.mean)
        lat.append(time.perf_counter() - ts)
    dt = time.perf_counter() - t0
    now = engine.num_compiles()
    retraces = None if (compiles is None or now is None) else now - compiles

    if args.refresh_every and n_test > 0:
        blk = min(width, n_test)
        online = OnlineGP(ds.x_train, ds.y_train, state, cfg)
        online.append(ds.x_test[:blk], ds.y_test[:blk])
        report = online.refresh_into(engine, budget_epochs=10.0)
        print(f"[serve-gp] online refresh: +{blk} rows -> n={report.n}, "
              f"{report.epochs:.1f} epochs, res_y={report.res_y:.3f}")

    m = predictive_metrics(
        ds.y_test[:width], engine.submit(ds.x_test[:width]), state.params
    )
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    retrace_msg = "n/a (no cache introspection)" if retraces is None else retraces
    print(f"[serve-gp] {args.requests} requests x {width} in {dt:.2f}s "
          f"({args.requests*width/dt:.1f} q/s, p50={p50:.1f}ms p99={p99:.1f}ms) "
          f"— buckets={buckets}, retraces after warmup={retrace_msg}, "
          f"ZERO solves at serve time; "
          f"rmse={float(m['rmse']):.4f} llh={float(m['llh']):.4f}")
    if retraces:
        raise SystemExit(f"steady-state serving retraced {retraces}x")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gp-iterative")
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=2000)
    ap.add_argument("--train-steps", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buckets", default="16,64,256",
                    help="comma-separated GP engine row buckets")
    ap.add_argument("--compat", action="store_true",
                    help="legacy per-request GP loop (jit hoisted, tail padded)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="if set, run one warm online refresh after serving")
    args = ap.parse_args(argv)
    if args.arch == "gp-iterative":
        serve_gp(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
