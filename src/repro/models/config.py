"""Model configuration for the assigned architecture pool.

One `ModelConfig` describes any of the 10 assigned LM-family architectures:
dense / GQA transformers, sliding-window & local:global & chunked-local
attention variants, MoE (top-k with optional shared expert), Mamba2 SSD
blocks and hybrid interleavings, encoder-decoder (Whisper), and stubbed
audio/vision frontends (per spec the modality frontend supplies precomputed
frame/patch embeddings).

Layer heterogeneity is expressed as a *pattern*: a period of `LayerSpec`s
repeated `num_layers / len(pattern)` times. The runtime scans over periods
(small HLO, true interleaving order preserved).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Attention kinds
ATTN_FULL = "full"  # causal full attention
ATTN_SWA = "swa"  # sliding-window causal
ATTN_CHUNKED = "chunked"  # causal within fixed chunks (llama4-style local)
ATTN_BIDIR = "bidir"  # encoder (non-causal) attention
MAMBA = "mamba"  # Mamba2 SSD block (attention-free)


@dataclass(frozen=True)
class LayerSpec:
    kind: str = ATTN_FULL  # full | swa | chunked | mamba
    window: int = 0  # swa window / chunk size (tokens)
    moe: bool = False  # MoE FFN instead of dense FFN


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on shared expert


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length (train path)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (self-attention only, bidirectional)."""

    num_layers: int = 32
    # Decoder cross-attends to the encoded sequence; the conv frontend is a
    # stub (identity-shaped linear) fed precomputed frame embeddings.
    max_source_len: int = 4096


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: precomputed embeddings enter via input_specs."""

    kind: str = "none"  # none | audio | vision
    num_prefix: int = 0  # vision: patches prepended to the text sequence
    embed_dim: int = 0  # incoming embedding dim (projected to d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab_size: int = 32_000
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True  # False -> sinusoidal absolute positions (whisper)
    norm_eps: float = 1e-5
    mlp_activation: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    decoder_len: int = 448  # enc-dec only: decoder text length in training
    # Numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # MoE combine: one fused scatter-add over all experts (True) vs one
    # read-modify-write per expert (False; the naive baseline — E x the
    # combine HBM traffic, kept for the §Perf A/B).
    moe_single_scatter: bool = True
    # Rematerialisation policy for the period scan body:
    #   "full" — save only period boundaries, recompute everything (min
    #            memory, +1 forward of flops AND weight re-reads in bwd)
    #   "dots" — save matmul outputs (jax.checkpoint_policies), skip the
    #            recompute at the cost of activation memory
    remat_policy: str = "full"

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (TP divisibility; Megatron rule)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def num_periods(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern period {len(self.pattern)}"
        )
        return self.num_layers // len(self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def has_subquadratic_path(self) -> bool:
        """True unless the arch is PURE full attention — hybrids (jamba,
        llama4, gemma3) and SSM/windowed archs run long_500k; the few full
        layers they retain are O(S) per decoded token, which is the shape's
        point (DESIGN.md §5 skip rule)."""
        return any(
            spec.kind in (MAMBA, ATTN_SWA, ATTN_CHUNKED)
            for spec in self.pattern
        )

    def active_params_per_token_layers(self) -> int:
        """Approximate ACTIVE parameter count (MoE counts top_k+shared experts
        only) — used for MODEL_FLOPS = 6 * N_active * D in the roofline."""
        n = 0
        # embeddings (counted once, not per layer here)
        n += self.padded_vocab * self.d_model * (1 if self.tie_embeddings else 2)
        for spec in self.pattern:
            per = 0
            if spec.kind == MAMBA:
                ssm = self.ssm
                d_in = ssm.d_inner(self.d_model)
                nh = ssm.num_heads(self.d_model)
                d_proj = 2 * d_in + 2 * ssm.d_state + nh
                per += self.d_model * d_proj  # in_proj
                per += d_in * self.d_model  # out_proj
                per += ssm.conv_width * (d_in + 2 * ssm.d_state)  # conv
            else:
                per += self.d_model * (self.q_dim + 2 * self.kv_dim)
                per += self.q_dim * self.d_model
            # FFN
            mults = 3 if self.mlp_activation == "swiglu" else 2
            if spec.moe and self.moe is not None:
                active = self.moe.top_k + (1 if self.moe.shared_expert else 0)
                per += active * mults * self.d_model * self.d_ff
                per += self.d_model * self.moe.num_experts  # router
            elif self.d_ff > 0:
                per += mults * self.d_model * self.d_ff
            n += per * self.num_periods
        if self.is_encdec:
            # encoder layers: self-attn + dense FFN each; cross-attn in decoder
            enc_per = self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model
            mults = 3 if self.mlp_activation == "swiglu" else 2
            enc_per += mults * self.d_model * self.d_ff
            n += enc_per * self.encoder.num_layers
            n += self.num_layers * (
                self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model
            )  # cross-attention blocks
        return n

    def total_params(self) -> int:
        """Approximate TOTAL parameter count (all experts)."""
        if self.moe is None:
            return self.active_params_per_token_layers()
        base = dataclasses.replace(
            self,
            moe=MoEConfig(
                num_experts=self.moe.num_experts,
                top_k=self.moe.num_experts,  # count all experts
                shared_expert=self.moe.shared_expert,
            ),
        )
        return base.active_params_per_token_layers()
