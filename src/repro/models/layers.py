"""Transformer / SSM building blocks shared by all 10 assigned architectures.

Pure functions over nested-dict parameter pytrees (fp32 storage, bf16
compute). Every block has a *train* path (full sequence) and a *decode* path
(one token against a cache). Sharding is expressed with
`repro.distributed.sharding.constrain`, so the same code runs on a 1-device
CPU smoke test and the 512-chip dry-run mesh.

Attention parallelism policy (divisibility-robust across the pool):
  * head-parallel over "model" when num_(kv_)heads % tp == 0
  * otherwise sequence-parallel: Q-rows (train) / KV-cache rows (decode)
    are sharded over "model"; XLA inserts the distributed-softmax
    collectives (all-reduce of max / sum — the flash-decoding combine).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    DP,
    TP,
    axis_size,
    constrain,
    get_global_mesh,
)
from repro.models.config import (
    ATTN_BIDIR,
    ATTN_CHUNKED,
    ATTN_SWA,
    LayerSpec,
    ModelConfig,
)

NEG_INF = -1e30


def _tp_size() -> int:
    mesh = get_global_mesh()
    return axis_size(mesh, TP) if mesh is not None else 1


# --------------------------------------------------------------------------
# Normalisation, positions
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angles = pos / jnp.power(10_000.0, 2.0 * i / dim)
    emb = jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)
    return emb.astype(dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def _attn_mask(
    seq_len: int, kind: str, window: int, dtype=jnp.float32
) -> Optional[jax.Array]:
    """(S, S) additive mask for the train path (None = no masking)."""
    if kind == ATTN_BIDIR:
        return None
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    allowed = j <= i  # causal
    if kind == ATTN_SWA and window > 0:
        allowed &= (i - j) < window
    elif kind == ATTN_CHUNKED and window > 0:
        allowed &= (i // window) == (j // window)
    return jnp.where(allowed, 0.0, NEG_INF).astype(dtype)


def _gqa_scores_and_out(q, k, v, mask, scale):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd). Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        scores = scores + mask  # mask broadcasts over (b, kv, g)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def attention_train(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,
) -> jax.Array:
    """Full-sequence (GQA) attention; x: (B, S, D)."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    w = lambda name: params[name].astype(xc.dtype)

    q = xc @ w("wq")
    k = xc @ w("wk")
    v = xc @ w("wv")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    tp = _tp_size()
    if h % tp == 0 and kv % tp == 0:
        q = constrain(q, DP, None, TP, None)
        k = constrain(k, DP, None, TP, None)
        v = constrain(v, DP, None, TP, None)
    else:  # sequence-parallel fallback (gemma3 8H, whisper 20H)
        q = constrain(q, DP, TP, None, None)
        k = constrain(k, DP, None, None, None)
        v = constrain(v, DP, None, None, None)

    mask = _attn_mask(s, spec.kind, spec.window, dtype=jnp.float32)
    out = _gqa_scores_and_out(q, k, v, mask, 1.0 / math.sqrt(hd))
    out = out.reshape(b, s, h * hd)
    return (out @ w("wo")).astype(x.dtype)


def cross_attention_train(
    params: dict, x: jax.Array, enc: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Decoder cross-attention (whisper); x: (B,S,D), enc: (B,T,D)."""
    b, s, d = x.shape
    t = enc.shape[1]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    ec = enc.astype(xc.dtype)
    w = lambda name: params[name].astype(xc.dtype)
    q = (xc @ w("wq")).reshape(b, s, h, hd)
    k = (ec @ w("wk")).reshape(b, t, kv, hd)
    v = (ec @ w("wv")).reshape(b, t, kv, hd)
    out = _gqa_scores_and_out(q, k, v, None, 1.0 / math.sqrt(hd))
    return (out.reshape(b, s, h * hd) @ w("wo")).astype(x.dtype)


def attention_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D) current token hidden
    cache: dict,  # {"k": (B, S_max, KV, hd), "v": ...}
    pos: jax.Array,  # scalar int32: index of the current token
    cfg: ModelConfig,
    spec: LayerSpec,
) -> tuple[jax.Array, dict]:
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_max = cache["k"].shape[1]
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    w = lambda name: params[name].astype(xc.dtype)

    q = xc @ w("wq")
    k_new = xc @ w("wk")
    v_new = xc @ w("wv")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k_new = k_new + params["bk"].astype(k_new.dtype)
        v_new = v_new + params["bv"].astype(v_new.dtype)
    q = q.reshape(b, 1, h, hd)
    k_new = k_new.reshape(b, 1, kv, hd)
    v_new = v_new.reshape(b, 1, kv, hd)
    if cfg.use_rope:
        p1 = pos[None] if pos.ndim == 0 else pos
        q = rope(q, p1, cfg.rope_theta)
        k_new = rope(k_new, p1, cfg.rope_theta)

    # Windowed layers use a RING-BUFFER cache of length min(window, s_max):
    # slot j holds absolute position pos - ((pos - j) mod W), always inside
    # the attention window. This keeps long_500k local layers at O(window)
    # memory AND avoids dynamic-slicing a sequence-sharded cache (which the
    # SPMD partitioner can only realise as an all-gather of the full cache).
    windowed = (
        spec.kind in (ATTN_SWA, ATTN_CHUNKED)
        and spec.window > 0
        and s_max <= spec.window
    )
    slot = pos % s_max if windowed else pos
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0)
    )
    k_use, v_use = k_cache, v_cache

    j = jnp.arange(s_max)
    if not windowed:
        valid = j <= pos
    elif spec.kind == ATTN_SWA:
        # every written slot is inside the sliding window by construction
        valid = jnp.logical_or(j <= pos, pos >= s_max)
    else:  # chunked: only slots written in the current chunk
        valid = j <= (pos % s_max)

    tp = _tp_size()
    if kv % tp == 0:
        k_use = constrain(k_use, DP, None, TP, None)
        v_use = constrain(v_use, DP, None, TP, None)
    else:  # KV-sequence sharding: flash-decoding style distributed softmax
        k_use = constrain(k_use, DP, TP, None, None)
        v_use = constrain(v_use, DP, TP, None, None)

    mask = jnp.where(valid[None, :], 0.0, NEG_INF)[:, None, None, None, :]
    out = _gqa_scores_and_out(q, k_use, v_use, mask, 1.0 / math.sqrt(hd))
    out = out.reshape(b, 1, h * hd)
    y = (out @ w("wo")).astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def cross_attention_decode(
    params: dict, x: jax.Array, cache: dict, cfg: ModelConfig
) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decode)."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    w = lambda name: params[name].astype(xc.dtype)
    q = (xc @ w("wq")).reshape(b, 1, h, hd)
    out = _gqa_scores_and_out(
        q, cache["ck"].astype(xc.dtype), cache["cv"].astype(xc.dtype),
        None, 1.0 / math.sqrt(hd),
    )
    return (out.reshape(b, 1, h * hd) @ w("wo")).astype(x.dtype)


# --------------------------------------------------------------------------
# FFN: dense + MoE
# --------------------------------------------------------------------------
def _ffn_apply(p: dict, x: jax.Array, activation: str) -> jax.Array:
    """x: (..., D) -> (..., D), weights fetched from p (fp32->compute dtype)."""
    w = lambda name: p[name].astype(x.dtype)
    if activation == "swiglu":
        g = jax.nn.silu(x @ w("wi_gate"))
        u = x @ w("wi_up")
        h = constrain(g * u, DP, None, TP)
        return h @ w("wo")
    h = jax.nn.gelu(x @ w("wi"))
    h = constrain(h, DP, None, TP)
    return h @ w("wo")


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    return _ffn_apply(params, xc, cfg.mlp_activation).astype(x.dtype)


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE with static per-expert capacity (loop-over-experts dispatch).

    Instead of the GShard (B,S,E,C) dispatch one-hot — O(B*S*E*C) memory —
    each expert gathers its top-C tokens (lax.top_k on its gate column) and
    scatter-adds its output. Capacity C = ceil(S * top_k * cf / E); lower-
    weight overflow tokens are dropped (standard capacity semantics).
    Expert FFN weights are stacked (E, D, F) with F sharded over "model".
    """
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    # Keep the dispatch operands batch-sharded only: gathers/scatters over
    # the token dim must not see a model-sharded feature dim (SPMD gather
    # partitioning cannot slice a sharded operand dim).
    xc = constrain(xc, DP, None, None)

    router_logits = (
        xc.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    )  # (B,S,E) in fp32 for a stable softmax
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # Per-expert combine weight (B,S): sum of top-k weights routed to e.
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    combine = jnp.einsum("bske,bsk->bse", onehot, top_vals)  # (B,S,E)

    cap = max(1, int(math.ceil(s * k * moe.capacity_factor / e)))
    cap = min(cap, s)
    batch_ix = jnp.arange(b)[:, None]
    outs, idxs = [], []
    for ei in range(e):
        w_e = combine[:, :, ei]  # (B,S)
        scores, idx = jax.lax.top_k(w_e, cap)  # (B,C)
        idx = constrain(idx, DP, None)
        xg = jnp.take_along_axis(xc, idx[:, :, None], axis=1)  # (B,C,D)
        xg = constrain(xg, DP, None, None)
        pe = {
            key: params[key][ei]
            for key in params
            if key.startswith("wi") or key == "wo"
        }
        out = _ffn_apply(pe, xg, cfg.mlp_activation)  # (B,C,D)
        out = constrain(out, DP, None, None)
        outs.append(out * scores[:, :, None].astype(out.dtype))
        idxs.append(idx)
    if cfg.moe_single_scatter:
        # ONE combined scatter-add: scattering per expert would read+write
        # the full (B,S,D) output E times (E x the combine HBM traffic).
        all_out = jnp.concatenate(outs, axis=1)  # (B, E*C, D)
        all_idx = jnp.concatenate(idxs, axis=1)  # (B, E*C)
        y = jnp.zeros((b, s, d), dtype=xc.dtype)
        y = y.at[batch_ix, all_idx].add(all_out)
    else:  # naive per-expert combine (baseline for the §Perf A/B)
        y = jnp.zeros((b, s, d), dtype=xc.dtype)
        for out, idx in zip(outs, idxs):
            y = y.at[batch_ix, idx].add(out)
    y = constrain(y, DP, None, None)
    if moe.shared_expert:
        shared = {key[7:]: params[key] for key in params if key.startswith("shared_")}
        y = y + _ffn_apply(shared, xc, cfg.mlp_activation)
    return y.astype(x.dtype)
