from repro.models.config import (
    ATTN_BIDIR,
    ATTN_CHUNKED,
    ATTN_FULL,
    ATTN_SWA,
    MAMBA,
    EncoderConfig,
    FrontendConfig,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.models.transformer import (
    abstract_params,
    decode_step,
    encode,
    forward_encdec,
    forward_lm,
    init_cache,
    init_params,
)
from repro.models.steps import (
    batch_pspec,
    cache_shardings,
    concrete_batch,
    input_specs,
    lm_loss,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    param_pspec_tree,
    param_shardings,
)

__all__ = [
    "ATTN_BIDIR", "ATTN_CHUNKED", "ATTN_FULL", "ATTN_SWA", "MAMBA",
    "EncoderConfig", "FrontendConfig", "LayerSpec", "ModelConfig",
    "MoEConfig", "SSMConfig",
    "abstract_params", "decode_step", "encode", "forward_encdec",
    "forward_lm", "init_cache", "init_params",
    "batch_pspec", "cache_shardings", "concrete_batch", "input_specs",
    "lm_loss", "make_prefill_step", "make_serve_step", "make_train_step",
    "param_pspec_tree", "param_shardings",
]
