"""Train / prefill / serve step builders + parameter sharding rules +
abstract ``input_specs`` for every (arch x shape) dry-run cell.

train_step: microbatched grad accumulation (lax.scan) -> global fp32 grads
-> Adam. Losses use one-hot label contraction so the vocab-sharded logits
never require a gather over a sharded dimension.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import (
    DP,
    FSDP,
    TP,
    axis_size,
    valid_spec,
)
from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward_encdec,
    forward_lm,
    init_cache,
)
from repro.train.adam import AdamConfig, AdamState, adam_update


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------
def lm_loss(
    logits: jax.Array,  # (B, S, Vp) compute dtype
    labels: jax.Array,  # (B, S) int32 (ids < vocab_size)
    mask: jax.Array,  # (B, S) f32
) -> jax.Array:
    """Mean next-token cross entropy.

    The label term uses a one-hot contraction (not take_along_axis) so it
    shards cleanly when logits are vocab-sharded over "model"; the lse term
    reduces over the sharded vocab with XLA-inserted collectives.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def _forward_loss(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    if cfg.is_encdec:
        logits = forward_encdec(params, cfg, batch["frames"], batch["tokens"])
        return lm_loss(logits, batch["labels"], batch["mask"])
    patch = batch.get("patch_embeds", None)
    logits = forward_lm(params, cfg, batch["tokens"], patch_embeds=patch)
    if patch is not None:
        # loss on the text positions only (vision prefix is unsupervised)
        npfx = patch.shape[1]
        logits = logits[:, npfx:, :]
    return lm_loss(logits, batch["labels"], batch["mask"])


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, adam_cfg: Optional[AdamConfig] = None,
                    num_microbatches: int = 1):
    adam_cfg = adam_cfg or AdamConfig(learning_rate=3e-4, grad_clip_norm=1.0)

    def train_step(params, opt: AdamState, batch: dict):
        if num_microbatches > 1:
            def micro(g_acc, mb):
                loss, g = jax.value_and_grad(_forward_loss)(params, cfg, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return g_acc, loss

            mb_batch = jax.tree.map(
                lambda x: x.reshape(
                    (num_microbatches, x.shape[0] // num_microbatches)
                    + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, losses = jax.lax.scan(micro, g0, mb_batch)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(_forward_loss)(params, cfg, batch)
        new_params, new_opt = adam_update(grads, opt, params, adam_cfg)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch: dict):
        if cfg.is_encdec:
            return forward_encdec(params, cfg, batch["frames"], batch["tokens"])
        return forward_lm(
            params, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds", None),
        )

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    return serve_step


# --------------------------------------------------------------------------
# Parameter / input sharding rules
# --------------------------------------------------------------------------
_COL_PARALLEL = {
    "wq", "wk", "wv", "wi", "wi_gate", "wi_up", "in_proj", "frontend_proj",
    "lm_head", "shared_wi", "shared_wi_gate", "shared_wi_up",
}
_ROW_PARALLEL = {"wo", "out_proj", "shared_wo"}
_TP_VECS = {"bq", "bk", "bv", "conv_b", "norm"}


def _base_spec(name: str, ndim_trailing: int):
    if name == "embed":
        # Vocab-dim sharding: XLA partitions the token gather as
        # local-take + mask + psum (no table all-gather, no D-sharded
        # activation mismatch under jvp).
        return (TP, None)
    if name in _COL_PARALLEL:
        return (FSDP, TP)
    if name in _ROW_PARALLEL:
        return (TP, FSDP)
    if name == "conv_w":
        return (None, TP)
    if name in _TP_VECS:
        return (TP,)
    if name == "router":
        return (None, None)
    return ()  # replicate (ln scales, A_log, D, dt_bias, ...)


def param_pspec_tree(cfg: ModelConfig, params_abstract, serving: bool = False) -> dict:
    """PartitionSpec pytree mirroring the parameter pytree.

    Specs are right-aligned: stacked period / expert leading axes are
    unsharded (periods are scanned; experts looped).

    ``serving=True`` drops the FSDP storage axis: a serving fleet has no
    optimiser state, so weights stay RESIDENT per chip (TP-sharded only) and
    every per-step FSDP all-gather disappears."""

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        base = _base_spec(name, leaf.ndim)
        if serving:
            base = tuple(None if a == FSDP else a for a in base)
        pad = (None,) * (leaf.ndim - len(base))
        return pad + tuple(base)

    return jax.tree_util.tree_map_with_path(spec_for, params_abstract)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_abstract,
                    serving: bool = False):
    specs = param_pspec_tree(cfg, params_abstract, serving=serving)
    return jax.tree.map(
        lambda leaf, spec: NamedSharding(mesh, valid_spec(mesh, leaf.shape, spec)),
        params_abstract,
        specs,
    )


def opt_shardings(mesh: Mesh, param_sh, opt_abstract: AdamState):
    return AdamState(
        step=NamedSharding(mesh, P()),
        mu=param_sh,
        nu=param_sh,
    )


def batch_pspec(batch_abstract, mesh: Mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, valid_spec(mesh, leaf.shape, (DP,) + (None,) * (leaf.ndim - 1))
        ),
        batch_abstract,
    )


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_abstract):
    """KV cache: batch over DP; kv-heads over TP when divisible, else the
    sequence dim over TP (flash-decoding layout). Leading dim = periods."""
    tp = axis_size(mesh, TP)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ck", "cv"):  # (P, B, S, KV, hd)
            if cfg.num_kv_heads % tp == 0:
                spec = (None, DP, None, TP, None)
            else:
                spec = (None, DP, TP, None, None)
        elif name == "ssm":  # (P, B, NH, hd, N)
            spec = (None, DP, TP, None, None)
        elif name == "conv":  # (P, B, W-1, conv_dim)
            spec = (None, DP, None, TP)
        else:
            spec = (None, DP)
        return NamedSharding(mesh, valid_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map_with_path(spec_for, cache_abstract)


# --------------------------------------------------------------------------
# Abstract input specs per (arch x shape) — dry-run inputs (no allocation)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32

    if shape.step == "train":
        if cfg.is_encdec:
            sd = cfg.decoder_len
            batch = {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, sd), i32),
                "labels": jax.ShapeDtypeStruct((b, sd), i32),
                "mask": jax.ShapeDtypeStruct((b, sd), f32),
            }
        elif cfg.frontend.kind == "vision":
            npfx = cfg.frontend.num_prefix
            st = s - npfx
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (b, npfx, cfg.frontend.embed_dim), f32
                ),
                "labels": jax.ShapeDtypeStruct((b, st), i32),
                "mask": jax.ShapeDtypeStruct((b, st), f32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "mask": jax.ShapeDtypeStruct((b, s), f32),
            }
        return {"batch": batch}

    if shape.step == "prefill":
        if cfg.is_encdec:
            return {"batch": {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, cfg.decoder_len), i32),
            }}
        if cfg.frontend.kind == "vision":
            npfx = cfg.frontend.num_prefix
            return {"batch": {
                "tokens": jax.ShapeDtypeStruct((b, s - npfx), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (b, npfx, cfg.frontend.embed_dim), f32
                ),
            }}
        return {"batch": {"tokens": jax.ShapeDtypeStruct((b, s), i32)}}

    # decode: one token against a seq_len cache
    enc_len = min(s, cfg.encoder.max_source_len) if cfg.is_encdec else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, enc_len=enc_len)
    )
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, key) -> dict:
    """Materialised random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    vocab = cfg.vocab_size

    def fill(leaf, k):
        if leaf.dtype == jnp.int32 and leaf.ndim >= 1:
            return jax.random.randint(k, leaf.shape, 0, vocab, dtype=jnp.int32)
        if leaf.dtype == jnp.int32:
            return jnp.zeros(leaf.shape, jnp.int32)
        return jax.random.normal(k, leaf.shape, leaf.dtype) * 0.1

    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(leaves))
    out = [
        fill(l, k) if not isinstance(l, jax.Array) else l
        for l, k in zip(leaves, keys)
    ]
    tree = jax.tree.unflatten(treedef, out)
    if "batch" in tree and "mask" in tree["batch"]:
        tree["batch"]["mask"] = jnp.ones_like(tree["batch"]["mask"])
    if "pos" in tree:
        tree["pos"] = jnp.asarray(shape.seq_len // 2, jnp.int32)
    return tree
