"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

TPU adaptation (DESIGN.md §3): the SSD *chunked* train path is used instead
of the CUDA selective-scan kernel — within a chunk the recurrence becomes
dense (masked) matmuls that map onto the MXU; across chunks a short
`lax.scan` carries the (heads, head_dim, d_state) state. This is the
algorithm the SSD paper itself advocates for matmul hardware.

Decode is the O(1) recurrence: h' = h * exp(dt*A) + dt * (B outer x);
y = C . h + D*x, plus a rolling depthwise-conv state.

Single B/C group (n_groups=1), following mamba2-780m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import DP, TP, constrain
from repro.models.config import ModelConfig


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    """in_proj output -> (z, xbc, dt) with xbc = [x | B | C]."""
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    nh = ssm.num_heads(cfg.d_model)
    conv_dim = d_in + 2 * ssm.d_state
    z, xbc, dt = jnp.split(proj, [d_in, d_in + conv_dim], axis=-1)
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _ssd_chunked(x, dt, a, bmat, cmat, chunk):
    """SSD scan over chunks.

    x: (B,L,H,P); dt: (B,L,H); a: (H,) negative; bmat/cmat: (B,L,N).
    Returns y: (B,L,H,P).
    """
    b, l, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, f"seq {l} % chunk {q} != 0"
    nc = l // q

    xd = x * dt[..., None]  # fold dt into inputs (B,L,H,P)
    la = dt * a  # (B,L,H) log-decay per step (negative)

    xc = xd.reshape(b, nc, q, h, p)
    lac = la.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    cum = jnp.cumsum(lac, axis=2)  # (B,NC,Q,H) inclusive
    total = cum[:, :, -1, :]  # (B,NC,H)

    # Intra-chunk: Y[t] += sum_{s<=t} C_t.B_s exp(cum_t - cum_s) xd_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,T,S,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bctn,bcsn->bcts", cc, bc)  # (B,NC,T,S)
    y_intra = jnp.einsum(
        "bcts,bctsh,bcshp->bcthp", scores, decay.astype(scores.dtype),
        xc.astype(scores.dtype),
    )

    # Chunk summary state: S_c = sum_s exp(total - cum_s) B_s (x) xd_s
    decay_out = jnp.exp(total[:, :, None, :] - cum)  # (B,NC,Q,H)
    s_chunk = jnp.einsum(
        "bcsn,bcsh,bcshp->bchpn", bc, decay_out.astype(bc.dtype),
        xc.astype(bc.dtype),
    )  # (B,NC,H,P,N)

    # Inter-chunk recurrence: H_{c+1} = H_c * exp(total_c) + S_c
    def step(hstate, inp):
        s_c, tot_c = inp  # (B,H,P,N), (B,H)
        out = hstate  # state entering this chunk
        hstate = hstate * jnp.exp(tot_c)[:, :, None, None] + s_c
        return hstate, out

    h0 = jnp.zeros((b, h, p, n), dtype=s_chunk.dtype)
    _, h_enter = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)  # (B,NC,H,P,N)

    # Inter-chunk output: Y[t] += C_t . (exp(cum_t) * H_enter)
    y_inter = jnp.einsum(
        "bctn,bcth,bchpn->bcthp", cc, jnp.exp(cum).astype(cc.dtype), h_enter
    )
    return (y_intra + y_inter).reshape(b, l, h, p)


def mamba_train(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence Mamba2 block. x: (B, L, D) -> (B, L, D)."""
    ssm = cfg.ssm
    b, l, d = x.shape
    d_in = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    hd = ssm.head_dim
    n = ssm.d_state
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    w = lambda name: params[name].astype(xc.dtype)

    proj = xc @ w("in_proj")  # (B,L, 2*d_in + 2N + NH)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = constrain(xbc, DP, None, TP)

    # Depthwise causal conv over the (x|B|C) streams, width W.
    wt = params["conv_w"].astype(xc.dtype)  # (W, conv_dim)
    width = wt.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    conv = sum(
        pads[:, i : i + l, :] * wt[i][None, None, :] for i in range(width)
    )
    xbc = jax.nn.silu(conv + params["conv_b"].astype(xc.dtype))

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(b, l, nh, hd)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,L,NH)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (NH,)

    y = _ssd_chunked(
        xs.astype(jnp.float32), dt, a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32), ssm.chunk,
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32
    )
    y = y.reshape(b, l, d_in).astype(xc.dtype)
    y = y * jax.nn.silu(z)  # gated
    y = rms_norm_gated(y, params["norm"], cfg.norm_eps)
    y = constrain(y, DP, None, TP)
    return (y @ w("out_proj")).astype(x.dtype)


def rms_norm_gated(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def mamba_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # {"conv": (B, W-1, conv_dim), "ssm": (B, NH, HD, N)}
    cfg: ModelConfig,
) -> tuple[jax.Array, dict]:
    """O(1) per-token Mamba2 recurrence."""
    ssm = cfg.ssm
    b, _, d = x.shape
    d_in = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    hd = ssm.head_dim
    n = ssm.d_state
    xc = x.astype(jnp.bfloat16) if cfg.compute_dtype == "bfloat16" else x
    w = lambda name: params[name].astype(xc.dtype)

    proj = (xc @ w("in_proj"))[:, 0]  # (B, ...)
    z, xbc, dt_raw = _split_proj(proj, cfg)

    # Rolling conv state: window = [cache | current]
    wt = params["conv_w"].astype(xc.dtype)  # (W, conv_dim)
    window = jnp.concatenate(
        [cache["conv"].astype(xc.dtype), xbc[:, None, :]], axis=1
    )  # (B, W, conv_dim)
    conv = jnp.einsum("bwc,wc->bc", window, wt) + params["conv_b"].astype(xc.dtype)
    xbc_act = jax.nn.silu(conv)
    new_conv = window[:, 1:, :]

    xs, bvec, cvec = jnp.split(xbc_act, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(b, nh, hd)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, NH)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (NH,)

    h = cache["ssm"].astype(jnp.float32)  # (B,NH,HD,N)
    decay = jnp.exp(dt * a)[:, :, None, None]
    upd = (
        dt[:, :, None, None]
        * xs.astype(jnp.float32)[:, :, :, None]
        * bvec.astype(jnp.float32)[:, None, None, :]
    )
    h_new = h * decay + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, cvec.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, d_in).astype(xc.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm_gated(y, params["norm"], cfg.norm_eps)
    out = (y @ w("out_proj"))[:, None, :].astype(x.dtype)
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "ssm": h_new.astype(cache["ssm"].dtype)}
