"""Model assembly for all assigned architectures.

Parameters are nested dicts of fp32 arrays. Layers are grouped into the
config's repeating *pattern period*; parameters of each period are stacked
on a leading axis and applied with `lax.scan` (true interleaving order,
O(period) HLO size). `jax.checkpoint` on the period body gives layer-
granular rematerialisation.

Three entry points:
  forward_lm       decoder-only training forward (vision prefix optional)
  forward_encdec   whisper-style encoder-decoder training forward
  decode_step      one-token serve step against a KV/SSM cache
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import DP, TP, constrain
from repro.models.config import (
    ATTN_BIDIR,
    MAMBA,
    LayerSpec,
    ModelConfig,
)
from repro.models.layers import (
    attention_decode,
    attention_train,
    cross_attention_decode,
    cross_attention_train,
    mlp,
    moe_ffn,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.ssm import mamba_decode, mamba_train


# --------------------------------------------------------------------------
# Initialisation
# --------------------------------------------------------------------------
def _dense(key, fan_in, fan_out, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, (fan_in, fan_out), dtype) * scale


def _init_attn(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    p = {
        "ln": jnp.zeros((d,), jnp.float32),
        "wq": _dense(ks[0], d, cfg.q_dim),
        "wk": _dense(ks[1], d, cfg.kv_dim),
        "wv": _dense(ks[2], d, cfg.kv_dim),
        "wo": _dense(ks[3], cfg.q_dim, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"ln": jnp.zeros((d,), jnp.float32)}
    if cfg.mlp_activation == "swiglu":
        p["wi_gate"] = _dense(ks[0], d, f)
        p["wi_up"] = _dense(ks[1], d, f)
        p["wo"] = _dense(ks[2], f, d)
    else:
        p["wi"] = _dense(ks[0], d, f)
        p["wo"] = _dense(ks[1], f, d)
    return p


def _init_moe(key, cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(d)

    def stack(k, fan_in, fan_out):
        return (
            jax.random.normal(k, (e, fan_in, fan_out), jnp.float32)
            / math.sqrt(fan_in)
        )

    p = {
        "ln": jnp.zeros((d,), jnp.float32),
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale,
    }
    if cfg.mlp_activation == "swiglu":
        p["wi_gate"] = stack(ks[1], d, f)
        p["wi_up"] = stack(ks[2], d, f)
        p["wo"] = stack(ks[3], f, d)
    else:
        p["wi"] = stack(ks[1], d, f)
        p["wo"] = stack(ks[2], f, d)
    if moe.shared_expert:
        shared = _init_ffn(ks[4], cfg)
        for k2, v in shared.items():
            if k2 != "ln":
                p["shared_" + k2] = v
    return p


def _init_mamba(key, cfg: ModelConfig) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    conv_dim = d_in + 2 * ssm.d_state
    d_proj = 2 * d_in + 2 * ssm.d_state + nh
    ks = jax.random.split(key, 4)
    dt = jnp.exp(
        jax.random.uniform(ks[2], (nh,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "in_proj": _dense(ks[0], d, d_proj),
        "conv_w": jax.random.normal(ks[1], (ssm.conv_width, conv_dim), jnp.float32)
        / math.sqrt(ssm.conv_width),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            1.0 + jnp.arange(nh, dtype=jnp.float32)
        ),  # A in [-1, -nh]
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": _dense(ks[3], d_in, d),
    }


def _init_block(key, cfg: ModelConfig, spec: LayerSpec, cross: bool) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    blk = {}
    if spec.kind == MAMBA:
        blk["mamba"] = _init_mamba(k1, cfg)
    else:
        blk["attn"] = _init_attn(k1, cfg)
    if cross:
        blk["cross"] = _init_attn(k3, cfg)
    if cfg.d_ff > 0:
        blk["ffn"] = _init_moe(k2, cfg) if (spec.moe and cfg.moe) else _init_ffn(k2, cfg)
    return blk


def _init_period(key, cfg: ModelConfig, cross: bool) -> dict:
    keys = jax.random.split(key, len(cfg.pattern))
    return {
        f"block_{i}": _init_block(keys[i], cfg, spec, cross)
        for i, spec in enumerate(cfg.pattern)
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab
    params = _init_params_f32(ks, cfg, vp)
    if cfg.param_dtype == "bfloat16":
        # bf16 parameter storage (fp32 Adam moments remain the master
        # statistics; adam_update computes in fp32 and casts back).
        params = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
    return params


def _init_params_f32(ks, cfg: ModelConfig, vp: int) -> dict:
    params = {
        "embed": jax.random.normal(ks[0], (vp, cfg.d_model), jnp.float32) * 0.02,
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": jax.vmap(
            lambda k: _init_period(k, cfg, cross=cfg.is_encdec)
        )(jax.random.split(ks[1], cfg.num_periods)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(ks[2], cfg.d_model, vp)
    if cfg.frontend.kind == "vision":
        params["frontend_proj"] = _dense(ks[3], cfg.frontend.embed_dim, cfg.d_model)
    if cfg.is_encdec:
        enc_spec = LayerSpec(kind=ATTN_BIDIR)
        enc_cfg = cfg  # same dims for encoder (whisper-large symmetric)
        params["encoder"] = {
            "frontend_proj": _dense(ks[4], cfg.d_model, cfg.d_model),
            "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "layers": jax.vmap(
                lambda k: {
                    "block_0": _init_block(k, enc_cfg, enc_spec, cross=False)
                }
            )(jax.random.split(ks[5], cfg.encoder.num_layers)),
        }
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# Train forward
# --------------------------------------------------------------------------
def _apply_block(
    params: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
    positions: jax.Array, enc: Optional[jax.Array],
) -> jax.Array:
    if spec.kind == MAMBA:
        x = x + mamba_train(params["mamba"], rms_norm(x, params["mamba"]["ln"], cfg.norm_eps), cfg)
    else:
        x = x + attention_train(
            params["attn"], rms_norm(x, params["attn"]["ln"], cfg.norm_eps),
            cfg, spec, positions,
        )
    if enc is not None and "cross" in params:
        x = x + cross_attention_train(
            params["cross"], rms_norm(x, params["cross"]["ln"], cfg.norm_eps),
            enc, cfg,
        )
    if "ffn" in params:
        h = rms_norm(x, params["ffn"]["ln"], cfg.norm_eps)
        if spec.moe and cfg.moe is not None:
            x = x + moe_ffn(params["ffn"], h, cfg)
        else:
            x = x + mlp(params["ffn"], h, cfg)
    return constrain(x, DP, None, None)


def _run_stack(
    stacked: dict, x: jax.Array, cfg: ModelConfig,
    pattern: tuple, positions: jax.Array, enc: Optional[jax.Array],
) -> jax.Array:
    def period_body(carry, period_params):
        h = carry
        for i, spec in enumerate(pattern):
            h = _apply_block(
                period_params[f"block_{i}"], h, cfg, spec, positions, enc
            )
        return h, None

    if cfg.remat and cfg.remat_policy == "dots":
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    elif cfg.remat:
        body = jax.checkpoint(period_body)
    else:
        body = period_body
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def forward_lm(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S_text)
    patch_embeds: Optional[jax.Array] = None,  # (B, P, E) vision stub
) -> jax.Array:
    """Decoder-only LM forward -> logits (B, S_total, padded_vocab)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    )
    if patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["frontend_proj"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
    x = constrain(x, DP, None, None)
    x = _run_stack(params["layers"], x, cfg, cfg.pattern, positions, None)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = x @ head.astype(x.dtype)
    return constrain(logits, DP, None, TP)


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (B, T, D)."""
    enc_p = params["encoder"]
    x = frames.astype(
        jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    ) @ enc_p["frontend_proj"].astype(
        jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    )
    t = x.shape[1]
    x = x + sinusoidal_positions(t, cfg.d_model, x.dtype)[None]
    x = constrain(x, DP, None, None)
    x = _run_stack(
        enc_p["layers"], x, cfg, (LayerSpec(kind=ATTN_BIDIR),),
        jnp.arange(t), None,
    )
    return rms_norm(x, enc_p["final_ln"], cfg.norm_eps)


def forward_encdec(
    params: dict, cfg: ModelConfig, frames: jax.Array, tokens: jax.Array
) -> jax.Array:
    """Encoder-decoder training forward -> decoder logits."""
    enc = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0).astype(enc.dtype)
    s = x.shape[1]
    x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
    x = constrain(x, DP, None, None)
    x = _run_stack(params["layers"], x, cfg, cfg.pattern, jnp.arange(s), enc)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = x @ head.astype(x.dtype)
    return constrain(logits, DP, None, TP)


# --------------------------------------------------------------------------
# Decode (serving)
# --------------------------------------------------------------------------
def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0,
    dtype=jnp.bfloat16,
) -> dict:
    """Per-period stacked cache pytree."""
    p = cfg.num_periods
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    period = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == MAMBA:
            ssm = cfg.ssm
            d_in = ssm.d_inner(cfg.d_model)
            conv_dim = d_in + 2 * ssm.d_state
            blk = {
                "conv": jnp.zeros((p, batch, ssm.conv_width - 1, conv_dim), dtype),
                "ssm": jnp.zeros(
                    (p, batch, ssm.num_heads(cfg.d_model), ssm.head_dim,
                     ssm.d_state), jnp.float32,
                ),
            }
        else:
            # Windowed layers get a ring buffer of length window (see
            # layers.attention_decode) — O(window) memory at any context.
            length = max_len
            if spec.kind in ("swa", "chunked") and spec.window > 0:
                length = min(spec.window, max_len)
            blk = {
                "k": jnp.zeros((p, batch, length, kv, hd), dtype),
                "v": jnp.zeros((p, batch, length, kv, hd), dtype),
            }
        if cfg.is_encdec:
            blk["ck"] = jnp.zeros((p, batch, enc_len, kv, hd), dtype)
            blk["cv"] = jnp.zeros((p, batch, enc_len, kv, hd), dtype)
        period[f"block_{i}"] = blk
    return period


def prefill_cross_cache(
    params: dict, cfg: ModelConfig, frames: jax.Array, cache: dict
) -> dict:
    """Encode source frames and fill the decoder cross-attention K/V cache
    (whisper serving prefill). Returns the updated cache."""
    enc = encode(params, cfg, frames)  # (B, T, D)
    b, t, _ = enc.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim

    def per_period(period_params, period_cache):
        new = {}
        for i in range(len(cfg.pattern)):
            blk_p = period_params[f"block_{i}"]
            blk_c = dict(period_cache[f"block_{i}"])
            wk = blk_p["cross"]["wk"].astype(enc.dtype)
            wv = blk_p["cross"]["wv"].astype(enc.dtype)
            blk_c["ck"] = (enc @ wk).reshape(b, t, kv, hd).astype(
                blk_c["ck"].dtype
            )
            blk_c["cv"] = (enc @ wv).reshape(b, t, kv, hd).astype(
                blk_c["cv"].dtype
            )
            new[f"block_{i}"] = blk_c
        return new

    return jax.vmap(per_period)(params["layers"], cache)


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache: dict,
    tokens: jax.Array,  # (B,) current token ids
    pos: jax.Array,  # scalar int32 position
) -> tuple[jax.Array, dict]:
    """One serving step: next-token logits + updated cache."""
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(
        jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    )
    if not cfg.use_rope:
        freq_row = _sinusoidal_at(pos, cfg.d_model, x.dtype)
        x = x + freq_row[None, None, :]
    x = constrain(x, DP, None, None)

    def period_body(carry, inp):
        h = carry
        period_params, period_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            blk_p = period_params[f"block_{i}"]
            blk_c = period_cache[f"block_{i}"]
            nc = dict(blk_c)
            if spec.kind == MAMBA:
                y, upd = mamba_decode(
                    blk_p["mamba"],
                    rms_norm(h, blk_p["mamba"]["ln"], cfg.norm_eps),
                    {"conv": blk_c["conv"], "ssm": blk_c["ssm"]}, cfg,
                )
                nc.update(upd)
            else:
                y, upd = attention_decode(
                    blk_p["attn"],
                    rms_norm(h, blk_p["attn"]["ln"], cfg.norm_eps),
                    {"k": blk_c["k"], "v": blk_c["v"]}, pos, cfg, spec,
                )
                nc.update(upd)
            h = h + y
            if cfg.is_encdec and "cross" in blk_p:
                h = h + cross_attention_decode(
                    blk_p["cross"],
                    rms_norm(h, blk_p["cross"]["ln"], cfg.norm_eps),
                    blk_c, cfg,
                )
            if "ffn" in blk_p:
                z = rms_norm(h, blk_p["ffn"]["ln"], cfg.norm_eps)
                if spec.moe and cfg.moe is not None:
                    h = h + moe_ffn(blk_p["ffn"], z, cfg)
                else:
                    h = h + mlp(blk_p["ffn"], z, cfg)
            new_cache[f"block_{i}"] = nc
        return h, new_cache

    x, new_cache = jax.lax.scan(period_body, x, (params["layers"], cache))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = (x[:, 0, :] @ head.astype(x.dtype)).astype(jnp.float32)
    return constrain(logits, DP, TP), new_cache


def _sinusoidal_at(pos: jax.Array, dim: int, dtype) -> jax.Array:
    i = jnp.arange(dim // 2, dtype=jnp.float32)
    angles = pos.astype(jnp.float32) / jnp.power(10_000.0, 2.0 * i / dim)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)]).astype(dtype)
