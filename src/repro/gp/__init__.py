from repro.gp.hyperparams import HyperParams, softplus, softplus_inverse
from repro.gp.kernels_math import (
    h_mvm_dense,
    h_mvm_streamed,
    kernel_matrix,
    kernel_mvm_streamed,
    regularised_kernel_matrix,
    scaled_sqdist,
)
from repro.gp.rff import RFFState, init_rff, prior_sample_at, rff_features
from repro.gp.exact import (
    exact_mll,
    exact_mll_grad,
    exact_posterior,
    gaussian_loglik,
    rmse,
)

__all__ = [
    "HyperParams",
    "softplus",
    "softplus_inverse",
    "h_mvm_dense",
    "h_mvm_streamed",
    "kernel_matrix",
    "kernel_mvm_streamed",
    "regularised_kernel_matrix",
    "scaled_sqdist",
    "RFFState",
    "init_rff",
    "prior_sample_at",
    "rff_features",
    "exact_mll",
    "exact_mll_grad",
    "exact_posterior",
    "gaussian_loglik",
    "rmse",
]
