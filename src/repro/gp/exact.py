"""Exact (Cholesky) GP computations — the paper's reference baseline.

Used for: small-n validation of iterative results, the exact-optimisation
trajectories of Figs. 5/8/11-13, the pivoted-Cholesky-free ground truth in
tests, and exact posterior predictives.

Everything here is O(n^3) compute / O(n^2) memory by design.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import kernel_matrix, regularised_kernel_matrix

LOG2PI = 1.8378770664093453


def exact_mll(
    x: jax.Array, y: jax.Array, params: HyperParams, kind: Optional[str] = None
) -> jax.Array:
    """Marginal log-likelihood (paper eq. 4), exact via Cholesky."""
    n = x.shape[0]
    h = regularised_kernel_matrix(x, params, kind=kind)
    chol = jnp.linalg.cholesky(h)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    return -0.5 * (y @ alpha) - 0.5 * logdet - 0.5 * n * LOG2PI


def exact_mll_grad(
    x: jax.Array, y: jax.Array, params: HyperParams, kind: Optional[str] = None
):
    """(mll, grad) wrt the raw hyperparameters via autodiff (exact)."""
    return jax.value_and_grad(lambda p: exact_mll(x, y, p, kind=kind))(params)


class ExactPosterior(NamedTuple):
    mean: jax.Array  # (m,)
    var: jax.Array  # (m,) latent-function variance (without noise)


def exact_posterior(
    x: jax.Array,
    y: jax.Array,
    xs: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
) -> ExactPosterior:
    """Exact posterior mean/variance at test inputs xs (paper eqs. 1-2)."""
    h = regularised_kernel_matrix(x, params, kind=kind)
    chol = jnp.linalg.cholesky(h)
    kxs = kernel_matrix(x, xs, params, kind=kind)  # (n, m)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    mean = kxs.T @ alpha
    tmp = jax.scipy.linalg.solve_triangular(chol, kxs, lower=True)  # (n, m)
    prior_var = params.signal**2
    var = jnp.maximum(prior_var - jnp.sum(tmp * tmp, axis=0), 1e-12)
    return ExactPosterior(mean=mean, var=var)


def gaussian_loglik(
    y: jax.Array, mean: jax.Array, var_plus_noise: jax.Array
) -> jax.Array:
    """Mean predictive log density (the paper's 'test log-likelihood')."""
    return jnp.mean(
        -0.5 * (LOG2PI + jnp.log(var_plus_noise))
        - 0.5 * (y - mean) ** 2 / var_plus_noise
    )


def rmse(y: jax.Array, mean: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((y - mean) ** 2))
