"""Dense/streamed kernel mathematics over the registered stationary kernels.

All kernels are parameterised by per-dimension lengthscales and a scalar
signal scale (paper §2), evaluated as ``k(a, b) = s^2 * kappa(r^2)`` with
``r = ||(a - b) / ell||_2`` the scaled Euclidean distance. The scalar
profiles ``kappa`` live in ``repro.kernels.registry`` (RBF + Matérn family)
and are SHARED with the fused Pallas tile kernels, so dense reference and
tiled hot path agree bit-for-bit on the profile maths.

The *regularised kernel matrix* is ``H_theta = K(x, x) + sigma^2 I``.

These functions are the pure-jnp oracles; the Pallas kernels in
``repro.kernels`` compute tiled/fused versions of the same maths.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams, resolve_kind
from repro.kernels.registry import available_kernels, get_kernel



def scaled_sqdist(x1: jax.Array, x2: jax.Array, lengthscales: jax.Array) -> jax.Array:
    """Pairwise squared distances of lengthscale-scaled inputs.

    Args:
      x1: (n, d); x2: (m, d); lengthscales: (d,).
    Returns:
      (n, m) matrix of ||(x1_i - x2_j)/ell||^2, clamped to >= 0.

    Uses the expanded quadratic form so the cross term is a single GEMM
    (the same contraction the Pallas kernel feeds to the MXU).
    """
    u = x1 / lengthscales
    v = x2 / lengthscales
    uu = jnp.sum(u * u, axis=-1)  # (n,)
    vv = jnp.sum(v * v, axis=-1)  # (m,)
    cross = u @ v.T  # (n, m) — MXU-friendly
    r2 = uu[:, None] + vv[None, :] - 2.0 * cross
    return jnp.maximum(r2, 0.0)


def profile_from_r2(kind: str) -> Callable:
    """Signal-scaled profile ``(r2, signal) -> s^2 kappa(r2)`` for ``kind``."""
    spec = get_kernel(kind)

    def profile(r2: jax.Array, signal: jax.Array) -> jax.Array:
        return (signal**2) * spec.kappa_from_r2(r2)

    return profile


# Dense signal-scaled profiles, one per registered kernel. Built at import;
# kernels registered later are reachable via profile_from_r2 / get_kernel.
PROFILES: dict[str, Callable] = {
    name: profile_from_r2(name) for name in available_kernels()
}
_PROFILES = PROFILES  # back-compat alias

# Named profiles of the built-in family (back-compat with the seed API).
rbf_from_r2 = PROFILES["rbf"]
matern12_from_r2 = PROFILES["matern12"]
matern32_from_r2 = PROFILES["matern32"]
matern52_from_r2 = PROFILES["matern52"]


def kernel_matrix(
    x1: jax.Array,
    x2: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
) -> jax.Array:
    """Dense cross-kernel matrix K(x1, x2; theta) of shape (n, m)."""
    kind = resolve_kind(kind, params)
    r2 = scaled_sqdist(x1, x2, params.lengthscales)
    return profile_from_r2(kind)(r2, params.signal)


def regularised_kernel_matrix(
    x: jax.Array, params: HyperParams, kind: Optional[str] = None
) -> jax.Array:
    """H_theta = K(x, x) + sigma^2 I (dense; reference/small-n only)."""
    n = x.shape[0]
    k = kernel_matrix(x, x, params, kind=kind)
    return k + (params.noise**2) * jnp.eye(n, dtype=k.dtype)


@partial(jax.jit, static_argnames=("kind", "block_rows"))
def kernel_mvm_streamed(
    x1: jax.Array,
    x2: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    block_rows: int = 1024,
) -> jax.Array:
    """K(x1, x2) @ v without materialising K — O(block * m) memory.

    Streams over row blocks of x1 with ``lax.map``; each block builds its
    distance tile, applies the profile, and contracts against ``v``.
    This is the pure-jnp analogue of the fused Pallas kernel and the
    single-device form of the distributed ring MVM.

    Args:
      x1: (n, d); x2: (m, d); v: (m, s) or (m,).
    Returns:
      (n, s) or (n,) — K @ v.
    """
    kind = resolve_kind(kind, params)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    n = x1.shape[0]
    nb = -(-n // block_rows)
    pad = nb * block_rows - n
    x1p = jnp.pad(x1, ((0, pad), (0, 0)))
    blocks = x1p.reshape(nb, block_rows, x1.shape[1])
    profile = profile_from_r2(kind)

    def body(xb):
        r2 = scaled_sqdist(xb, x2, params.lengthscales)
        kb = profile(r2, params.signal)
        return kb @ v

    out = jax.lax.map(body, blocks).reshape(nb * block_rows, v.shape[1])[:n]
    return out[:, 0] if squeeze else out


def h_mvm_dense(
    x: jax.Array, v: jax.Array, params: HyperParams, kind: Optional[str] = None
) -> jax.Array:
    """H_theta @ v via the dense kernel matrix (reference)."""
    h = regularised_kernel_matrix(x, params, kind=kind)
    return h @ v


def h_mvm_streamed(
    x: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    block_rows: int = 1024,
) -> jax.Array:
    """H_theta @ v = K @ v + sigma^2 v, streamed (no n x n materialisation)."""
    kv = kernel_mvm_streamed(x, x, v, params, kind=kind, block_rows=block_rows)
    return kv + (params.noise**2) * v
