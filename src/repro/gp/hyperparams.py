"""GP hyperparameters with softplus reparameterisation (paper Appendix B).

Each positive hyperparameter ``theta_k`` is stored as an unconstrained raw
value ``nu_k`` with ``theta_k = softplus(nu_k) = log(1 + exp(nu_k))`` so the
outer-loop Adam optimiser operates on R^{d_theta} (paper: "to facilitate
unconstrained optimisation").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def softplus(nu: jax.Array) -> jax.Array:
    """Numerically stable log(1 + exp(nu))."""
    return jnp.logaddexp(0.0, nu)


def softplus_inverse(theta: jax.Array) -> jax.Array:
    """Inverse of :func:`softplus`: nu = log(exp(theta) - 1), stable form."""
    # For large theta, expm1(theta) overflows; use theta + log1p(-exp(-theta)).
    theta = jnp.asarray(theta)
    small = theta < 20.0
    safe = jnp.where(small, theta, 1.0)
    return jnp.where(small, jnp.log(jnp.expm1(safe)), theta + jnp.log1p(-jnp.exp(-theta)))


class HyperParams(NamedTuple):
    """Unconstrained GP hyperparameters (a pytree; leaves are raw values).

    Attributes:
      raw_lengthscales: shape (d,), one per input dimension.
      raw_signal: scalar signal scale (sqrt of kernel variance).
      raw_noise: scalar observation noise scale sigma.
      kernel: registered kernel name (repro.kernels.registry) — static pytree
        aux data, not a leaf, so it survives tree maps / Adam / checkpointing
        and acts as the default ``kind`` wherever one is not given explicitly.
    """

    raw_lengthscales: jax.Array
    raw_signal: jax.Array
    raw_noise: jax.Array
    kernel: str = "matern32"

    @property
    def lengthscales(self) -> jax.Array:
        return softplus(self.raw_lengthscales)

    @property
    def signal(self) -> jax.Array:
        return softplus(self.raw_signal)

    @property
    def noise(self) -> jax.Array:
        return softplus(self.raw_noise)

    @property
    def num_params(self) -> int:
        return int(self.raw_lengthscales.shape[0]) + 2

    @staticmethod
    def create(
        d: int,
        lengthscale: float = 1.0,
        signal: float = 1.0,
        noise: float = 1.0,
        dtype=jnp.float32,
        kernel: str = "matern32",
    ) -> "HyperParams":
        """Constrained-space constructor (paper initialises at 1.0)."""
        ls = jnp.full((d,), lengthscale, dtype=dtype)
        return HyperParams(
            raw_lengthscales=softplus_inverse(ls),
            raw_signal=softplus_inverse(jnp.asarray(signal, dtype=dtype)),
            raw_noise=softplus_inverse(jnp.asarray(noise, dtype=dtype)),
            kernel=kernel,
        )

    def constrained(self) -> dict:
        return {
            "lengthscales": self.lengthscales,
            "signal": self.signal,
            "noise": self.noise,
        }

    def flat(self) -> jax.Array:
        """All constrained hyperparameters as one vector (for logging)."""
        return jnp.concatenate(
            [self.lengthscales, self.signal[None], self.noise[None]]
        )


# ``kernel`` rides along as static aux data: tree maps (Adam updates, grads,
# checkpoint restore-by-template) see only the three raw arrays as leaves.
jax.tree_util.register_pytree_node(
    HyperParams,
    lambda p: ((p.raw_lengthscales, p.raw_signal, p.raw_noise), p.kernel),
    lambda kernel, children: HyperParams(*children, kernel=kernel),
)


def resolve_kind(kind, params) -> str:
    """The effective kernel name: an explicit ``kind`` wins over the params'."""
    return kind if kind is not None else params.kernel
