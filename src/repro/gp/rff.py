"""Random Fourier features for approximate GP prior function samples.

Used by pathwise conditioning (paper eq. 3) and the pathwise gradient
estimator (paper §3, Appendix B): a prior sample is ``f(.) = phi(.) @ w``
with ``w ~ N(0, I_{2m})`` and ``phi`` built from ``m`` sin/cos frequency
pairs (paper uses m=1000 pairs, 2000 features total).

Matérn-3/2 spectral sampling: a standard multivariate Student-t with 3
degrees of freedom has characteristic function ``(1 + sqrt(3)|t|)
exp(-sqrt(3)|t|)`` — exactly the Matérn-3/2 correlation — so frequencies are
``omega = z * sqrt(3 / u) / ell`` with ``z ~ N(0, I_d)`` and ``u ~ chi^2_3``
(one ``u`` per frequency, shared across dimensions). RBF uses ``omega = z/ell``.

Warm-start contract (paper Appendix B): the *base* draws ``(z, u, w)`` are
sampled ONCE and fixed; each outer step re-evaluates ``omega`` from the fixed
base draws and the CURRENT lengthscales, so the right-hand sides of the linear
systems track theta deterministically ("selecting a particular instance of a
prior sample").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams


class RFFState(NamedTuple):
    """Fixed base randomness for RFF prior samples (pytree).

    ``kind`` is registered as static aux data (not a leaf) so RFFState can
    flow through jit-ted functions.
    """

    z: jax.Array  # (m, d) standard normal
    u: jax.Array  # (m,) chi^2_3 (matern32) or ones (rbf)
    w: jax.Array  # (2m, s) feature weights, one column per prior sample
    kind: str = "matern32"


jax.tree_util.register_pytree_node(
    RFFState,
    lambda s: ((s.z, s.u, s.w), s.kind),
    lambda kind, children: RFFState(*children, kind=kind),
)


def init_rff(
    key: jax.Array,
    num_pairs: int,
    d: int,
    num_samples: int,
    kind: str = "matern32",
    dtype=jnp.float32,
) -> RFFState:
    kz, ku, kw = jax.random.split(key, 3)
    z = jax.random.normal(kz, (num_pairs, d), dtype=dtype)
    if kind == "matern32":
        # chi^2 with 3 dof = 2 * Gamma(shape=1.5, scale=1)
        u = 2.0 * jax.random.gamma(ku, 1.5, (num_pairs,), dtype=dtype)
    elif kind == "rbf":
        u = jnp.ones((num_pairs,), dtype=dtype)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    w = jax.random.normal(kw, (2 * num_pairs, num_samples), dtype=dtype)
    return RFFState(z=z, u=u, w=w, kind=kind)


def rff_frequencies(state: RFFState, params: HyperParams) -> jax.Array:
    """Frequencies (m, d) for the current lengthscales."""
    if state.kind == "matern32":
        scale = jnp.sqrt(3.0 / state.u)[:, None]
    else:
        scale = 1.0
    return state.z * scale / params.lengthscales


def rff_features(
    x: jax.Array, state: RFFState, params: HyperParams
) -> jax.Array:
    """Feature matrix phi(x) of shape (n, 2m); phi @ phi.T ~= K(x, x)."""
    omega = rff_frequencies(state, params)  # (m, d)
    proj = x @ omega.T  # (n, m)
    m = state.z.shape[0]
    amp = params.signal * jnp.sqrt(1.0 / m)
    return amp * jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1)


def prior_sample_at(
    x: jax.Array, state: RFFState, params: HyperParams
) -> jax.Array:
    """Evaluate the s fixed prior function samples at x: (n, s).

    O(n * m) per call (paper: "Both of these operations are O(n)").
    """
    return rff_features(x, state, params) @ state.w
