"""Random Fourier features for approximate GP prior function samples.

Used by pathwise conditioning (paper eq. 3) and the pathwise gradient
estimator (paper §3, Appendix B): a prior sample is ``f(.) = phi(.) @ w``
with ``w ~ N(0, I_{2m})`` and ``phi`` built from ``m`` sin/cos frequency
pairs (paper uses m=1000 pairs, 2000 features total).

Spectral sampling is kernel-agnostic via ``repro.kernels.registry``: the
Matérn-nu spectral density is a multivariate Student-t with 2*nu degrees of
freedom — a Gaussian scale mixture — so frequencies are ``omega = z *
sqrt(2 nu / u) / ell`` with ``z ~ N(0, I_d)`` and ``u ~ chi^2_{2 nu}`` (one
``u`` per frequency, shared across dimensions; e.g. Matérn-3/2 has
characteristic function ``(1 + sqrt(3)|t|) exp(-sqrt(3)|t|)``). RBF uses
the plain Gaussian ``omega = z / ell`` (``u`` degenerate at 1).

Warm-start contract (paper Appendix B): the *base* draws ``(z, u, w)`` are
sampled ONCE and fixed; each outer step re-evaluates ``omega`` from the fixed
base draws and the CURRENT lengthscales, so the right-hand sides of the linear
systems track theta deterministically ("selecting a particular instance of a
prior sample").
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams
from repro.kernels.registry import get_kernel

# Per-kernel default sin/cos pair counts (AUTO_NUM_PAIRS / num_pairs=None
# resolves here). The paper's m=1000 suits the light-tailed spectra;
# Matérn-1/2's Cauchy spectrum needs more features for the same covariance
# error even with the stratified mixture draws (kernels.registry), so its
# default is 4x. Kernels registered later fall back to 1000.
DEFAULT_NUM_PAIRS = {
    "rbf": 1000,
    "matern32": 1000,
    "matern52": 1000,
    "matern12": 4000,
}
AUTO_NUM_PAIRS = -1


def default_num_pairs(kind: str) -> int:
    """The kernel's default feature-pair count (1000 for unlisted kernels)."""
    return DEFAULT_NUM_PAIRS.get(kind, 1000)


class RFFState(NamedTuple):
    """Fixed base randomness for RFF prior samples (pytree).

    ``kind`` is registered as static aux data (not a leaf) so RFFState can
    flow through jit-ted functions.
    """

    z: jax.Array  # (m, d) standard normal
    u: jax.Array  # (m,) spectral mixture draws (chi^2_{2 nu}; ones for rbf)
    w: jax.Array  # (2m, s) feature weights, one column per prior sample
    kind: str = "matern32"


jax.tree_util.register_pytree_node(
    RFFState,
    lambda s: ((s.z, s.u, s.w), s.kind),
    lambda kind, children: RFFState(*children, kind=kind),
)


def init_rff(
    key: jax.Array,
    num_pairs: Optional[int],
    d: int,
    num_samples: int,
    kind: str = "matern32",
    dtype=jnp.float32,
) -> RFFState:
    spec = get_kernel(kind)  # raises on unknown kernel
    if num_pairs is None or num_pairs == AUTO_NUM_PAIRS:
        num_pairs = default_num_pairs(kind)
    kz, ku, kw = jax.random.split(key, 3)
    z = jax.random.normal(kz, (num_pairs, d), dtype=dtype)
    u = spec.mixture_sample(ku, num_pairs, dtype=dtype)
    w = jax.random.normal(kw, (2 * num_pairs, num_samples), dtype=dtype)
    return RFFState(z=z, u=u, w=w, kind=kind)


def rff_frequencies(state: RFFState, params: HyperParams) -> jax.Array:
    """Frequencies (m, d) for the current lengthscales."""
    scale = get_kernel(state.kind).mixture_scale(state.u)[:, None]
    return state.z * scale / params.lengthscales


def rff_features(
    x: jax.Array, state: RFFState, params: HyperParams
) -> jax.Array:
    """Feature matrix phi(x) of shape (n, 2m); phi @ phi.T ~= K(x, x)."""
    omega = rff_frequencies(state, params)  # (m, d)
    proj = x @ omega.T  # (n, m)
    m = state.z.shape[0]
    amp = params.signal * jnp.sqrt(1.0 / m)
    return amp * jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1)


def prior_sample_at(
    x: jax.Array, state: RFFState, params: HyperParams
) -> jax.Array:
    """Evaluate the s fixed prior function samples at x: (n, s).

    O(n * m) per call (paper: "Both of these operations are O(n)").
    """
    return rff_features(x, state, params) @ state.w
