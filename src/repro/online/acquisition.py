"""Batched, jitted acquisition scoring for the online BO driver.

Both acquisitions are pure functions of the engine's pathwise posterior
``(mean, var)`` at the candidate set, so the whole acquire step is: one
bucketed engine predict (already jitted and warmed) + one call to
:func:`acquisition_argmax` (jitted here, one executable per acquisition
name and candidate-set shape). The incumbent ``best`` and the exploration
weights ``beta``/``xi`` are TRACED scalars — annealing them per round does
not retrace — so after the first round the steady state is exactly zero
compiles per round. All scores follow the maximisation convention (the
driver negates the objective to minimise).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm

# Variance estimates from a finite pathwise sample set can brush zero (or
# dip microscopically negative); clamp before sqrt so EI/UCB stay finite.
MIN_VARIANCE = 1e-12


def ucb(mean: jax.Array, var: jax.Array, beta=2.0) -> jax.Array:
    """Upper confidence bound ``mean + beta * sqrt(var)``.

    Args:
      mean: (m,) posterior mean at the candidates.
      var: (m,) posterior variance (clamped at ``MIN_VARIANCE``).
      beta: exploration weight (scalar, float or traced).
    Returns:
      (m,) scores; larger is better.
    """
    return mean + beta * jnp.sqrt(jnp.maximum(var, MIN_VARIANCE))


def expected_improvement(
    mean: jax.Array, var: jax.Array, best=0.0, xi=0.01
) -> jax.Array:
    """Expected improvement over the incumbent, ``E[max(f - best - xi, 0)]``.

    Args:
      mean: (m,) posterior mean at the candidates.
      var: (m,) posterior variance (clamped at ``MIN_VARIANCE``).
      best: incumbent objective value (scalar, float or traced).
      xi: exploration margin added to the incumbent.
    Returns:
      (m,) scores; larger is better. The closed form
      ``d * Phi(d / s) + s * phi(d / s)`` with ``d = mean - best - xi`` and
      ``s = sqrt(var)`` is used throughout (the clamp keeps ``s > 0``).
    """
    s = jnp.sqrt(jnp.maximum(var, MIN_VARIANCE))
    d = mean - best - xi
    z = d / s
    return d * norm.cdf(z) + s * norm.pdf(z)


ACQUISITIONS = {"ucb": ucb, "ei": expected_improvement}


@partial(jax.jit, static_argnames=("name",))
def acquisition_argmax(
    mean: jax.Array,
    var: jax.Array,
    name: str = "ucb",
    best: jax.Array | float = 0.0,
    beta: jax.Array | float = 2.0,
    xi: jax.Array | float = 0.01,
) -> tuple[jax.Array, jax.Array]:
    """Score every candidate and pick the argmax, in one jitted program.

    Args:
      mean: (m,) posterior mean at the candidates.
      var: (m,) posterior variance at the candidates.
      name: acquisition name (static): ``"ucb"`` or ``"ei"``.
      best: incumbent objective value (traced; used by EI).
      beta: UCB exploration weight (traced).
      xi: EI exploration margin (traced).
    Returns:
      ``(idx, score)`` — the winning candidate's index (int32 scalar) and
      its acquisition score. One executable per (name, m); the traced
      scalars make per-round annealing free.
    """
    if name not in ACQUISITIONS:
        raise ValueError(
            f"unknown acquisition {name!r}; have {sorted(ACQUISITIONS)}"
        )
    if name == "ucb":
        scores = ucb(mean, var, beta=beta)
    else:
        scores = expected_improvement(mean, var, best=best, xi=xi)
    idx = jnp.argmax(scores)
    return idx, scores[idx]
