"""Sequential decision-making on the serving stack (online BO driver).

The paper's warm-starting and budget machinery pays off most when solves
are *sequential* — the regime of Dong et al. (2025): each acquisition step
appends one observation and refreshes the model from the previous solver
state instead of re-solving cold. This package closes that loop end to end:

  * :mod:`repro.online.acquisition` — batched, jitted UCB / expected
    improvement scoring + argmax over a fixed-size candidate set (one
    executable per acquisition name; round number, incumbent and
    exploration weights ride as traced scalars).
  * :mod:`repro.online.bo` — :func:`run_bo`, the acquire -> observe ->
    append -> refresh -> predict loop on `OnlineGP` + `BucketedEngine`,
    with per-round refresh-mode selection, cumulative epoch/escalation
    accounting, and regret tracking against a known optimum.
"""
from repro.online.acquisition import (
    ACQUISITIONS,
    acquisition_argmax,
    expected_improvement,
    ucb,
)
from repro.online.bo import (
    BOConfig,
    BOResult,
    make_gaussian_bumps,
    run_bo,
)

__all__ = [
    "ACQUISITIONS", "acquisition_argmax", "expected_improvement", "ucb",
    "BOConfig", "BOResult", "make_gaussian_bumps", "run_bo",
]
