"""Sequential BO driver: acquire -> observe -> append -> refresh -> predict.

One round is: (1) draw a fixed-size candidate set, (2) predict through the
bucketed serving engine (jitted pathwise predictor, shapes pinned by the
candidate count), (3) pick the acquisition argmax (jitted,
:func:`repro.online.acquisition.acquisition_argmax`), (4) evaluate the
objective there, (5) `OnlineGP.append` the observation, (6) refresh with
the configured mode (block / auto-escalate / full solve) and atomically
swap the new artifact into the engine. Hundreds of rounds run with ZERO
retraces after warmup because every moving part keeps its shape: the
candidate set is a fixed engine bucket, the training arrays sit on the
geometric capacity ladder (`growth="geometric"` + ``reserve=rounds``), and
all per-round numerics (budgets, incumbent, exploration weights) ride as
traced scalars.

The driver is also the measurement harness the paper's warm-start story
needs in the sequential regime: it accumulates solver epochs round by
round, counts block-refresh escalations and damped corrections, and tracks
simple regret, so a warm run and a cold-re-solve baseline
(``BOConfig(warm=False)``) are directly comparable — see
``benchmarks/online_bo.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import PATHWISE
from repro.core.outer import OuterConfig, OuterState
from repro.online.acquisition import ACQUISITIONS, acquisition_argmax
from repro.serve.engine import BucketedEngine
from repro.serve.refresh import (
    CORRECTION_DAMPING,
    CORRECTION_EPOCHS,
    GROWTH_GEOMETRIC,
    OnlineGP,
)


@dataclass(frozen=True)
class BOConfig:
    """Knobs of the sequential loop (static per run).

    ``warm=True`` is the paper's sequential-inference path: appends refresh
    via ``refresh_mode`` (default ``"auto"``: block refresh with damped
    old-row correction, escalating to a warm full solve only when the
    corrected residual stays above threshold). ``warm=False`` is the
    cold-re-solve control: every refresh is a full ``mode="solve"`` from a
    zero initialisation — same engine, same shapes, same tolerance, so the
    cumulative-epoch ratio isolates exactly the warm-start + block-refresh
    saving.
    """

    rounds: int = 200  # acquisition rounds (one append each)
    num_candidates: int = 512  # fixed candidate-set size (= engine bucket)
    acquisition: str = "ucb"  # "ucb" | "ei"
    beta: float = 2.0  # UCB exploration weight
    xi: float = 0.01  # EI exploration margin
    warm: bool = True  # False => cold full re-solve baseline
    refresh_mode: str = "auto"  # refine mode when warm (block|auto|solve)
    correction: str = "damped"  # old-row correction for block/auto
    correction_epochs: float = CORRECTION_EPOCHS
    correction_damping: float = CORRECTION_DAMPING
    budget_epochs: Optional[float] = None  # per-refresh cap; None = tolerance
    refresh_every: int = 1  # refresh after every k-th append
    seed: int = 0  # candidate-draw PRNG seed


class BOResult(NamedTuple):
    """Everything a benchmark or notebook needs from one BO run.

    ``history`` has one dict per round (JSON-serialisable): the chosen
    point's objective value, the incumbent, regret (when ``f_opt`` is
    known), and the round's `RefreshReport` essentials (mode, epochs,
    residuals, escalated/corrected). The scalar fields are the run-level
    rollups the acceptance asserts run against.
    """

    history: list  # per-round dicts (see above)
    best_y: float  # incumbent objective value after the last round
    regret: Optional[float]  # f_opt - best_y, when f_opt was given
    cum_epochs: float  # solver epochs over all refreshes (full-system units)
    escalations: int  # auto-mode refreshes that fell back to a full solve
    corrections: int  # refreshes that ran the damped old-row correction
    rounds_per_sec: float  # wall-clock throughput of the whole loop
    engine_retraces: Optional[int]  # predict compiles after warmup (want 0)
    solve_compiles: Optional[int]  # OnlineGP solve executables (O(log N))
    refresh_stats: dict  # OnlineGP.stats_dict() snapshot at the end


def make_gaussian_bumps(
    key: jax.Array,
    d: int,
    num_bumps: int = 4,
    bounds: tuple = (-1.0, 1.0),
    width: float = 0.35,
) -> tuple[Callable[[jax.Array], jax.Array], float]:
    """A smooth multi-modal test objective: a sum of Gaussian bumps.

    Args:
      key: PRNG key placing the bumps.
      d: input dimension.
      num_bumps: number of bumps; amplitudes are drawn in [0.5, 1.5].
      bounds: (lo, hi) box the bump centres are drawn from.
      width: bump lengthscale (same units as the box).
    Returns:
      ``(objective, f_opt)`` — a vectorised callable mapping (m, d) inputs
      to (m,) values, and the objective value at the best bump centre (a
      lower bound on the true optimum; overlapping bumps can slightly
      exceed it, so regret can go marginally negative — fine for tracking).
    """
    lo, hi = bounds
    ck, ak = jax.random.split(key)
    centers = jax.random.uniform(
        ck, (num_bumps, d), minval=lo, maxval=hi, dtype=jnp.float32
    )
    amps = 0.5 + jax.random.uniform(ak, (num_bumps,), dtype=jnp.float32)

    def objective(x: jax.Array) -> jax.Array:
        x = jnp.atleast_2d(x)
        sq = jnp.sum((x[:, None, :] - centers[None]) ** 2, axis=-1)
        return jnp.sum(amps * jnp.exp(-sq / (2.0 * width**2)), axis=-1)

    f_opt = float(jnp.max(objective(centers)))
    return objective, f_opt


def run_bo(
    objective: Callable[[jax.Array], jax.Array],
    x0: jax.Array,
    y0: jax.Array,
    state: OuterState,
    cfg: OuterConfig,
    bo: BOConfig = BOConfig(),
    bounds: tuple = (-1.0, 1.0),
    f_opt: Optional[float] = None,
    key: Optional[jax.Array] = None,
) -> BOResult:
    """Run the sequential loop for ``bo.rounds`` rounds.

    Args:
      objective: vectorised black box mapping (m, d) inputs to (m,) values
        (maximisation convention).
      x0: (n0, d) initial training inputs (the fitted model's data).
      y0: (n0,) initial training targets.
      state: the fitted `OuterState` (pathwise estimator required — the
        engine's variance comes from the pathwise sample paths).
      cfg: the `OuterConfig` the state was fitted under.
      bo: loop configuration (:class:`BOConfig`).
      bounds: (lo, hi) box candidates are drawn uniformly from.
      f_opt: known optimum for regret tracking (optional).
      key: PRNG key for candidate draws; defaults to ``PRNGKey(bo.seed)``.
    Returns:
      :class:`BOResult`. Shape discipline inside: the `OnlineGP` reserves
      capacity for all ``bo.rounds`` appends up front, so the engine's
      bucket executables compile once at warmup and ``engine_retraces``
      is 0 for the entire run.
    """
    if cfg.estimator != PATHWISE:
        raise ValueError(
            "run_bo needs a pathwise-fitted state (the serving engine's "
            f"variance comes from pathwise samples); got {cfg.estimator!r}"
        )
    if bo.acquisition not in ACQUISITIONS:
        raise ValueError(
            f"unknown acquisition {bo.acquisition!r}; "
            f"have {sorted(ACQUISITIONS)}"
        )
    if bo.refresh_every < 1:
        raise ValueError(f"refresh_every must be >= 1, got {bo.refresh_every}")
    key = jax.random.PRNGKey(bo.seed) if key is None else key
    d = x0.shape[1]
    lo, hi = bounds

    # Capacity for every future append is reserved up front: the exported
    # artifact keeps ONE shape for the whole run, so the engine never
    # retraces after warmup and the solver compiles exactly one full-system
    # and one block executable.
    online = OnlineGP(
        x0, y0, state, cfg,
        growth=GROWTH_GEOMETRIC, reserve=bo.rounds,
    )
    engine = BucketedEngine(
        online.export(), buckets=(bo.num_candidates,), bm=cfg.bm, bn=cfg.bn
    )
    warm_compiles = engine.warmup()

    # Cold baseline = full re-solve from zero; warm path uses the
    # configured incremental mode. (block/auto refine IS a warm-carry
    # refinement, so warm=False forces mode="solve".)
    mode = bo.refresh_mode if bo.warm else "solve"
    best_y = float(jnp.max(y0))
    history: list = []
    t0 = time.perf_counter()
    for r in range(bo.rounds):
        cands = jax.random.uniform(
            jax.random.fold_in(key, r), (bo.num_candidates, d),
            minval=lo, maxval=hi, dtype=x0.dtype,
        )
        pred = engine.submit(cands)
        idx, score = acquisition_argmax(
            pred.mean, pred.var, name=bo.acquisition,
            best=best_y, beta=bo.beta, xi=bo.xi,
        )
        x_sel = cands[int(idx)]
        y_obs = float(objective(x_sel[None, :])[0])
        online.append(x_sel[None, :], jnp.asarray([y_obs], dtype=y0.dtype))
        entry = {
            "round": r, "y": y_obs, "score": float(score),
            "acquisition": bo.acquisition,
        }
        if (r + 1) % bo.refresh_every == 0:
            report = online.refresh_into(
                engine,
                budget_epochs=bo.budget_epochs,
                mode=mode, warm=bo.warm,
                correction=bo.correction if bo.warm else "none",
                correction_epochs=bo.correction_epochs,
                correction_damping=bo.correction_damping,
            )
            entry.update({
                "mode": report.mode, "epochs": report.epochs,
                "res_y": report.res_y, "res_z": report.res_z,
                "escalated": report.escalated,
                "corrected": report.corrected,
            })
        best_y = max(best_y, y_obs)
        entry["best_y"] = best_y
        if f_opt is not None:
            entry["regret"] = f_opt - best_y
        history.append(entry)
    elapsed = time.perf_counter() - t0

    stats = online.stats_dict()
    now_compiles = engine.num_compiles()
    retraces = (None if warm_compiles is None or now_compiles is None
                else now_compiles - warm_compiles)
    return BOResult(
        history=history,
        best_y=best_y,
        regret=None if f_opt is None else f_opt - best_y,
        cum_epochs=float(stats["cum_epochs"]),
        escalations=int(stats["escalations"]),
        corrections=int(stats["corrections"]),
        rounds_per_sec=bo.rounds / max(elapsed, 1e-9),
        engine_retraces=retraces,
        solve_compiles=stats["num_solve_compiles"],
        refresh_stats=stats,
    )
