"""Linear-system solver registry and a single dispatch entry point."""
from __future__ import annotations

from dataclasses import replace
from typing import Optional

import jax

from repro.solvers.base import SolveResult, SolverConfig
from repro.solvers.cg import solve_cg
from repro.solvers.ap import solve_ap
from repro.solvers.sgd import solve_sgd
from repro.solvers.operator import HOperator, kernel_mvm_tiled
from repro.solvers.precond import (
    AUTO_RANK,
    PRECOND_DEFAULTS,
    Preconditioner,
    PrecondDefaults,
    build_preconditioner,
    default_precond,
    pivoted_cholesky,
)

SOLVERS = {"cg": solve_cg, "ap": solve_ap, "sgd": solve_sgd}


def solve(
    op: HOperator,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """Solve H [v_y, v_1..v_s] = b with the configured solver.

    ``v0=None`` is the cold start (zero initialisation); pass the previous
    outer step's solution to warm start (paper §4).

    ``cfg.kind`` (when set) asserts the kernel the solve runs on: it must
    agree with the operator's effective kernel (explicit ``op.kind`` or
    ``params.kernel``); any disagreement is an error rather than a silent
    override.
    """
    if cfg.kind is not None:
        if cfg.kind != op.kernel_kind:
            raise ValueError(
                f"SolverConfig.kind={cfg.kind!r} conflicts with the "
                f"operator's kernel {op.kernel_kind!r}"
            )
        if op.kind is None:
            op = replace(op, kind=cfg.kind)
    if cfg.name == "cg":
        return solve_cg(op, b, v0, cfg)
    if cfg.name == "ap":
        return solve_ap(op, b, v0, cfg)
    if cfg.name == "sgd":
        return solve_sgd(op, b, v0, cfg, key=key)
    raise ValueError(f"unknown solver {cfg.name!r}")


__all__ = [
    "SOLVERS",
    "solve",
    "solve_cg",
    "solve_ap",
    "solve_sgd",
    "SolveResult",
    "SolverConfig",
    "HOperator",
    "kernel_mvm_tiled",
    "AUTO_RANK",
    "PRECOND_DEFAULTS",
    "Preconditioner",
    "PrecondDefaults",
    "build_preconditioner",
    "default_precond",
    "pivoted_cholesky",
]
