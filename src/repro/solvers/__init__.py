"""Linear-system solver registry and a single dispatch entry point."""
from __future__ import annotations

from dataclasses import replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.solvers.base import (
    NO_EPOCH_BUDGET,
    SolveResult,
    SolverConfig,
    SolverNumerics,
    broadcast_numerics,
    numerics_of,
    stack_numerics,
    strip_numerics,
)
from repro.solvers.adaptive import (
    AUTO_HORIZON,
    BudgetPolicy,
    DecayFit,
    broadcast_policy,
    budget_allocate,
    budget_observe,
    fit_decay,
    make_budget_policy,
    noise_probe,
    predict_epochs,
    resolve_horizon,
)
from repro.solvers.cg import solve_cg
from repro.solvers.ap import solve_ap
from repro.solvers.sgd import solve_sgd
from repro.solvers.operator import HOperator, kernel_mvm_tiled
from repro.solvers.precond import (
    AUTO_RANK,
    PRECOND_DEFAULTS,
    Preconditioner,
    PrecondDefaults,
    build_preconditioner,
    default_precond,
    pivoted_cholesky,
)

SOLVERS = {"cg": solve_cg, "ap": solve_ap, "sgd": solve_sgd}


def solve(
    op: HOperator,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    key: Optional[jax.Array] = None,
    numerics: Optional[SolverNumerics] = None,
) -> SolveResult:
    """Solve H [v_y, v_1..v_s] = b with the configured solver.

    ``v0=None`` is the cold start (zero initialisation); pass the previous
    outer step's solution to warm start (paper §4).

    ``cfg.kind`` (when set) asserts the kernel the solve runs on: it must
    agree with the operator's effective kernel (explicit ``op.kind`` or
    ``params.kernel``); any disagreement is an error rather than a silent
    override.

    ``numerics`` overrides the config's numeric settings (tolerance, epoch
    budget, lr, momentum, divergence threshold) with TRACED values — under
    ``jax.vmap`` each lane may carry its own (see :func:`solve_lanes`).
    ``None`` reads them from ``cfg`` — identical maths, and still one
    executable per static config.
    """
    if cfg.kind is not None:
        if cfg.kind != op.kernel_kind:
            raise ValueError(
                f"SolverConfig.kind={cfg.kind!r} conflicts with the "
                f"operator's kernel {op.kernel_kind!r}"
            )
        if op.kind is None:
            op = replace(op, kind=cfg.kind)
    if cfg.name == "cg":
        return solve_cg(op, b, v0, cfg, numerics=numerics)
    if cfg.name == "ap":
        return solve_ap(op, b, v0, cfg, numerics=numerics)
    if cfg.name == "sgd":
        return solve_sgd(op, b, v0, cfg, key=key, numerics=numerics)
    raise ValueError(f"unknown solver {cfg.name!r}")


def solve_lanes(
    x: jax.Array,
    params,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    *,
    kind: Optional[str] = None,
    backend: str = "streamed",
    bm: int = 1024,
    bn: int = 1024,
    keys: Optional[jax.Array] = None,
    numerics: Optional[SolverNumerics] = None,
) -> SolveResult:
    """Solve B independent scenario lanes in one vmapped program.

    Each lane is a full batched GP system ``H(theta_l) V_l = B_l`` sharing
    the training inputs ``x`` and the static solver config but with its own
    hyperparameters, right-hand sides, and (optionally) warm start. The
    shared ``while_loop`` keeps running while ANY lane is unconverged; the
    per-lane freeze masks inside each solver body guarantee lane ``l``'s
    trajectory — iterates, residuals, and ``iters``/``epochs`` counters —
    matches a single-lane :func:`solve` of the same system.

    Args:
      x: (n, d) training inputs shared by all lanes.
      params: HyperParams pytree, either lane-stacked (leaves with a leading
        B axis) or shared (unstacked, broadcast to every lane).
      b: (B, n, t) right-hand sides.
      v0: (B, n, t) warm starts, or None for cold starts.
      keys: (B, 2) PRNG keys (SGD batch sampling), or None.
      numerics: SolverNumerics pytree — lane-stacked ((B,) leaves: each lane
        gets its own tolerance/budget/lr) or shared (scalar leaves); None
        reads the config's values. Numeric grids ride as lanes of this one
        executable instead of retracing per cell.
    Returns:
      SolveResult with a leading lane axis on every field.
    """
    lanes = b.shape[0]
    # Stacked params have a (B,) raw_signal; shared params a scalar.
    p_axis = 0 if jnp.ndim(params.raw_signal) > 0 else None
    # Numerics may arrive with MIXED leaves (say a stacked lr but a shared
    # scalar tolerance); broadcast every leaf to (B,) so one in_axes=0
    # covers the whole pytree.
    if numerics is not None:
        numerics = broadcast_numerics(numerics, lanes)
    n_axis = None if numerics is None else 0
    if keys is None:
        keys = jax.random.split(jax.random.PRNGKey(0), lanes)

    def one(p, bl, v0l, kl, nm):
        op = HOperator(x=x, params=p, kind=kind, backend=backend, bm=bm, bn=bn)
        return solve(op, bl, v0l, cfg, key=kl, numerics=nm)

    # v0=None / numerics=None are empty pytrees: in_axes=None broadcasts them.
    v_axis = None if v0 is None else 0
    return jax.vmap(one, in_axes=(p_axis, 0, v_axis, 0, n_axis))(
        params, b, v0, keys, numerics
    )


__all__ = [
    "SOLVERS",
    "NO_EPOCH_BUDGET",
    "AUTO_HORIZON",
    "BudgetPolicy",
    "DecayFit",
    "broadcast_policy",
    "budget_allocate",
    "budget_observe",
    "fit_decay",
    "make_budget_policy",
    "noise_probe",
    "predict_epochs",
    "resolve_horizon",
    "solve",
    "solve_lanes",
    "solve_cg",
    "solve_ap",
    "solve_sgd",
    "SolveResult",
    "SolverConfig",
    "SolverNumerics",
    "numerics_of",
    "strip_numerics",
    "stack_numerics",
    "broadcast_numerics",
    "HOperator",
    "kernel_mvm_tiled",
    "AUTO_RANK",
    "PRECOND_DEFAULTS",
    "Preconditioner",
    "PrecondDefaults",
    "build_preconditioner",
    "default_precond",
    "pivoted_cholesky",
]
