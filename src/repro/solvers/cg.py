"""Preconditioned conjugate gradients for batched GP systems (Algorithm 1).

Solves ``H [v_y, v_1..v_s] = [y, b_1..b_s]`` with one shared MVM per
iteration; per-column step sizes (each column is an independent system with
the same coefficient matrix). Rank-100 pivoted-Cholesky preconditioner by
default (Wang et al. [29]).

Epoch accounting: 1 CG iteration = 1 solver epoch (every entry of H touched
once per MVM).

Note: the paper's pseudocode line 6 reads ``d <- b``; we implement the
standard PCG recursion ``d <- p`` (as in GPyTorch, which the paper follows) —
with ``d <- b`` warm starting would be incorrect.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.solvers.base import (
    SolveResult,
    SolverConfig,
    SolverNumerics,
    denormalise,
    freeze,
    history_init,
    history_record,
    lane_active,
    max_iters_from_epochs,
    normalise_system,
    not_converged,
    numerics_of,
    residual_norms,
)
from repro.solvers.operator import HOperator
from repro.solvers.precond import Preconditioner, build_preconditioner


class _CGState(NamedTuple):
    v: jax.Array
    r: jax.Array
    d: jax.Array
    gamma: jax.Array  # (t,) r^T P^-1 r per column
    t: jax.Array
    res_y: jax.Array
    res_z: jax.Array
    hist: Optional[jax.Array]  # (H, 2) residual ring, None when recording off


def solve_cg(
    op: HOperator,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    precond: Optional[Preconditioner] = None,
    numerics: Optional[SolverNumerics] = None,
) -> SolveResult:
    """Preconditioned conjugate gradients on the batched system ``H V = b``.

    Args:
      op: matrix-free `HOperator` for ``H = K(x, x) + sigma^2 I`` (n x n).
      b: (n, t) right-hand sides ``[y | b_1..b_s]`` (column 0 = mean system).
      v0: (n, t) warm start, or None for the zero cold start.
      cfg: static solver config; ``precond_rank`` selects the
        pivoted-Cholesky preconditioner (0 disables, AUTO_RANK resolves
        per kernel).
      precond: pre-built preconditioner (built from ``cfg`` when None).
      numerics: traced numeric overrides (tolerance, epoch budget); None
        reads ``cfg``'s values.
    Returns:
      `SolveResult` with (n, t) solutions; ``epochs == iters`` for CG (one
      full MVM per iteration, paper §5 budget accounting).
    """
    num = numerics if numerics is not None else numerics_of(cfg)
    if precond is None:
        precond = build_preconditioner(op, cfg.precond_rank)

    sysn = normalise_system(b, v0)
    max_iters = max_iters_from_epochs(num.max_epochs, 1.0)

    r0 = sysn.b - op.mvm(sysn.v0)
    p0 = precond.apply(r0)
    gamma0 = jnp.sum(r0 * p0, axis=0)
    res_y0, res_z0 = residual_norms(r0)
    state0 = _CGState(
        v=sysn.v0, r=r0, d=p0, gamma=gamma0,
        t=jnp.asarray(0, jnp.int32), res_y=res_y0, res_z=res_z0,
        hist=history_init(cfg),
    )

    def cond(s: _CGState):
        return jnp.logical_and(
            s.t < max_iters, not_converged(s.res_y, s.res_z, num.tolerance)
        )

    def body(s: _CGState):
        # This lane's own cond (freeze mask): a no-op single-lane, but under
        # vmap the loop runs while ANY lane is live and converged lanes must
        # stop mutating (and stop counting iterations).
        active = lane_active(s.t, max_iters, s.res_y, s.res_z, num.tolerance)
        hd = op.mvm(s.d)
        denom = jnp.sum(s.d * hd, axis=0)
        # Guard converged columns (denom -> 0) against 0/0.
        alpha = s.gamma / jnp.where(denom > 0, denom, 1.0)
        alpha = jnp.where(denom > 0, alpha, 0.0)
        v = s.v + alpha * s.d
        r = s.r - alpha * hd
        p = precond.apply(r)
        gamma_new = jnp.sum(r * p, axis=0)
        beta = gamma_new / jnp.where(s.gamma > 0, s.gamma, 1.0)
        beta = jnp.where(s.gamma > 0, beta, 0.0)
        d = p + beta * s.d
        res_y, res_z = residual_norms(r)
        return _CGState(
            v=freeze(active, v, s.v),
            r=freeze(active, r, s.r),
            d=freeze(active, d, s.d),
            gamma=freeze(active, gamma_new, s.gamma),
            t=s.t + active.astype(jnp.int32),
            res_y=freeze(active, res_y, s.res_y),
            res_z=freeze(active, res_z, s.res_z),
            hist=history_record(s.hist, s.t, res_y, res_z, active),
        )

    final = jax.lax.while_loop(cond, body, state0)
    return SolveResult(
        v=denormalise(final.v, sysn.scale),
        res_y=final.res_y,
        res_z=final.res_z,
        iters=final.t,
        epochs=final.t.astype(jnp.float32),
        res_history=final.hist,
    )
