"""Stochastic gradient descent for batched GP systems (Algorithm 3, Lin et al.).

Minimises the quadratic (paper eq. 8) with minibatch gradients: sample a
random row batch, compute the batch gradient ``g[idx] = H[idx, :] @ v -
b[idx]`` (one (b x n) kernel slab), take a momentum step, and sparsely refresh
the running residual estimate ``r[idx] <- -g[idx]`` (negative gradient =
residual).

Epoch accounting: one iteration = b/n of an epoch, as for AP.

Per the paper: batch 500, momentum 0.9, NO Polyak averaging (it would
interfere with the residual estimation heuristic), learning rate from a grid
search (config value). Following Algorithm 3 the residual estimate is
initialised at ``b`` (stale under warm starts until refreshed); set
``cfg.exact_final_residual=True`` to spend one extra epoch on an exact
residual for reporting.

Divergence cut-off: a lane whose summed residual blows past
``divergence_threshold`` (or goes non-finite) freezes instead of spending
its remaining budget — the early-stop arm of the lr grid search and of
per-lane numeric sweeps. The default threshold is inf (non-finite-only).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.solvers.base import (
    SolveResult,
    SolverConfig,
    SolverNumerics,
    denormalise,
    freeze,
    history_init,
    history_record,
    lane_active,
    lane_diverged,
    max_iters_from_epochs,
    normalise_system,
    numerics_of,
    residual_norms,
)
from repro.solvers.operator import HOperator


class _SGDState(NamedTuple):
    v: jax.Array
    m: jax.Array
    r: jax.Array  # running residual estimate
    key: jax.Array
    t: jax.Array
    res_y: jax.Array
    res_z: jax.Array
    hist: Optional[jax.Array]  # (H, 2) residual ring, None when recording off


def solve_sgd(
    op: HOperator,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    key: Optional[jax.Array] = None,
    numerics: Optional[SolverNumerics] = None,
) -> SolveResult:
    """Stochastic gradient descent (Polyak momentum) on ``H V = b``.

    Args:
      op: matrix-free `HOperator` for ``H = K(x, x) + sigma^2 I`` (n x n).
      b: (n, t) right-hand sides ``[y | b_1..b_s]``.
      v0: (n, t) warm start, or None for the zero cold start.
      cfg: static solver config; ``batch_size`` rows are sampled per step
        and ``learning_rate``/``momentum`` drive the update.
      key: PRNG key for batch sampling (PRNGKey(0) when None).
      numerics: traced numeric overrides (tolerance, budget, lr, momentum,
        divergence threshold); None reads ``cfg``'s values. A lane whose
        summed residual blows past ``divergence_threshold`` (or goes
        non-finite) freezes instead of burning budget.
    Returns:
      `SolveResult`; one iteration touches a (n x batch) slab, i.e.
      batch/n of an epoch (paper §5).
    """
    num = numerics if numerics is not None else numerics_of(cfg)
    n = op.n
    bs = cfg.batch_size
    if n % bs != 0:
        raise ValueError(f"n={n} must be a multiple of batch_size={bs}")
    nb = n // bs
    if key is None:
        key = jax.random.PRNGKey(0)

    sysn = normalise_system(b, v0)
    max_iters = max_iters_from_epochs(num.max_epochs, float(nb))

    r0 = sysn.b  # Alg. 3 line 4: r <- b (stale under warm start until refreshed)
    res_y0, res_z0 = residual_norms(r0)
    state0 = _SGDState(
        v=sysn.v0,
        m=jnp.zeros_like(sysn.v0),
        r=r0,
        key=key,
        t=jnp.asarray(0, jnp.int32),
        res_y=res_y0,
        res_z=res_z0,
        hist=history_init(cfg),
    )

    def _active(s: _SGDState):
        # Converged-or-budget-exhausted OR diverged past the cut-off: either
        # way this lane is done. The same predicate serves as the while-loop
        # cond and the per-lane freeze mask so lane and single-lane
        # trajectories agree.
        return jnp.logical_and(
            lane_active(s.t, max_iters, s.res_y, s.res_z, num.tolerance),
            ~lane_diverged(s.res_y, s.res_z, num.divergence_threshold),
        )

    def cond(s: _SGDState):
        return _active(s)

    bn = sysn.b

    def body(s: _SGDState):
        # Per-lane freeze mask (see solvers.base): no-op single-lane, keeps
        # converged lanes inert under vmap. The key still advances on frozen
        # lanes, but their drawn batch index is masked out with everything
        # else, so each live lane's key sequence matches a single-lane run.
        active = _active(s)
        # Random contiguous block = random row batch with O(1) index logic;
        # block boundaries are randomised by the data shuffle, and a uniform
        # block is an unbiased minibatch of rows.
        key, sub = jax.random.split(s.key)
        i = jax.random.randint(sub, (), 0, nb)
        start = i * bs
        bb = jax.lax.dynamic_slice(bn, (start, 0), (bs, bn.shape[1]))
        gb = op.row_block_mvm(start, bs, s.v) - bb  # (bs, t) batch gradient
        mb_prev = s.m
        # Momentum step on the full vector; the gradient is sparse so only
        # the batch rows of the gradient term change, but the momentum decay
        # touches every row (as in Alg. 3: m <- rho m - (gamma/b) g).
        g_full = jnp.zeros_like(s.v)
        g_full = jax.lax.dynamic_update_slice(g_full, gb, (start, 0))
        m = num.momentum * mb_prev - (num.learning_rate / bs) * g_full
        v = s.v + m
        # Sparse residual refresh: r[idx] <- -g[idx].
        r = jax.lax.dynamic_update_slice(s.r, -gb, (start, 0))
        res_y, res_z = residual_norms(r)
        return _SGDState(
            v=freeze(active, v, s.v),
            m=freeze(active, m, s.m),
            r=freeze(active, r, s.r),
            # repro-lint: disable=freeze-mask -- key advances on frozen lanes by design: draws stay decorrelated and masked v/m/r never see it
            key=key,
            t=s.t + active.astype(jnp.int32),
            res_y=freeze(active, res_y, s.res_y),
            res_z=freeze(active, res_z, s.res_z),
            hist=history_record(s.hist, s.t, res_y, res_z, active),
        )

    final = jax.lax.while_loop(cond, body, state0)

    v_out = denormalise(final.v, sysn.scale)
    res_y, res_z = final.res_y, final.res_z
    epochs = final.t.astype(jnp.float32) * (bs / n)
    if cfg.exact_final_residual:
        r_exact = bn - op.mvm(final.v)
        res_y, res_z = residual_norms(r_exact)
        epochs = epochs + 1.0
    return SolveResult(
        v=v_out, res_y=res_y, res_z=res_z, iters=final.t, epochs=epochs,
        res_history=final.hist,
    )
