"""Alternating projections for batched GP systems (Algorithm 2, Wu et al.).

Per iteration: greedily pick the block with the largest residual norm,
solve the (b x b) diagonal block against the block residual with its cached
Cholesky factor, update the solution block and the FULL residual via one
(n x b) column-block kernel slab.

Epoch accounting: one iteration touches n*b entries of H = b/n of an epoch;
``max_iters = (n / b) * max_epochs``. The per-block Cholesky factors are
computed once per outer MLL step and cached (their cost is counted once as
b/n of an epoch per block = 1 extra epoch total the first time).

Block selection: the paper's pseudocode takes an argmax over a per-block
aggregate of mean+probe residuals; we use the Frobenius norm of the block
residual across all t systems, which coincides for a single system and
avoids sign cancellation across probes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.solvers.base import (
    SolveResult,
    SolverConfig,
    SolverNumerics,
    denormalise,
    freeze,
    history_init,
    history_record,
    lane_active,
    max_iters_from_epochs,
    normalise_system,
    not_converged,
    numerics_of,
    residual_norms,
)
from repro.solvers.operator import HOperator


class _APState(NamedTuple):
    v: jax.Array
    r: jax.Array
    t: jax.Array
    res_y: jax.Array
    res_z: jax.Array
    hist: Optional[jax.Array]  # (H, 2) residual ring, None when recording off


def solve_ap(
    op: HOperator,
    b: jax.Array,
    v0: Optional[jax.Array],
    cfg: SolverConfig,
    block_chols: Optional[jax.Array] = None,
    numerics: Optional[SolverNumerics] = None,
) -> SolveResult:
    """Alternating projections over row blocks of the system ``H V = b``.

    Args:
      op: matrix-free `HOperator` for ``H = K(x, x) + sigma^2 I`` (n x n).
      b: (n, t) right-hand sides ``[y | b_1..b_s]``.
      v0: (n, t) warm start, or None for the zero cold start.
      cfg: static solver config; ``block_size`` sets the projection block
        (must divide n — pad via `repro.data.synthetic.pad_to_block_multiple`).
      block_chols: pre-factorised per-block Cholesky factors
        (n/block, block, block); computed once here when None.
      numerics: traced numeric overrides; None reads ``cfg``'s values.
    Returns:
      `SolveResult`; one iteration projects one block, i.e. block/n of an
      epoch (paper §5), so ``epochs = iters * block_size / n``.
    """
    num = numerics if numerics is not None else numerics_of(cfg)
    n = op.n
    bs = cfg.block_size
    if n % bs != 0:
        raise ValueError(f"n={n} must be a multiple of block_size={bs}")
    nb = n // bs
    if block_chols is None:
        block_chols = op.all_block_cholesky(bs)

    sysn = normalise_system(b, v0)
    max_iters = max_iters_from_epochs(num.max_epochs, float(nb))

    r0 = sysn.b - op.mvm(sysn.v0)
    res_y0, res_z0 = residual_norms(r0)
    state0 = _APState(
        v=sysn.v0, r=r0, t=jnp.asarray(0, jnp.int32),
        res_y=res_y0, res_z=res_z0, hist=history_init(cfg),
    )

    def cond(s: _APState):
        return jnp.logical_and(
            s.t < max_iters, not_converged(s.res_y, s.res_z, num.tolerance)
        )

    def body(s: _APState):
        # Per-lane freeze mask (see solvers.base): no-op single-lane, keeps
        # converged lanes inert under vmap.
        active = lane_active(s.t, max_iters, s.res_y, s.res_z, num.tolerance)
        # Greedy block selection by block-residual Frobenius norm.
        blk_norms = jnp.sum(
            s.r.reshape(nb, bs, -1) ** 2, axis=(1, 2)
        )
        i = jnp.argmax(blk_norms)
        start = i * bs
        rb = jax.lax.dynamic_slice(s.r, (start, 0), (bs, s.r.shape[1]))
        chol = block_chols[i]
        delta = jax.scipy.linalg.cho_solve((chol, True), rb)  # (bs, t)
        vb = jax.lax.dynamic_slice(s.v, (start, 0), (bs, s.v.shape[1]))
        v = jax.lax.dynamic_update_slice(s.v, vb + delta, (start, 0))
        # r <- r - H[:, blk] @ delta  (one (n x b) kernel slab)
        r = s.r - op.col_block_mvm(start, bs, delta)
        res_y, res_z = residual_norms(r)
        return _APState(
            v=freeze(active, v, s.v),
            r=freeze(active, r, s.r),
            t=s.t + active.astype(jnp.int32),
            res_y=freeze(active, res_y, s.res_y),
            res_z=freeze(active, res_z, s.res_z),
            hist=history_record(s.hist, s.t, res_y, res_z, active),
        )

    final = jax.lax.while_loop(cond, body, state0)
    return SolveResult(
        v=denormalise(final.v, sysn.scale),
        res_y=final.res_y,
        res_z=final.res_z,
        iters=final.t,
        epochs=final.t.astype(jnp.float32) * (bs / n),
        res_history=final.hist,
    )
