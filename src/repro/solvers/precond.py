"""Rank-k pivoted Cholesky preconditioner for CG (paper Appendix B, following
Wang et al. [29] / GPyTorch).

Builds a partial pivoted Cholesky factor L (n x k) of the *kernel* matrix K
(without noise) using k greedy pivots, then applies

    P^{-1} r = (L L^T + sigma^2 I)^{-1} r
             = (r - L (sigma^2 I_k + L^T L)^{-1} L^T r) / sigma^2      (Woodbury)

Each pivot step needs exactly one kernel row K[i, :] — O(n * d) work — so the
full preconditioner costs O(k * n * (d + k)) and is negligible next to solver
epochs (k=100).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.solvers.operator import HOperator

_JITTER = 1e-10

# Sentinel for SolverConfig.precond_rank: resolve rank/jitter from the
# per-kernel table below instead of a hand-picked number.
AUTO_RANK = -1


class PrecondDefaults(NamedTuple):
    """Per-kernel pivoted-Cholesky settings (see PRECOND_DEFAULTS)."""

    rank: int
    jitter: float


# Per-kernel pivoted-Cholesky defaults. Rank tracks the kernel's eigendecay:
# RBF spectra decay super-exponentially, so a very low-rank factor already
# captures K and larger ranks only buy extra O(n k^2) setup; Matérn spectra
# decay polynomially with smoothness nu, so rougher kernels need more pivots
# to pay off (matern12 also gets a larger inner jitter — its near-diagonal
# Schur complements are noisier under the floored-r profile). Unregistered
# kernels fall back to the paper's rank-100 / Wang et al. setting.
PRECOND_DEFAULTS: dict[str, PrecondDefaults] = {
    "rbf": PrecondDefaults(rank=20, jitter=_JITTER),
    "matern12": PrecondDefaults(rank=150, jitter=1e-8),
    "matern32": PrecondDefaults(rank=100, jitter=_JITTER),
    "matern52": PrecondDefaults(rank=60, jitter=_JITTER),
}

_FALLBACK = PrecondDefaults(rank=100, jitter=_JITTER)


def default_precond(kind: str) -> PrecondDefaults:
    """The rank/jitter defaults for a registered kernel name."""
    return PRECOND_DEFAULTS.get(kind, _FALLBACK)


class Preconditioner(NamedTuple):
    """Partial pivoted-Cholesky preconditioner ``P = LL^T + sigma^2 I``."""

    l: jax.Array  # (n, k) partial pivoted-Cholesky factor of K
    chol_inner: jax.Array  # (k, k) Cholesky of sigma^2 I_k + L^T L
    noise_var: jax.Array  # sigma^2

    def apply(self, r: jax.Array) -> jax.Array:
        """P^{-1} @ r for r of shape (n, t)."""
        ltr = self.l.T @ r  # (k, t)
        inner = jax.scipy.linalg.cho_solve((self.chol_inner, True), ltr)
        return (r - self.l @ inner) / self.noise_var


def identity_preconditioner(n: int, dtype=jnp.float32) -> Preconditioner:
    """Rank-0 stand-in: apply() reduces to the identity (L = 0)."""
    return Preconditioner(
        l=jnp.zeros((n, 1), dtype=dtype),
        chol_inner=jnp.eye(1, dtype=dtype),
        noise_var=jnp.asarray(1.0, dtype=dtype),
    )


def pivoted_cholesky(op: HOperator, rank: int) -> jax.Array:
    """Partial pivoted Cholesky of K (kernel only, no noise): (n, rank).

    Greedy pivot = argmax of the running diagonal of the Schur complement.
    """
    n = op.n
    dtype = op.x.dtype

    def step(carry, j):
        l, d = carry  # l: (n, rank); d: (n,) residual diagonal
        i = jnp.argmax(d)
        row = op.kernel_row(i)  # (n,) K[i, :]
        # Schur correction from previously selected columns.
        li = jax.lax.dynamic_slice(l, (i, 0), (1, rank))[0]  # (rank,)
        row = row - l @ li
        pivot = jnp.sqrt(jnp.maximum(d[i], _JITTER))
        col = row / pivot
        # Exact zero at previously-pivoted rows is implied; numerically we
        # just update the diagonal and clamp.
        l = l.at[:, j].set(col)
        d = jnp.maximum(d - col**2, 0.0)
        d = d.at[i].set(0.0)
        return (l, d), None

    l0 = jnp.zeros((n, rank), dtype=dtype)
    d0 = op.kernel_diag()
    (l, _), _ = jax.lax.scan(step, (l0, d0), jnp.arange(rank))
    return l


def build_preconditioner(op: HOperator, rank: int) -> Preconditioner:
    """Rank-``rank`` preconditioner; 0 disables, AUTO_RANK (< 0) resolves the
    rank and jitter from the per-kernel :data:`PRECOND_DEFAULTS` table."""
    jitter = _JITTER
    if rank < 0:
        defaults = default_precond(op.kernel_kind)
        rank, jitter = defaults.rank, defaults.jitter
    rank = min(rank, op.n)
    if rank <= 0:
        return identity_preconditioner(op.n, dtype=op.x.dtype)
    l = pivoted_cholesky(op, rank)
    inner = op.noise_var * jnp.eye(rank, dtype=l.dtype) + l.T @ l
    inner = inner + jitter * jnp.eye(rank, dtype=l.dtype)
    return Preconditioner(
        l=l, chol_inner=jnp.linalg.cholesky(inner), noise_var=op.noise_var
    )
