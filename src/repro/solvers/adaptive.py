"""Adaptive per-step solver budgets calibrated from residual telemetry.

The paper's early-stopping contribution fixes the epoch budget per outer
MLL step a priori; this module closes that loop using the solver's own
byproducts (ROADMAP "solver-statistics-driven adaptive numerics"), in the
spirit of probnum's ``UncertaintyCalibration`` / ``OptimalNoiseScale``:

1. **Convergence-rate estimator** (:func:`fit_decay`): a jit-safe weighted
   least-squares fit of a log-linear (log-Rayleigh-style) decay model to
   the residual ring buffers the solvers record inside their while-loops
   (``SolverConfig.record_history`` -> ``SolveResult.res_history``). The
   fitted slope — nats of log-residual per iteration — predicts the
   epochs still needed to reach any target residual
   (:func:`predict_epochs`).

2. **Noise probe** (:func:`noise_probe`): scores how noisy the current
   MLL gradient estimate is from the same probe-vector solves the
   estimator reads — the RMS misfit of the decay fit (solver
   stochasticity: ~0 for CG, large for SGD's sparse residual refresh) and
   the probe-system residual level relative to tolerance (the gradient
   estimate's solver-induced error floor). The misfit term widens the
   allocation margin so stochastic solvers are not systematically
   under-budgeted.

3. **Budget controller** (:class:`BudgetPolicy`, :func:`budget_allocate`,
   :func:`budget_observe`): a pytree carried across outer steps — global
   epoch pool, per-step floor/ceiling, EMA-smoothed decay slope /
   perturbation / noise — that converts the telemetry into a TRACED
   ``SolverNumerics.max_epochs`` per step (per-lane under ``vmap``), so
   adaptive fits retrace exactly as often as fixed-budget ones: never.

The controller's target rule is the warm-start insight made quantitative:
each hyperparameter update re-inflates the residual by a measurable
*perturbation* (entry residual of step t minus end residual of step
t − 1). Solving far below that perturbation is wasted work — the next
Adam step undoes it — so the per-step residual target is

    target_t = max(tolerance, margin * perturbation_ema * anneal_t)

with ``anneal_t = 1 - t/horizon`` decaying linearly so the final steps
solve all the way to tolerance (final ``res_z`` matches a fixed
to-tolerance run) while mid-trajectory steps stop at the perturbation
floor. The allocation is the predicted epochs to reach that target:

    alloc_t = clip((need_nats + noise) / rate * safety, floor, ceiling)

capped by the remaining pool and the configured ``max_epochs``. When no
decay model is available yet (first step, stalled or diverging solve,
ring too short) the controller FALLS BACK to the fixed budget
``min(ceiling, max_epochs)`` — adaptive never degrades below the
configured behaviour. See ``docs/adaptive.md`` for the full contract.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.solvers.base import SolverNumerics

# Smallest ring that supports a slope fit (two points define a line; fewer
# is not a model). `fit`/`outer_scan` refuse adaptive budgets below this.
MIN_RECORD_HISTORY = 2

# Slopes flatter than this (nats per epoch, towards zero) are treated as
# "no measurable decay": the controller falls back to the fixed budget
# rather than dividing by a near-zero rate.
SLOPE_EPS = 1e-4

# Sentinel horizon: `fit` resolves it to the run's `cfg.num_steps` so the
# anneal schedule lands exactly on the optimisation's last step.
AUTO_HORIZON = 0.0

# Closed-loop correction: when the residual GREW across an outer step, the
# previous allocation — hence the assumed decay rate — was too optimistic
# (short solves may leave sub-2-point rings, so the slope EMA cannot learn
# this from fits alone). Shrink the assumed rate by this factor per stalled
# step; allocations then escalate geometrically until solves are long
# enough to yield honest fits again (or the fixed-budget fallback engages).
STALL_DECAY = 0.5

# Floor on residuals entering logs (relative residuals; systems are
# normalised to ||b~|| = 1 so anything at fp32 round-off is "converged").
_RES_FLOOR = 1e-12


class DecayFit(NamedTuple):
    """Weighted least-squares fit of ``log res ~ intercept + slope * iter``.

    All fields are traced scalars (per-lane under ``vmap``):

    - ``slope``: nats of log-residual per ITERATION (negative while
      converging); convert to per-epoch with the solver's own
      epochs/iteration ratio before predicting epoch budgets.
    - ``intercept``: fitted log-residual at iteration 0.
    - ``rms``: root-mean-square misfit of the fit — the decay model's own
      noise estimate (see :func:`noise_probe`).
    - ``n_pts``: number of valid ring entries the fit used.
    - ``log_first`` / ``log_last``: log combined residual at the earliest
      and latest ring entries (NaN when the ring is empty).
    """

    slope: jax.Array
    intercept: jax.Array
    rms: jax.Array
    n_pts: jax.Array
    log_first: jax.Array
    log_last: jax.Array


def _combined(res_y: jax.Array, res_z: jax.Array) -> jax.Array:
    """The convergence-relevant residual: BOTH families must reach tau."""
    return jnp.maximum(res_y, res_z)


def fit_decay(hist: jax.Array, iters: jax.Array) -> DecayFit:
    """Fit the log-linear decay model to one solver residual ring.

    jit- and vmap-safe: works directly on the ROTATED ring (slot
    ``j % H`` holds the residuals after iteration ``j + 1``, see
    ``solvers.base.history_record``) by reconstructing each slot's true
    iteration index from the traced ``iters`` count — no host-side
    ``unroll_history`` needed. NaN slots (unfilled, or frozen lanes) are
    masked out of the weighted least squares.

    Args:
      hist: ``(H, 2)`` residual ring (``[res_y, res_z]`` per slot).
      iters: traced iteration count of the solve that wrote the ring.
    Returns:
      A :class:`DecayFit`; ``n_pts < 2`` marks an unusable fit (callers
      must fall back, see :func:`budget_allocate`).
    """
    h = hist.shape[0]
    n = iters.astype(jnp.int32)
    j = jnp.arange(h, dtype=jnp.int32)
    # Slot j holds iteration m = j + 1 + H * floor((n-1-j)/H): the LATEST
    # iteration <= n whose (m-1) mod H == j. For j >= n (never written)
    # the floor term goes negative and m <= 0, which the mask drops.
    m = j + 1 + h * jnp.floor_divide(n - 1 - j, h)
    r = _combined(hist[:, 0], hist[:, 1])
    logr = jnp.log(jnp.maximum(r, _RES_FLOOR))
    valid = (m >= 1) & (m <= n) & jnp.isfinite(logr)
    w = valid.astype(jnp.float32)
    # Sanitise masked entries BEFORE any arithmetic: 0 * NaN is NaN.
    ms = jnp.where(valid, m, 0).astype(jnp.float32)
    ys = jnp.where(valid, logr, 0.0)
    sw = jnp.sum(w)
    swc = jnp.maximum(sw, 1.0)
    mx = jnp.sum(w * ms) / swc
    my = jnp.sum(w * ys) / swc
    dx = jnp.where(valid, ms - mx, 0.0)
    dy = jnp.where(valid, ys - my, 0.0)
    sxx = jnp.sum(w * dx * dx)
    sxy = jnp.sum(w * dx * dy)
    slope = sxy / jnp.maximum(sxx, 1e-20)
    slope = jnp.where(sxx > 0, slope, 0.0)
    resid = jnp.where(valid, dy - slope * dx, 0.0)
    rms = jnp.sqrt(jnp.sum(w * resid * resid) / swc)
    # Earliest surviving entry: iteration 1 while the ring has not wrapped
    # (n <= H), else iteration n - H + 1 at slot n mod H. Latest: slot
    # (n-1) mod H. Guard n == 0 (solver converged at entry, empty ring).
    first_slot = jnp.where(n <= h, 0, jnp.mod(n, h))
    last_slot = jnp.mod(jnp.maximum(n - 1, 0), h)
    empty = n < 1
    log_first = jnp.where(empty, jnp.nan, logr[first_slot])
    log_last = jnp.where(empty, jnp.nan, logr[last_slot])
    return DecayFit(
        slope=slope, intercept=my - slope * mx, rms=rms, n_pts=sw,
        log_first=log_first, log_last=log_last,
    )


def predict_epochs(
    fit: DecayFit,
    epochs_per_iter: jax.Array,
    log_from: jax.Array,
    log_target: jax.Array,
) -> jax.Array:
    """Epochs to descend ``log_from -> log_target`` at the fitted rate.

    ``epochs_per_iter`` converts the per-iteration slope into the solver's
    own budget units (1 for CG, block/n for AP, batch/n for SGD — read it
    off a solve's ``epochs / iters``). Returns +inf when the fit shows no
    decay (slope >= -SLOPE_EPS after conversion) so callers fall back.
    """
    rate = -fit.slope / jnp.maximum(epochs_per_iter, 1e-12)  # nats/epoch
    need = jnp.maximum(log_from - log_target, 0.0)
    return jnp.where(rate > SLOPE_EPS, need / jnp.maximum(rate, SLOPE_EPS),
                     jnp.inf)


def noise_probe(
    fit: DecayFit, res_z: jax.Array, tolerance: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Score the noisiness of the current MLL gradient estimate.

    Both scores come from the probe-vector solves the estimator already
    reads — no extra MVMs:

    - ``stochasticity``: the decay fit's RMS misfit in nats. A
      deterministic solver (CG/AP) tracks its own decay line to fp32
      round-off; SGD's sparsely-refreshed residual scatters around it.
      The controller adds this directly to the nats it budgets for.
    - ``grad_noise``: ``log(res_z / tolerance)`` clipped at 0 — how far
      the probe systems (whose residuals bound the solver-induced error
      of the gradient estimate) still are from the configured target.

    Returns ``(stochasticity, grad_noise)``.
    """
    grad_noise = jnp.maximum(
        jnp.log(jnp.maximum(res_z, _RES_FLOOR))
        - jnp.log(jnp.maximum(tolerance, _RES_FLOOR)),
        0.0,
    )
    return fit.rms, grad_noise


class BudgetPolicy(NamedTuple):
    """Adaptive-budget controller state + coefficients (a pytree).

    Every leaf is a traced array so the policy rides through
    ``lax.scan`` chunks and, lane-stacked with ``(B,)`` leaves, through
    ``vmap`` — per-lane budgets inside one executable, zero retraces.

    Evolving state (updated by :func:`budget_observe` each outer step):

    - ``pool``: remaining global epoch pool (``inf`` = unlimited).
    - ``slope``: EMA of the per-EPOCH log-residual decay rate (negative).
    - ``noise``: EMA of the decay fit's RMS misfit (nats).
    - ``perturbation``: EMA of the residual re-inflation one hyperparameter
      update causes (absolute relative-residual units).
    - ``last_res``: combined residual at the end of the previous step.
    - ``steps_seen``: outer steps observed (drives the anneal schedule).
    - ``fits_seen``: accepted decay fits (0 -> fixed-budget fallback).

    Coefficients (constant through a fit; per-lane under ``vmap``):

    - ``floor`` / ``ceiling``: per-step epoch bounds on the allocation.
    - ``margin``: target = ``margin x perturbation`` — how far above the
      perturbation floor a mid-trajectory solve may stop.
    - ``safety``: multiplier on the predicted epochs (under-prediction
      insurance).
    - ``ema``: smoothing factor for slope/noise/perturbation EMAs.
    - ``horizon``: anneal length in steps; the target relaxes by
      ``1 - steps_seen/horizon`` so the last steps solve to tolerance.
      :data:`AUTO_HORIZON` (0) is resolved to ``cfg.num_steps`` by
      ``fit``; a non-positive horizon elsewhere disables annealing.
    """

    pool: jax.Array
    slope: jax.Array
    noise: jax.Array
    perturbation: jax.Array
    last_res: jax.Array
    steps_seen: jax.Array
    fits_seen: jax.Array
    floor: jax.Array
    ceiling: jax.Array
    margin: jax.Array
    safety: jax.Array
    ema: jax.Array
    horizon: jax.Array


def make_budget_policy(
    pool: float = float("inf"),
    floor: float = 1.0,
    ceiling: float = float("inf"),
    margin: float = 1.0,
    safety: float = 1.5,
    ema: float = 0.7,
    horizon: float = AUTO_HORIZON,
    dtype=jnp.float32,
) -> BudgetPolicy:
    """A fresh scalar-leaf :class:`BudgetPolicy`.

    Args:
      pool: global epoch pool for the whole fit (``inf`` = unlimited).
      floor / ceiling: per-step epoch bounds; the ceiling doubles as the
        fixed-budget fallback (intersected with ``numerics.max_epochs``).
      margin: mid-trajectory residual target in perturbation units.
      safety: multiplier on predicted epochs.
      ema: EMA smoothing for the calibrated coefficients.
      horizon: anneal length; :data:`AUTO_HORIZON` lets ``fit`` substitute
        its ``cfg.num_steps``.
    Returns:
      A :class:`BudgetPolicy` ready for ``fit(budget_policy=...)``.
    """
    f = lambda v: jnp.asarray(v, dtype)  # noqa: E731 - local shorthand
    return BudgetPolicy(
        pool=f(pool), slope=f(0.0), noise=f(0.0), perturbation=f(0.0),
        last_res=f(jnp.inf), steps_seen=jnp.asarray(0, jnp.int32),
        fits_seen=jnp.asarray(0, jnp.int32), floor=f(floor),
        ceiling=f(ceiling), margin=f(margin), safety=f(safety), ema=f(ema),
        horizon=f(horizon),
    )


def broadcast_policy(policy: BudgetPolicy, lanes: int) -> BudgetPolicy:
    """Broadcast scalar policy leaves to ``(lanes,)``; validate stacked ones.

    Mirrors ``solvers.base.broadcast_numerics``: a shared policy fans out
    to every lane, while per-lane coefficients (say a floor grid) ride as
    already-stacked leaves.
    """
    def one(v):
        v = jnp.asarray(v)
        if v.ndim == 0:
            return jnp.broadcast_to(v, (lanes,))
        if v.shape != (lanes,):
            raise ValueError(
                f"policy leaf shape {v.shape} does not match lanes={lanes}"
            )
        return v

    return jax.tree.map(one, policy)


def resolve_horizon(policy: BudgetPolicy, num_steps: int) -> BudgetPolicy:
    """Replace :data:`AUTO_HORIZON` leaves with the run's step count."""
    h = jnp.asarray(policy.horizon)
    return policy._replace(
        horizon=jnp.where(h == AUTO_HORIZON, float(num_steps), h)
    )


def step_target(policy: BudgetPolicy, tolerance: jax.Array) -> jax.Array:
    """This step's annealed residual target (module docstring).

    ``max(tolerance, margin x perturbation x anneal)`` with the anneal
    decaying linearly over the horizon. ``steps_seen`` is ``t - 1`` when
    allocating step ``t`` (:func:`budget_observe` increments it AFTER the
    solve, so allocate and observe of the same step agree on the target);
    the ``+1`` makes the LAST step of an N-step horizon anneal to exactly
    0 — its target is the bare tolerance, never a relaxed one.
    """
    tol = jnp.maximum(tolerance, _RES_FLOOR)
    anneal = jnp.where(
        policy.horizon > 0,
        jnp.clip(1.0 - (policy.steps_seen.astype(jnp.float32) + 1.0)
                 / jnp.maximum(policy.horizon, 1.0), 0.0, 1.0),
        1.0,
    )
    return jnp.maximum(tol, policy.margin * policy.perturbation * anneal)


def budget_allocate(
    policy: BudgetPolicy, numerics: SolverNumerics
) -> tuple[jax.Array, jax.Array]:
    """This step's epoch allocation, decided BEFORE the solve.

    Pure elementwise maths on the policy state — runs inside the jitted
    outer-step body, per-lane under ``vmap``. Returns
    ``(alloc, pred_to_tol)``:

    - ``alloc``: traced epochs for ``SolverNumerics.max_epochs``, the
      clipped predicted cost of reaching this step's annealed target
      (module docstring), capped by the remaining pool and the configured
      ``numerics.max_epochs``. Falls back to
      ``min(ceiling, numerics.max_epochs)`` until a decay fit has been
      accepted (``fits_seen == 0``) or when the EMA slope shows no decay.
    - ``pred_to_tol``: predicted epochs to reach ``numerics.tolerance``
      from the estimated entry residual (NaN before the first accepted
      fit) — the "predicted epochs-to-tolerance" half of the
      ``budget_decision`` telemetry.
    """
    tol = jnp.maximum(numerics.tolerance, _RES_FLOOR)
    log_tol = jnp.log(tol)
    rate = -policy.slope  # nats per epoch, positive while converging
    have_model = (policy.fits_seen >= 1) & (rate > SLOPE_EPS)

    # Estimated residual entering this solve: previous end + the EMA
    # perturbation one hyperparameter update injects (absolute units).
    res_in = jnp.minimum(policy.last_res, 1.0) + policy.perturbation
    log_res_in = jnp.log(jnp.maximum(res_in, _RES_FLOOR))

    target = step_target(policy, numerics.tolerance)
    log_target = jnp.log(target)

    need = jnp.maximum(log_res_in - log_target, 0.0) + policy.noise
    safe_rate = jnp.maximum(rate, SLOPE_EPS)
    alloc = need / safe_rate * policy.safety
    alloc = jnp.clip(alloc, policy.floor, policy.ceiling)

    fallback = jnp.minimum(policy.ceiling, numerics.max_epochs)
    alloc = jnp.where(have_model, alloc, fallback)
    # Never exceed the configured budget or the remaining global pool.
    alloc = jnp.minimum(alloc, numerics.max_epochs)
    alloc = jnp.minimum(alloc, jnp.maximum(policy.pool, 0.0))

    pred_to_tol = (jnp.maximum(log_res_in - log_tol, 0.0) + policy.noise) \
        / safe_rate * policy.safety
    pred_to_tol = jnp.where(have_model, pred_to_tol, jnp.nan)
    return alloc, pred_to_tol


def budget_observe(
    policy: BudgetPolicy,
    hist: jax.Array,
    iters: jax.Array,
    epochs: jax.Array,
    res_y: jax.Array,
    res_z: jax.Array,
    tolerance: jax.Array,
) -> tuple[BudgetPolicy, dict]:
    """Fold one solve's telemetry into the policy state, AFTER the solve.

    Fits the decay model on the step's residual ring, converts the slope
    to epoch units via the solve's own ``epochs / iters`` ratio, and
    EMA-updates slope / noise / perturbation — each only when its
    observation is valid (an empty ring, a stalled solve, or the very
    first step leave the corresponding EMA untouched; a first valid
    observation seeds its EMA directly instead of blending with the
    zero init). Decrements the pool by the epochs actually spent.

    Returns ``(new_policy, decision)`` where ``decision`` holds the
    traced telemetry half of the ``budget_decision`` event: realised
    epochs, end residual, the updated EMAs, the pool remaining, and the
    noise-probe scores.
    """
    fit = fit_decay(hist, iters)
    ran = iters >= 1
    epi = epochs / jnp.maximum(iters.astype(epochs.dtype), 1.0)
    slope_epoch = fit.slope * jnp.maximum(iters.astype(epochs.dtype), 1.0) \
        / jnp.maximum(epochs, 1e-12)
    ok_fit = ran & (fit.n_pts >= 2) & (slope_epoch < -SLOPE_EPS)

    def ema_update(prev, obs, ok, seeded):
        blended = policy.ema * prev + (1.0 - policy.ema) * obs
        return jnp.where(ok, jnp.where(seeded, blended, obs), prev)

    res_end = _combined(res_y, res_z)
    # Closed-loop stall correction (see STALL_DECAY): the solve MISSED the
    # target it was allocated for — it ended meaningfully above the step
    # target AND above the previous end (growing from below the target is
    # normal hovering: the perturbation pushes the residual up each step by
    # design). The assumed rate was too optimistic, and the ring may be too
    # short to re-fit honestly, so shrink it — the next allocation then
    # escalates geometrically instead of repeating the too-small one. A
    # valid fit takes precedence (real data beats the heuristic); the rate
    # ever reaching ~0 engages the fixed-budget fallback.
    target = step_target(policy, tolerance)
    stalled = ran & jnp.isfinite(policy.last_res) & (
        res_end > jnp.maximum(1.5 * target, policy.last_res)
    )
    stalled_slope = jnp.where(stalled, policy.slope * STALL_DECAY,
                              policy.slope)

    fits_seeded = policy.fits_seen >= 1
    slope = jnp.where(
        ok_fit,
        ema_update(policy.slope, slope_epoch, ok_fit, fits_seeded),
        stalled_slope,
    )
    stoch, grad_noise = noise_probe(fit, res_z, tolerance)
    noise = ema_update(policy.noise, stoch, ok_fit, fits_seeded)

    # Perturbation: residual re-inflation across the step boundary — the
    # residual this solve STARTED from vs the end of the previous one
    # (absolute relative-residual units). The ring's first entry is one
    # iteration in (post-descent), so with a valid fit the entry residual
    # is the decay line extrapolated to iteration 0 (exp(intercept), at
    # least the first recorded point); without one, the first recorded
    # point is the best available lower bound. Valid once a previous step
    # exists.
    res_first = jnp.exp(fit.log_first)
    res_entry = jnp.where(
        ok_fit, jnp.maximum(jnp.exp(fit.intercept), res_first), res_first
    )
    pert_obs = jnp.maximum(res_entry - policy.last_res, 0.0)
    ok_pert = ran & (policy.steps_seen >= 1) & jnp.isfinite(pert_obs)
    pert_seeded = policy.steps_seen >= 2
    perturbation = ema_update(policy.perturbation, pert_obs, ok_pert,
                              pert_seeded)
    new = policy._replace(
        pool=policy.pool - epochs,
        slope=slope,
        noise=noise,
        perturbation=perturbation,
        last_res=res_end,
        steps_seen=policy.steps_seen + 1,
        fits_seen=policy.fits_seen + ok_fit.astype(jnp.int32),
    )
    decision = {
        "realised": epochs,
        "res": res_end,
        "slope": slope,
        "noise": noise,
        "perturbation": perturbation,
        "grad_noise": grad_noise,
        "pool": new.pool,
        "epochs_per_iter": epi,
    }
    return new, decision
