"""Shared solver types: config, result, normalisation, budget accounting.

Budget accounting follows paper §5 footnote 3: one *solver epoch* = every
entry of H_theta computed once. CG: 1 iteration = 1 epoch (one full MVM).
AP / SGD with block/batch size b: one iteration touches an (n x b) slab,
i.e. b/n of an epoch, so ``max_iters = (n / b) * max_epochs``.

Normalisation follows Appendix B: each system ``H u = b`` is solved as
``H u~ = b~`` with ``b~ = b / (||b|| + eps)`` and rescaled afterwards; the
relative-residual tolerance then becomes an absolute tolerance on ``||r~||``.

Termination (paper §B "Linear System Solver"): BOTH the mean-system residual
norm ``||r_y||`` and the probe average ``||r_z|| = (1/s) sum_j ||r_j||`` must
reach tau. (The pseudocode's ``and`` in the while-condition is a typo for the
text's rule; we follow the text.)

Lane batching: every solver body re-evaluates its OWN continue predicate
(:func:`lane_active`) and masks every state update through :func:`freeze`.
Unbatched this is a no-op (the ``while_loop`` cond already admitted the
body), but under ``jax.vmap`` the loop runs while ANY lane is unconverged
and the mask is what keeps converged lanes frozen: their solution stops
mutating and their per-lane ``iters``/``epochs`` counters stop, so each
lane's trajectory is identical to a single-lane solve.

Static vs traced configuration: :class:`SolverConfig` is the hashable,
jit-static half (solver kind, shapes, flags — anything that changes the
compiled program), while :class:`SolverNumerics` is the TRACED half
(tolerance, epoch budget, learning rate, momentum, divergence threshold —
values the program merely reads). Solvers accept an optional ``numerics``
pytree and fall back to the config's scalar values, so a grid over numeric
settings can ride as lane-stacked traced inputs of ONE executable instead
of retracing per cell (see :func:`repro.solvers.solve_lanes` and
``launch.batch``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NORM_EPS = 1e-10

# int32-safe iteration cap for traced epoch budgets: exactly representable
# in float32 (2**31 - 1 is NOT — it rounds up and overflows the int32 cast).
MAX_SOLVER_ITERS = 2**30

# Explicit "no epoch budget — run to tolerance" sentinel for `max_epochs`,
# matching the `divergence_threshold=inf` convention: jnp arithmetic on it
# is well-defined and `max_iters_from_epochs` clamps it to MAX_SOLVER_ITERS.
NO_EPOCH_BUDGET = float("inf")


@dataclass(frozen=True)
class SolverConfig:
    """Static (hashable, jit-signature) half of solver configuration.

    Numeric fields (NUMERIC_FIELDS) can be overridden at trace time via a
    `SolverNumerics` pytree; everything else specialises the executable.
    """

    name: str = "cg"  # cg | ap | sgd
    tolerance: float = 0.01  # tau (paper: Maddox et al. value)
    # Kernel override for the operator: a registered kernel name pins the
    # solve to that kernel; None defers to HOperator.kind / params.kernel.
    kind: Optional[str] = None
    max_epochs: float = 1e9  # budget in solver epochs; large => to-tolerance
    # CG
    # Pivoted-Cholesky rank: 0 disables; AUTO_RANK (-1) resolves rank and
    # jitter per kernel from solvers.precond.PRECOND_DEFAULTS.
    precond_rank: int = 100
    # AP
    block_size: int = 1000
    # SGD
    batch_size: int = 500
    learning_rate: float = 30.0
    momentum: float = 0.9
    # Early-stop once res_y + res_z blows past this (or goes non-finite):
    # a diverging lane freezes instead of burning its remaining budget.
    # inf preserves the run-to-budget behaviour (SGD only).
    divergence_threshold: float = float("inf")
    # Numerics
    exact_final_residual: bool = False  # extra full MVM for reporting
    # Telemetry: record the last `record_history` per-iteration residual
    # pairs (res_y, res_z) in a fixed-size ring buffer INSIDE the while-loop
    # (jit-safe, vmap-compatible, no host round-trips). 0 (default) disables
    # recording entirely — the compiled program is bit-identical to a build
    # of this module without the feature. Static on purpose: it changes the
    # loop-carry structure, hence the executable.
    record_history: int = 0


# The numeric fields of SolverConfig — everything a compiled solver merely
# READS, never specialises on. These become the SolverNumerics pytree.
NUMERIC_FIELDS = (
    "tolerance", "max_epochs", "learning_rate", "momentum",
    "divergence_threshold",
)


class SolverNumerics(NamedTuple):
    """Traced numeric solver settings (a pytree; lane-stackable).

    The traced half of :class:`SolverConfig`: tolerance, epoch budget,
    SGD learning rate / momentum, and the divergence cut-off. None of these
    affect shapes or control-flow *structure*, so a sweep over them is data,
    not a retrace: stack each leaf along a leading lane axis (see
    :func:`stack_numerics`) and every cell of a tolerance x lr x budget grid
    runs inside one executable. Scalar leaves broadcast to every lane.
    """

    tolerance: jax.Array
    max_epochs: jax.Array
    learning_rate: jax.Array
    momentum: jax.Array
    divergence_threshold: jax.Array


def numerics_of(cfg: SolverConfig, dtype=jnp.float32) -> SolverNumerics:
    """The config's numeric fields as a traced pytree (scalar leaves)."""
    return SolverNumerics(*(
        jnp.asarray(getattr(cfg, f), dtype) for f in NUMERIC_FIELDS
    ))


def strip_numerics(cfg: SolverConfig) -> SolverConfig:
    """Canonical static signature: numeric fields reset to class defaults.

    Two configs that agree after stripping compile to the SAME executable
    when their numeric settings ride in as a :class:`SolverNumerics` pytree
    — this is the group key ``launch.batch`` partitions solver-config
    sweeps by.
    """
    defaults = {
        f.name: f.default for f in dataclasses.fields(SolverConfig)
        if f.name in NUMERIC_FIELDS
    }
    return dataclasses.replace(cfg, **defaults)


def stack_numerics(nums: "list[SolverNumerics]") -> SolverNumerics:
    """Stack per-cell numerics into one lane-stacked pytree (lane axis 0)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *nums)


def broadcast_numerics(num: SolverNumerics, lanes: int) -> SolverNumerics:
    """Broadcast scalar leaves to ``(lanes,)``; validates stacked leaves."""
    def one(v):
        v = jnp.asarray(v)
        if v.ndim == 0:
            return jnp.broadcast_to(v, (lanes,))
        if v.shape != (lanes,):
            raise ValueError(
                f"numerics leaf shape {v.shape} does not match lanes={lanes}"
            )
        return v

    return jax.tree.map(one, num)


def max_iters_from_epochs(max_epochs: jax.Array, iters_per_epoch: float
                          ) -> jax.Array:
    """Traced iteration cap: ``iters_per_epoch * max_epochs``, int32-safe."""
    cap = jnp.minimum(iters_per_epoch * max_epochs,
                      jnp.float32(MAX_SOLVER_ITERS))
    return cap.astype(jnp.int32)


class SolveResult(NamedTuple):
    """What every solver returns: solutions + residuals + budget spent."""

    v: jax.Array  # (n, t) solutions [v_y | v_1 .. v_s]
    res_y: jax.Array  # final relative residual of the mean system
    res_z: jax.Array  # mean relative residual over probe systems
    iters: jax.Array  # inner iterations executed
    epochs: jax.Array  # solver epochs consumed (budget units)
    # (H, 2) ring buffer of [res_y, res_z] after each iteration when
    # SolverConfig.record_history = H > 0, else None (None is an empty
    # pytree leaf, so jit/vmap/scan signatures stay clean when off).
    # Slot ``j % H`` holds the residuals after iteration ``j + 1``; unfilled
    # slots are NaN. Use :func:`unroll_history` to restore time order.
    res_history: Optional[jax.Array] = None


def history_init(cfg: SolverConfig, dtype=jnp.float32) -> Optional[jax.Array]:
    """Fresh NaN-filled ``(record_history, 2)`` ring, or None when off.

    The None/array split happens at trace time on the STATIC config field,
    so the disabled path contributes nothing to the loop carry and compiles
    to the identical program.
    """
    if cfg.record_history <= 0:
        return None
    return jnp.full((cfg.record_history, 2), jnp.nan, dtype)


def history_record(
    hist: Optional[jax.Array], t: jax.Array, res_y: jax.Array,
    res_z: jax.Array, active: jax.Array,
) -> Optional[jax.Array]:
    """Write ``[res_y, res_z]`` into ring slot ``t % H``; freeze-masked.

    ``t`` is the pre-increment iteration counter, so iteration j+1's
    residuals land in slot j (mod H). ``dynamic_update_slice`` handles the
    traced slot index and vmaps cleanly; the :func:`freeze` mask keeps a
    converged lane's ring bit-identical to its single-lane solve.
    """
    if hist is None:
        return None
    entry = jnp.stack([res_y, res_z]).astype(hist.dtype)
    slot = jnp.mod(t, hist.shape[0])
    new = jax.lax.dynamic_update_slice(hist, entry[None, :], (slot, 0))
    return freeze(active, new, hist)


def unroll_history(hist, iters) -> Optional[jax.Array]:
    """Host-side: ring buffer -> time-ordered ``(H, 2)`` residual history.

    Row k holds the residuals after iteration ``iters - H + 1 + k`` (NaN
    where the solve finished in fewer than H iterations). Accepts numpy or
    jax inputs; leading lane axes are handled by recursing per lane.
    """
    import numpy as np

    if hist is None:
        return None
    hist = np.asarray(hist)
    if hist.ndim > 2:  # lane-stacked: unroll each lane independently
        iters = np.broadcast_to(np.asarray(iters), hist.shape[:-2])
        return np.stack([
            unroll_history(h, i) for h, i in zip(hist, iters)
        ])
    h = hist.shape[0]
    n = int(iters)
    if n <= h:  # ring never wrapped: slots 0..n-1 are already in order
        return hist
    return np.roll(hist, -(n % h), axis=0)


class NormalisedSystem(NamedTuple):
    """Per-column normalised system (Appendix B): b~ = b / (||b|| + eps)."""

    b: jax.Array  # (n, t) normalised targets
    v0: jax.Array  # (n, t) normalised initialisation
    scale: jax.Array  # (t,) ||b|| + eps per column


def normalise_system(
    b: jax.Array, v0: Optional[jax.Array]
) -> NormalisedSystem:
    """Normalise each column of ``b`` (and ``v0``) by ``||b|| + eps``."""
    scale = jnp.linalg.norm(b, axis=0) + NORM_EPS
    bn = b / scale
    v0n = jnp.zeros_like(b) if v0 is None else v0 / scale
    return NormalisedSystem(b=bn, v0=v0n, scale=scale)


def denormalise(v: jax.Array, scale: jax.Array) -> jax.Array:
    """Undo `normalise_system`: rescale solutions back to ``b``'s scale."""
    return v * scale


def residual_norms(r: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(||r_y||, mean_j ||r_j||) for the normalised batched system.

    Column 0 is the mean system; columns 1..s are probes. If there is only
    one column, both norms coincide.
    """
    norms = jnp.linalg.norm(r, axis=0)
    res_y = norms[0]
    res_z = jnp.mean(norms[1:]) if r.shape[1] > 1 else norms[0]
    return res_y, res_z


def not_converged(res_y: jax.Array, res_z: jax.Array, tol) -> jax.Array:
    """Continue while EITHER system family is above tolerance.

    ``tol`` may be a Python float or a traced (per-lane) array.
    """
    return jnp.logical_or(res_y > tol, res_z > tol)


def lane_diverged(res_y: jax.Array, res_z: jax.Array, threshold) -> jax.Array:
    """Divergence cut-off: the summed residual blew past ``threshold`` or
    went non-finite. With the default ``threshold=inf`` only the non-finite
    arm can fire — and a non-finite iterate can never recover, so freezing
    it early only saves budget without changing any decision made on the
    final residual."""
    total = res_y + res_z
    return jnp.logical_or(~jnp.isfinite(total), total > threshold)


def lane_active(
    t: jax.Array, max_iters: jax.Array, res_y: jax.Array, res_z: jax.Array,
    tol,
) -> jax.Array:
    """This lane's own continue predicate — the solver while-loop cond.

    Scalar bool in a single-lane solve (necessarily True inside the body);
    per-lane bool under ``jax.vmap``, where the loop keeps running until
    every lane is done and frozen lanes must not mutate.
    """
    return jnp.logical_and(t < max_iters, not_converged(res_y, res_z, tol))


def freeze(active: jax.Array, new: jax.Array, old: jax.Array) -> jax.Array:
    """Per-lane freeze mask: take ``new`` only while the lane is active."""
    return jnp.where(active, new, old)
