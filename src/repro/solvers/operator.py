"""Matrix-free access to H_theta = K(x, x) + sigma^2 I.

All three solvers (CG / AP / SGD) and both gradient estimators touch H only
through this interface, so backends can be swapped freely:

  * ``dense``    — materialise H once (reference; small n only).
  * ``streamed`` — pure-jnp two-level tiling, O(bm*bn) live memory.
  * ``pallas``   — fused distance-tile TPU kernel for any registered
                   stationary kernel (repro.kernels); validated on CPU via
                   interpret mode.
  * ``ring``     — multi-device shard_map ring MVM (repro.distributed.ring);
                   constructed by the distributed driver.

Block index convention: AP/SGD work on contiguous blocks ``[i*b, (i+1)*b)``;
``n`` must be a multiple of the block size (the data pipeline pads with
far-away pseudo-points whose kernel row is exactly zero, see
``repro.data.synthetic.pad_to_block_multiple``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams, resolve_kind
from repro.gp.kernels_math import (
    kernel_matrix,
    profile_from_r2,
    regularised_kernel_matrix,
    scaled_sqdist,
)


def kernel_mvm_tiled(
    x1: jax.Array,
    x2: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> jax.Array:
    """K(x1, x2) @ v with two-level tiling; never materialises K.

    Outer ``lax.map`` over row tiles of x1, inner ``lax.scan`` accumulating
    over column tiles of (x2, v). Live memory is O(bm * bn + bm * s).
    """
    n, d = x1.shape
    m = x2.shape[0]
    s = v.shape[1]
    bm = min(bm, n)
    bn = min(bn, m)
    nb_m = -(-n // bm)
    nb_n = -(-m // bn)
    # Pad rows (extra outputs sliced off) and columns (v padded with zeros so
    # phantom columns contribute nothing).
    x1p = jnp.pad(x1, ((0, nb_m * bm - n), (0, 0)))
    x2p = jnp.pad(x2, ((0, nb_n * bn - m), (0, 0)))
    vp = jnp.pad(v, ((0, nb_n * bn - m), (0, 0)))
    x1b = x1p.reshape(nb_m, bm, d)
    x2b = x2p.reshape(nb_n, bn, d)
    vb = vp.reshape(nb_n, bn, s)
    profile = profile_from_r2(resolve_kind(kind, params))

    def row_tile(xr):
        def col_step(acc, xcvc):
            xc, vc = xcvc
            r2 = scaled_sqdist(xr, xc, params.lengthscales)
            kb = profile(r2, params.signal)
            return acc + kb @ vc, None

        acc0 = jnp.zeros((bm, s), dtype=v.dtype)
        acc, _ = jax.lax.scan(col_step, acc0, (x2b, vb))
        return acc

    out = jax.lax.map(row_tile, x1b).reshape(nb_m * bm, s)
    return out[:n]


@dataclass(frozen=True)
class HOperator:
    """H_theta = K(x, x; theta) + sigma^2 I as a linear operator."""

    # repro-lint: disable=config-static-array -- closure-captured operator, frozen for immutability; never hashed into a jit cache key
    x: jax.Array  # (n, d) training inputs
    params: HyperParams
    kind: Optional[str] = None  # None => params.kernel
    backend: str = "streamed"  # dense | streamed | pallas
    bm: int = 1024
    bn: int = 1024
    # Optional externally supplied full-MVM override (e.g. the distributed
    # ring MVM); signature (v: (n, s)) -> (n, s) for K @ v (noise added here).
    kernel_mvm_override: Optional[Callable] = None

    @property
    def n(self) -> int:
        """Number of training rows (the system dimension)."""
        return self.x.shape[0]

    @property
    def kernel_kind(self) -> str:
        """The effective kernel name (explicit kind wins over params.kernel)."""
        return resolve_kind(self.kind, self.params)

    @property
    def noise_var(self) -> jax.Array:
        """The regulariser sigma^2 added to the kernel diagonal."""
        return self.params.noise ** 2

    # -- full MVM ----------------------------------------------------------
    def _kernel_mvm(self, v: jax.Array) -> jax.Array:
        if self.kernel_mvm_override is not None:
            return self.kernel_mvm_override(v)
        if self.backend == "dense":
            k = kernel_matrix(self.x, self.x, self.params, kind=self.kind)
            return k @ v
        if self.backend == "pallas":
            from repro.kernels.ops import kernel_mvm

            return kernel_mvm(
                self.x, self.x, v, self.params, kind=self.kernel_kind,
                bm=self.bm, bn=self.bn,
            )
        return kernel_mvm_tiled(
            self.x, self.x, v, self.params, kind=self.kind, bm=self.bm, bn=self.bn
        )

    def mvm(self, v: jax.Array) -> jax.Array:
        """H @ v for v of shape (n, s) [or (n,)]."""
        squeeze = v.ndim == 1
        if squeeze:
            v = v[:, None]
        out = self._kernel_mvm(v) + self.noise_var * v
        return out[:, 0] if squeeze else out

    # -- partial access (AP / SGD / pivoted Cholesky) -----------------------
    def x_block(self, start: jax.Array, size: int) -> jax.Array:
        """(size, d) slice of the training inputs starting at row ``start``."""
        return jax.lax.dynamic_slice(self.x, (start, 0), (size, self.x.shape[1]))

    def row_block_mvm(self, start: jax.Array, size: int, v: jax.Array) -> jax.Array:
        """H[blk, :] @ v -> (size, s); one AP/SGD step's worth of kernel evals."""
        xb = self.x_block(start, size)
        kv = kernel_mvm_tiled(
            xb, self.x, v, self.params, kind=self.kind, bm=size, bn=self.bn
        )
        vb = jax.lax.dynamic_slice(v, (start, 0), (size, v.shape[1]))
        return kv + self.noise_var * vb

    def col_block_mvm(self, start: jax.Array, size: int, u: jax.Array) -> jax.Array:
        """H[:, blk] @ u -> (n, s) for u of shape (size, s)."""
        xb = self.x_block(start, size)
        ku = kernel_mvm_tiled(
            self.x, xb, u, self.params, kind=self.kind, bm=self.bm, bn=size
        )
        pad_u = jnp.zeros((self.n, u.shape[1]), dtype=u.dtype)
        pad_u = jax.lax.dynamic_update_slice(pad_u, u, (start, 0))
        return ku + self.noise_var * pad_u

    def block(self, start: jax.Array, size: int) -> jax.Array:
        """H[blk, blk] -> (size, size) dense tile (for AP block Cholesky)."""
        xb = self.x_block(start, size)
        kb = kernel_matrix(xb, xb, self.params, kind=self.kind)
        return kb + self.noise_var * jnp.eye(size, dtype=kb.dtype)

    def kernel_row(self, i: jax.Array) -> jax.Array:
        """K[i, :] (WITHOUT noise) -> (n,); used by pivoted Cholesky."""
        xi = jax.lax.dynamic_slice(self.x, (i, 0), (1, self.x.shape[1]))
        return kernel_matrix(xi, self.x, self.params, kind=self.kind)[0]

    def kernel_diag(self) -> jax.Array:
        """diag(K) (WITHOUT noise) -> (n,); constant s^2 for stationary k."""
        return jnp.full((self.n,), self.params.signal ** 2, dtype=self.x.dtype)

    def dense(self) -> jax.Array:
        """Materialise H = K + sigma^2 I as an (n, n) array (tests only)."""
        return regularised_kernel_matrix(self.x, self.params, kind=self.kind)

    # -- AP block Cholesky cache --------------------------------------------
    def all_block_cholesky(self, block_size: int) -> jax.Array:
        """Cholesky factors of every diagonal block, (nb, b, b).

        Computed once per outer MLL step and cached by the AP solver (paper:
        "the Cholesky factorisation of every block is computed once and
        cached afterwards").
        """
        nb = self.n // block_size
        starts = jnp.arange(nb) * block_size

        def one(start):
            return jnp.linalg.cholesky(self.block(start, block_size))

        return jax.lax.map(one, starts)
