"""Pallas TPU kernels for fused stationary-kernel matrix-vector products.

The GP solvers' hot spot is ``K(x1, x2) @ V`` where ``K`` is n x m and never
fits in HBM for the paper's large-n regime. These kernels stream
FlashAttention-style: a (bm x bn) *distance tile* is built in VMEM from row/
column blocks of the (pre-scaled) inputs — the cross term is a single MXU
GEMM — the kernel profile is applied in VREGs, and the tile is immediately
contracted against the corresponding V block into a (bm x s) fp32
accumulator. K is never materialised.

The tiling plumbing (BlockSpecs, grid order, accumulation, padding contract)
is kernel-AGNOSTIC: the only per-kernel code is the scalar profile
``kappa(r2)`` and its derivative ``dkappa/dr2`` looked up from
``repro.kernels.registry``. Both kernels operate on the UNIT kernel of
PRE-SCALED inputs ``u = x / ell``; the signal**2 factor, lengthscale scaling
and the sigma**2 diagonal live OUTSIDE (ops.py), where plain JAX autodiff
picks up their gradients.

Forward:   out[i]   = sum_j kappa(||u_i - w_j||^2) v_j
Backward:  du_i     = sum_j D_ij * 2 (u_i - w_j),  D = (g v^T) . dkappa/dr2

The same backward kernel computes dw by symmetry (swap (u,w) and (g,v)),
and db is the forward kernel with (u,w) swapped — see ops.py. This is the
"fused hyper-gradient" design from DESIGN.md §4: every hyperparameter's
gradient shares one sweep over distance tiles.

Grid iteration order: grid=(nm, nn) with the column index innermost, so each
(bm x s) output block is revisited consecutively and accumulates in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.registry import KernelSpec, get_kernel


def _dist_tile(u, w):
    """(bm, bn) squared-distance tile; cross term on the MXU in fp32."""
    uu = jnp.sum(u * u, axis=-1, keepdims=True)  # (bm, 1)
    ww = jnp.sum(w * w, axis=-1, keepdims=True)  # (bn, 1)
    cross = jax.lax.dot_general(
        u, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    return jnp.maximum(uu + ww.T - 2.0 * cross, 0.0)


def _mvm_kernel(spec: KernelSpec, u_ref, w_ref, v_ref, out_ref):
    """One (i, j) tile of kappa(u, w) @ v, accumulated over j."""
    j = pl.program_id(1)
    r2 = _dist_tile(u_ref[...], w_ref[...])
    k = spec.kappa_from_r2(r2)
    acc = jax.lax.dot(
        k.astype(v_ref.dtype), v_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += acc


def _mvm_bwd_kernel(spec: KernelSpec, u_ref, w_ref, g_ref, v_ref, du_ref):
    """One (i, j) tile of du = sum_j D_ij 2 (u_i - w_j), accumulated over j.

    D = (g v^T) * dkappa/dr2.
    du_i = 2 * (rowsum(D)_i * u_i - (D @ w)_i).
    """
    j = pl.program_id(1)
    u = u_ref[...]
    w = w_ref[...]
    r2 = _dist_tile(u, w)
    dk = spec.dkappa_dr2(r2)
    e = jax.lax.dot_general(
        g_ref[...], v_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bm, bn) = g v^T
    d_tile = e * dk
    rowsum = jnp.sum(d_tile, axis=1, keepdims=True)  # (bm, 1)
    dw_contrib = jax.lax.dot(d_tile, w, preferred_element_type=jnp.float32)
    acc = 2.0 * (rowsum * u - dw_contrib)

    @pl.when(j == 0)
    def _init():
        du_ref[...] = acc

    @pl.when(j > 0)
    def _acc():
        du_ref[...] += acc


def kernel_mvm_pallas(
    u: jax.Array,
    w: jax.Array,
    v: jax.Array,
    *,
    kind: str = "matern32",
    bm: int = 256,
    bn: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """kappa(u, w) @ v for pre-scaled inputs; shapes (n,d),(m,d),(m,s)->(n,s).

    n and m must be multiples of bm / bn (ops.py pads).
    """
    spec = get_kernel(kind)
    n, d = u.shape
    m = w.shape[0]
    s = v.shape[1]
    grid = (n // bm, m // bn)
    return pl.pallas_call(
        functools.partial(_mvm_kernel, spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, s), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), jnp.float32),
        interpret=interpret,
    )(u, w, v)


def kernel_mvm_bwd_pallas(
    u: jax.Array,
    w: jax.Array,
    g: jax.Array,
    v: jax.Array,
    *,
    kind: str = "matern32",
    bm: int = 256,
    bn: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """du for out = kappa(u, w) @ v with output cotangent g: (n, d)."""
    spec = get_kernel(kind)
    n, d = u.shape
    m = w.shape[0]
    s = v.shape[1]
    grid = (n // bm, m // bn)
    return pl.pallas_call(
        functools.partial(_mvm_bwd_kernel, spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, s), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, s), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(u, w, g, v)


# Matérn-3/2 aliases preserved for the original single-kernel API.
matern_mvm_pallas = functools.partial(kernel_mvm_pallas, kind="matern32")
matern_mvm_bwd_pallas = functools.partial(kernel_mvm_bwd_pallas, kind="matern32")
