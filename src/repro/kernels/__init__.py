"""Pallas TPU kernels for compute hot spots (DESIGN.md §4).

Kernel-agnostic substrate: ``registry`` holds the stationary kernel
profiles (RBF + Matérn-1/2, -3/2, -5/2 — profile, derivative, spectral
sampler); ``tiled`` holds the shared fused distance-tile Pallas kernels
(the inner-loop hot spot of every GP solver); ``ops`` wraps them in a
jit-ready custom-VJP op whose backward tile kernel doubles as the fused
hyper-gradient sweep (all d+2 hyperparameter gradients share its distance
tiles via the pre/post-scaling AD contract); ``ref`` is the dense oracle.
"""
from repro.kernels.registry import (
    KERNELS,
    KernelSpec,
    available_kernels,
    get_kernel,
    register_kernel,
)
from repro.kernels.ops import h_mvm, kernel_mvm, matern_mvm
from repro.kernels.ref import h_mvm_ref, kernel_mvm_ref, matern_mvm_ref

__all__ = [
    "KERNELS",
    "KernelSpec",
    "available_kernels",
    "get_kernel",
    "register_kernel",
    "kernel_mvm",
    "h_mvm",
    "kernel_mvm_ref",
    "h_mvm_ref",
    "matern_mvm",
    "matern_mvm_ref",
]
