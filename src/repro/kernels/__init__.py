"""Pallas TPU kernels for compute hot spots (DESIGN.md §4).

matern/ — fused Matérn-3/2 kernel MVM with custom VJP: the inner-loop hot
spot of every GP solver. The backward tile kernel doubles as the fused
hyper-gradient sweep (all d+2 hyperparameter gradients share its distance
tiles via the pre/post-scaling AD contract in ops.py).
"""
from repro.kernels.matern import h_mvm, h_mvm_ref, matern_mvm, matern_mvm_ref

__all__ = ["matern_mvm", "h_mvm", "matern_mvm_ref", "h_mvm_ref"]
