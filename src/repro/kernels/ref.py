"""Pure-jnp oracle for the Pallas kernel MVM (dense; small n only)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.gp.hyperparams import HyperParams, resolve_kind


def kernel_mvm_ref(
    x1: jax.Array,
    x2: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
) -> jax.Array:
    """Dense K(x1, x2) @ v — the correctness oracle."""
    # Deferred: repro.gp.kernels_math itself imports the registry from this
    # package, so a module-level import here would be circular.
    from repro.gp.kernels_math import kernel_matrix

    kind = resolve_kind(kind, params)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    out = kernel_matrix(x1, x2, params, kind=kind) @ v
    return out[:, 0] if squeeze else out


def h_mvm_ref(
    x: jax.Array, v: jax.Array, params: HyperParams, kind: Optional[str] = None
) -> jax.Array:
    return kernel_mvm_ref(x, x, v, params, kind=kind) + (params.noise**2) * v


def matern_mvm_ref(x1, x2, v, params):
    """Original Matérn-3/2 oracle (compat wrapper)."""
    return kernel_mvm_ref(x1, x2, v, params, kind="matern32")
