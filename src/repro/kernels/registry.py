"""Registry of stationary kernel profiles shared by every MVM backend.

The paper's solver machinery (pathwise estimator, warm starting, epoch
budgets) is kernel-agnostic: every backend only ever needs

  * the *unit* scalar profile ``kappa(r^2)`` of the lengthscale-scaled
    squared distance (signal**2 and the noise diagonal are applied by the
    callers, where plain JAX AD picks up their gradients),
  * its derivative ``dkappa/dr^2`` — the single quantity the fused Pallas
    backward distance-tile kernel applies in VREGs (repro.kernels.tiled),
  * a spectral mixture sampler for RFF prior draws (repro.gp.rff):
    Matérn-nu spectral densities are multivariate Student-t with 2*nu
    degrees of freedom, i.e. Gaussian scale mixtures ``omega = z *
    sqrt(2 nu / u)`` with ``u ~ chi^2_{2 nu}``; the RBF density is plain
    Gaussian (``u`` degenerate at 1).

Each :class:`KernelSpec` bundles exactly those three ingredients, so
registering one spec makes a kernel available to the dense reference
(`repro.gp.kernels_math`), the streamed/tiled jnp backends
(`repro.solvers.operator`), the fused Pallas path (`repro.kernels`), the
distributed ring MVM and the RFF sampler simultaneously.

Everything takes the SQUARED scaled distance so profiles that do not need
``r`` (RBF) never pay a sqrt, and profiles that do share one floor constant
that keeps the sqrt differentiable at coincident points.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979

# Keeps sqrt(r2) differentiable at coincident points. The floor MUST be
# applied as ``maximum(r2, floor)`` — not ``r2 + floor`` — so reverse-mode AD
# sees an exactly-zero derivative below the floor: with ``+`` the chain rule
# forms dkappa/dr * 1/(2*sqrt(floor)) ~ 0 * 5e14 on the clamped diagonal,
# which only cancels under favourable XLA fusion orders and otherwise
# poisons lengthscale gradients. Matérn-1/2 uses a larger floor: its
# dkappa/dr^2 ~ -1/(2r) diverges as r -> 0 and amplifies diagonal round-off
# in the fused backward tile accumulation; its registered dkappa is
# additionally zeroed on the clamped region (see _m12_dkappa) so coincident
# points contribute exactly nothing instead of the floored slope.
_R2_FLOOR = 1e-30
_R2_FLOOR_M12 = 1e-12


class KernelSpec(NamedTuple):
    """One stationary kernel's contribution to every compute backend.

    Attributes:
      name: registry key (e.g. ``"matern32"``).
      nu: Matérn smoothness, or None for RBF (infinitely smooth limit).
      kappa_from_r2: unit profile ``kappa(r2)`` with ``kappa(0) = 1``;
        evaluated per-tile in VREGs by the Pallas forward kernel and densely
        by the jnp reference/streamed backends.
      dkappa_dr2: ``d kappa / d r2`` — contracted against the outer-product
        cotangent in the fused Pallas backward tile kernel.
      mixture_sample: ``(key, num_pairs, dtype) -> u`` base mixture draws,
        shape (num_pairs,); drawn ONCE under the warm-start contract.
      mixture_scale: ``u -> per-frequency scale`` multiplying the standard
        normal directions ``z`` (deterministic in ``u``).
    """

    name: str
    nu: Optional[float]
    kappa_from_r2: Callable[[jax.Array], jax.Array]
    dkappa_dr2: Callable[[jax.Array], jax.Array]
    mixture_sample: Callable[..., jax.Array]
    mixture_scale: Callable[[jax.Array], jax.Array]


KERNELS: dict[str, KernelSpec] = {}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    """Register (or override) a kernel for all backends; returns the spec."""
    KERNELS[spec.name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    try:
        return KERNELS[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r}; registered: {sorted(KERNELS)}"
        ) from None


def available_kernels() -> tuple[str, ...]:
    return tuple(sorted(KERNELS))


# -- profiles ---------------------------------------------------------------


def _rbf_kappa(r2):
    return jnp.exp(-0.5 * r2)


def _rbf_dkappa(r2):
    return -0.5 * jnp.exp(-0.5 * r2)


def _m12_kappa(r2):
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR_M12))
    return jnp.exp(-r)


def _m12_dkappa(r2):
    """Subgradient-aware Matérn-1/2 derivative.

    exp(-r) is non-smooth at r=0 and dkappa/dr2 = -exp(-r)/(2r) diverges
    there. On the clamped region (r2 <= floor — exact duplicates and the
    tile diagonal, where the distance computation lands at hard zero) the
    true contribution to any hyperparameter gradient is zero: dr2/dtheta
    vanishes quadratically while the profile subdifferential stays bounded.
    Returning the FLOORED slope -1/(2*sqrt(floor)) ~ -5e5 instead (as the
    pre-fix code did) plants huge entries in the fused backward tile's
    D = (g v^T) . dkappa, whose row-sum/GEMM cancellation then amplifies
    fp32 round-off into a visible lengthscale-gradient bias on clustered or
    duplicated inputs. So: exact zero below the floor — matching what plain
    AD of ``kappa_from_r2`` produces through the ``maximum`` clamp — and
    the true slope above it.
    """
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR_M12))
    slope = -jnp.exp(-r) / (2.0 * r)
    return jnp.where(r2 > _R2_FLOOR_M12, slope, jnp.zeros_like(slope))


def _m32_kappa(r2):
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR))
    return (1.0 + SQRT3 * r) * jnp.exp(-SQRT3 * r)


def _m32_dkappa(r2):
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR))
    return -1.5 * jnp.exp(-SQRT3 * r)


def _m52_kappa(r2):
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR))
    return (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-SQRT5 * r)


def _m52_dkappa(r2):
    r = jnp.sqrt(jnp.maximum(r2, _R2_FLOOR))
    return -(5.0 / 6.0) * (1.0 + SQRT5 * r) * jnp.exp(-SQRT5 * r)


# -- spectral mixtures ------------------------------------------------------


def _ones_sample(key, num_pairs, dtype=jnp.float32):
    return jnp.ones((num_pairs,), dtype=dtype)


def _chi2_sample(dof: float):
    # chi^2_k = 2 * Gamma(shape=k/2, scale=1)
    def sample(key, num_pairs, dtype=jnp.float32):
        return 2.0 * jax.random.gamma(key, dof / 2.0, (num_pairs,), dtype=dtype)

    return sample


def _chi2_1_sample_stratified(key, num_pairs, dtype=jnp.float32):
    """Stratified (randomised-QMC) chi^2_1 mixture draws for Matérn-1/2.

    The Matérn-1/2 spectral density is Cauchy: the mixture scale
    ``sqrt(1/u)`` has no mean, so iid ``u ~ chi^2_1`` draws under- or
    over-represent the frequency tail at any practical feature count and
    the RFF covariance estimate converges slowly. One jittered
    inverse-CDF draw per probability stratum fixes the tail coverage by
    construction — exactly one frequency per quantile bin, every seed —
    while staying unbiased (the jitter is uniform within each stratum).
    chi^2_1 inverts through the normal CDF: ``u = Phi^{-1}((1+p)/2)^2``.
    Deterministic given ``key``, so the warm-start fixed-base-draw
    contract (gp.rff) is untouched.
    """
    jitter = jax.random.uniform(key, (num_pairs,), dtype=dtype)
    p = (jnp.arange(num_pairs, dtype=dtype) + jitter) / num_pairs
    # Keep ndtri's argument strictly inside (0.5, 1): in float32 the top
    # stratum's (1+p)/2 can round to exactly 1.0 (ndtri -> inf, poisoning
    # the stored u and every downstream feature map).
    q = jnp.minimum((1.0 + p) / 2.0, 1.0 - jnp.finfo(dtype).epsneg)
    z = jax.scipy.special.ndtri(q).astype(dtype)
    # First stratum can land at p ~ 0 -> u ~ 0 -> an infinite mixture
    # scale; clamp to the smallest positive normal (still a ~1e19x scale).
    return jnp.maximum(z * z, jnp.finfo(dtype).tiny)


def _student_scale(dof: float):
    def scale(u):
        return jnp.sqrt(dof / u)

    return scale


register_kernel(KernelSpec(
    name="rbf",
    nu=None,
    kappa_from_r2=_rbf_kappa,
    dkappa_dr2=_rbf_dkappa,
    mixture_sample=_ones_sample,
    mixture_scale=lambda u: jnp.ones_like(u),
))

register_kernel(KernelSpec(
    name="matern12",
    nu=0.5,
    kappa_from_r2=_m12_kappa,
    dkappa_dr2=_m12_dkappa,
    # Stratified, not iid: the Cauchy spectrum's tail is too heavy for
    # plain chi^2_1 draws at practical feature counts (see gp.rff, which
    # also gives matern12 a larger default feature count).
    mixture_sample=_chi2_1_sample_stratified,
    mixture_scale=_student_scale(1.0),
))

register_kernel(KernelSpec(
    name="matern32",
    nu=1.5,
    kappa_from_r2=_m32_kappa,
    dkappa_dr2=_m32_dkappa,
    mixture_sample=_chi2_sample(3.0),
    mixture_scale=_student_scale(3.0),
))

register_kernel(KernelSpec(
    name="matern52",
    nu=2.5,
    kappa_from_r2=_m52_kappa,
    dkappa_dr2=_m52_dkappa,
    mixture_sample=_chi2_sample(5.0),
    mixture_scale=_student_scale(5.0),
))
