"""Back-compat shim: the Matérn-3/2 Pallas path is now the ``matern32``
entry of the kernel-agnostic substrate in ``repro.kernels`` (registry +
tiled + ops + ref). Import from there in new code."""
from repro.kernels.ops import h_mvm, kernel_mvm, matern_mvm
from repro.kernels.ref import h_mvm_ref, kernel_mvm_ref, matern_mvm_ref
from repro.kernels.tiled import matern_mvm_bwd_pallas, matern_mvm_pallas

__all__ = [
    "matern_mvm",
    "h_mvm",
    "matern_mvm_ref",
    "h_mvm_ref",
    "kernel_mvm",
    "kernel_mvm_ref",
    "matern_mvm_pallas",
    "matern_mvm_bwd_pallas",
]
