from repro.kernels.matern.ops import h_mvm, matern_mvm
from repro.kernels.matern.ref import h_mvm_ref, matern_mvm_ref

__all__ = ["matern_mvm", "h_mvm", "matern_mvm_ref", "h_mvm_ref"]
