"""Pure-jnp oracle for the Pallas Matérn MVM (dense; small n only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import kernel_matrix


def matern_mvm_ref(
    x1: jax.Array, x2: jax.Array, v: jax.Array, params: HyperParams
) -> jax.Array:
    """Dense K(x1, x2) @ v — the correctness oracle."""
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    out = kernel_matrix(x1, x2, params, kind="matern32") @ v
    return out[:, 0] if squeeze else out


def h_mvm_ref(x: jax.Array, v: jax.Array, params: HyperParams) -> jax.Array:
    return matern_mvm_ref(x, x, v, params) + (params.noise**2) * v
