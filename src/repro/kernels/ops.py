"""Jit-ready public op around the Pallas kernel MVM, with a custom VJP.

``kernel_mvm(x1, x2, v, params, kind=...)`` computes ``K(x1, x2; theta) @ v``
for any kernel registered in ``repro.kernels.registry`` (RBF and the Matérn
family), with per-dimension lengthscales and signal scale (no noise diagonal
— HOperator adds ``sigma^2 v`` outside). ``kind=None`` defaults to
``params.kernel``.

Differentiation contract: gradients flow to ``x1``, ``x2``, ``v`` and the
hyperparameters. Lengthscale/signal gradients are picked up by plain JAX AD
through the pre-scaling ``u = x / ell`` and the post-scaling ``signal**2 *
out`` — the Pallas pair (forward + backward tile kernels) only ever sees the
unit kernel of pre-scaled inputs, and only the per-tile profile evaluation
differs between kernels. The backward pass is the paper-motivated fusion:
ONE extra sweep over distance tiles serves every hyperparameter.

On CPU (this container) the kernels run with ``interpret=True``; on TPU the
same BlockSpecs compile via Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams, resolve_kind
from repro.kernels.tiled import kernel_mvm_bwd_pallas, kernel_mvm_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    r = (-a.shape[0]) % mult
    return a if r == 0 else jnp.pad(a, ((0, r), (0, 0)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _unit_mvm(u, w, v, kind, bm, bn, interpret):
    return kernel_mvm_pallas(u, w, v, kind=kind, bm=bm, bn=bn,
                             interpret=interpret)


def _unit_mvm_fwd(u, w, v, kind, bm, bn, interpret):
    return _unit_mvm(u, w, v, kind, bm, bn, interpret), (u, w, v)


def _unit_mvm_bwd(kind, bm, bn, interpret, res, g):
    u, w, v = res
    g = g.astype(jnp.float32)
    # db = kappa(w, u) @ g  — forward kernel, roles swapped.
    dv = kernel_mvm_pallas(w, u, g, kind=kind, bm=bn, bn=bm,
                           interpret=interpret)
    # du: fused distance-tile backward; dw by the (u,w)/(g,v) symmetry
    # D(u,w,g,v)^T = D(w,u,v,g).
    du = kernel_mvm_bwd_pallas(u, w, g, v, kind=kind, bm=bm, bn=bn,
                               interpret=interpret)
    dw = kernel_mvm_bwd_pallas(w, u, v, g, kind=kind, bm=bn, bn=bm,
                               interpret=interpret)
    return du.astype(u.dtype), dw.astype(w.dtype), dv.astype(v.dtype)


_unit_mvm.defvjp(_unit_mvm_fwd, _unit_mvm_bwd)


def kernel_mvm(
    x1: jax.Array,
    x2: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 256,
    bn: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """K(x1, x2; theta) @ v via the fused Pallas kernel.

    Args:
      x1: (n, d); x2: (m, d); v: (m, s) or (m,).
      kind: registered kernel name; defaults to ``params.kernel``.
    Returns:
      (n, s) or (n,) in x1.dtype.
    """
    kind = resolve_kind(kind, params)
    if interpret is None:
        interpret = _interpret_default()
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    n = x1.shape[0]
    bm = min(bm, max(8, n))
    bn = min(bn, max(8, x2.shape[0]))
    u = _pad_rows(x1 / params.lengthscales, bm)
    w = _pad_rows(x2 / params.lengthscales, bn)
    vp = _pad_rows(v, bn)
    out = _unit_mvm(
        u.astype(jnp.float32), w.astype(jnp.float32), vp.astype(jnp.float32),
        kind, bm, bn, interpret,
    )[:n]
    out = (params.signal**2) * out
    out = out.astype(x1.dtype)
    return out[:, 0] if squeeze else out


def h_mvm(
    x: jax.Array,
    v: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 256,
    bn: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """H_theta @ v = K @ v + sigma^2 v via the Pallas kernel."""
    return kernel_mvm(x, x, v, params, kind=kind, bm=bm, bn=bn,
                      interpret=interpret) + (params.noise**2) * v


def matern_mvm(x1, x2, v, params, bm=256, bn=256, interpret=None):
    """Original Matérn-3/2 entry point (compat wrapper over kernel_mvm)."""
    return kernel_mvm(x1, x2, v, params, kind="matern32", bm=bm, bn=bn,
                      interpret=interpret)
