"""llama3-8b [dense] — arXiv:2407.21783 (unverified).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. RoPE, SwiGLU.
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    rope_theta=500_000.0,
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    mlp_activation="swiglu",
)
