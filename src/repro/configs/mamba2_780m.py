"""mamba2-780m [ssm] — arXiv:2405.21060 (unverified).

48L d_model=1536, attention-free (SSD blocks only, no FFN: d_ff=0),
vocab=50280 (padded to 50432), ssm_state=128, head_dim=64, expand=2
(d_inner=3072 -> 48 SSD heads), conv width 4, SSD chunk 256.
"""
from repro.models.config import MAMBA, LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    num_layers=48,
    d_model=1536,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(kind=MAMBA),),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    num_layers=4,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    pattern=(LayerSpec(kind=MAMBA),),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    tie_embeddings=True,
)
