"""gemma3-4b [dense] — hf:google/gemma-3-1b-pt family (unverified).

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144,
~5:1 local(1024-window SWA):global interleave, 128k context class.

34 layers = 2 periods of 17 with globals at in-period indices 5, 11, 16
(30 local : 4 global per period pair -> 28:6 over the checkpoint-faithful
ordering; documented approximation of the 5:1 rule at 34 layers).
"""
from repro.models.config import ATTN_FULL, ATTN_SWA, LayerSpec, ModelConfig

_L = LayerSpec(kind=ATTN_SWA, window=1024)
_G = LayerSpec(kind=ATTN_FULL)
_PATTERN = (_L,) * 5 + (_G,) + (_L,) * 5 + (_G,) + (_L,) * 4 + (_G,)

CONFIG = ModelConfig(
    name="gemma3-4b",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=_PATTERN,
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_SWA, window=8),) * 5
    + (LayerSpec(kind=ATTN_FULL),),
    mlp_activation="swiglu",
)
