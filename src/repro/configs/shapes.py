"""Assigned input-shape sets (the 4 LM shapes) + GP production shapes.

``train_*``   lowers train_step  (fwd + bwd + Adam, microbatched)
``prefill_*`` lowers prefill_step (full-sequence forward, no grad)
``decode_*``/``long_*`` lower serve_step (one token against a seq_len cache)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode
    # Microbatch rows per device for the train step (grad accumulation).
    microbatch_rows: int = 2


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Smoke-scale variants of the same steps (CPU, 1 device).
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train", microbatch_rows=1),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


@dataclass(frozen=True)
class GPShapeSpec:
    """Production shapes for the paper's own 'architecture' (gp-iterative)."""

    name: str
    n: int  # training rows (divisible by 512 devices)
    d: int
    num_probes: int = 64
    solver_epochs: int = 10  # budget per outer step (paper §5 large-data)


GP_SHAPES = {
    # Shapes mirror the paper's large-data regime (3droad/buzz/houseelectric),
    # rounded to multiples of 512 * block for even row sharding.
    "gp_392k": GPShapeSpec("gp_392k", 391_168, 3),
    "gp_525k": GPShapeSpec("gp_525k", 524_288, 77),
    "gp_1m8": GPShapeSpec("gp_1m8", 1_843_200, 11),
}
