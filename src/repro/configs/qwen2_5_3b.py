"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5 family (hf).

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936. QKV bias, RoPE,
SwiGLU.
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    qkv_bias=True,
    mlp_activation="swiglu",
)
