"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887 (hf).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Mamba+attention 1:7 interleave (one attention layer per 8-layer Jamba
block, at index 4), MoE every other layer.
"""
from repro.models.config import (
    ATTN_FULL,
    MAMBA,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

_PATTERN = tuple(
    LayerSpec(
        kind=ATTN_FULL if i == 4 else MAMBA,
        moe=(i % 2 == 1),
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=256),
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=_PATTERN,
    moe=MoEConfig(num_experts=4, top_k=2),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    mlp_activation="swiglu",
)
