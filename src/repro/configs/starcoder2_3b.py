"""starcoder2-3b [dense] — arXiv:2402.19173 (hf).

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. GQA, RoPE,
GELU MLP with QKV bias (starcoder2 style).
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    qkv_bias=True,
    mlp_activation="gelu",
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    qkv_bias=True,
    mlp_activation="gelu",
)
