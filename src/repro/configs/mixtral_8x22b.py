"""mixtral-8x22b [moe] — arXiv:2401.04088 (hf).

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts
top-2, sliding-window attention (4096) per spec.
"""
from repro.models.config import ATTN_SWA, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec(kind=ATTN_SWA, window=4096, moe=True),),
    moe=MoEConfig(num_experts=8, top_k=2),
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_SWA, window=16, moe=True),),
    moe=MoEConfig(num_experts=4, top_k=2),
    mlp_activation="swiglu",
)
