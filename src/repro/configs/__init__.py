"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned LM-family architectures + the paper's own gp-iterative.
Each module exposes CONFIG (exact published spec) and SMOKE (reduced
same-family variant for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import (
    GP_SHAPES,
    LM_SHAPES,
    SMOKE_SHAPES,
    GPShapeSpec,
    ShapeSpec,
)

_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "llama3-8b": "repro.configs.llama3_8b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "gp-iterative": "repro.configs.gp_iterative",
}

LM_ARCHS = tuple(k for k in _MODULES if k != "gp-iterative")
ALL_ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def runnable_cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells. long_500k only runs for archs with a
    sub-quadratic path (DESIGN.md §5 skip rule); encoder-only archs would
    skip decode shapes (none in this pool — whisper has a decoder)."""
    cells = []
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        for shape in LM_SHAPES.values():
            skip = shape.name == "long_500k" and not cfg.has_subquadratic_path
            if skip and not include_skips:
                continue
            cells.append((arch, shape.name, "skip" if skip else "run"))
    for shape in GP_SHAPES.values():
        cells.append(("gp-iterative", shape.name, "run"))
    return cells


__all__ = [
    "ALL_ARCHS", "LM_ARCHS", "GP_SHAPES", "LM_SHAPES", "SMOKE_SHAPES",
    "GPShapeSpec", "ShapeSpec", "get_config", "runnable_cells",
]
