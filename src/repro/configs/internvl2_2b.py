"""internvl2-2b [vlm] — arXiv:2404.16821 (hf).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 (padded to 92672 for
TP). InternViT frontend is a STUB per spec: input_specs supplies
precomputed patch embeddings (B, 256, 1024) projected into the sequence.
Backbone is the InternLM2-style decoder (SwiGLU + RoPE).
"""
from repro.models.config import (
    ATTN_FULL,
    FrontendConfig,
    LayerSpec,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="internvl2-2b",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    frontend=FrontendConfig(kind="vision", num_prefix=256, embed_dim=1024),
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=517,  # odd on purpose: exercises vocab padding
    pattern=(LayerSpec(kind=ATTN_FULL),),
    frontend=FrontendConfig(kind="vision", num_prefix=8, embed_dim=32),
    mlp_activation="swiglu",
)
