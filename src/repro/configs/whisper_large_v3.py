"""whisper-large-v3 [audio] — arXiv:2212.04356 (unverified).

32L d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866. Encoder-decoder;
conv frontend is a STUB per spec: input_specs supplies precomputed frame
embeddings (B, S, d_model). Sinusoidal positions (no RoPE), GELU MLP.
"""
from repro.models.config import (
    ATTN_FULL,
    EncoderConfig,
    FrontendConfig,
    LayerSpec,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="whisper-large-v3",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    encoder=EncoderConfig(num_layers=32, max_source_len=4096),
    frontend=FrontendConfig(kind="audio", embed_dim=1280),
    use_rope=False,
    mlp_activation="gelu",
    decoder_len=448,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(kind=ATTN_FULL),),
    encoder=EncoderConfig(num_layers=2, max_source_len=64),
    frontend=FrontendConfig(kind="audio", embed_dim=64),
    use_rope=False,
    mlp_activation="gelu",
    decoder_len=16,
)
