"""gp-iterative — the paper's own 'architecture'.

Iterative GP marginal-likelihood optimisation (pathwise estimator + warm
starts + epoch budgets) over any registered stationary kernel (RBF or the
Matérn family — see ``repro.kernels.registry``; Matérn-3/2 is the paper
default). Production shapes mirror the paper's large-data regime and run
through the same mesh / dry-run / roofline machinery as the LM archs
(DESIGN.md §5).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class GPArchConfig:
    name: str = "gp-iterative"
    kind: str = "matern32"  # any repro.kernels.registry name
    num_probes: int = 64
    num_rff_pairs: int = 1000
    estimator: str = "pathwise"
    warm_start: bool = True
    solver: str = "cg"
    solver_epochs: int = 10  # budget per outer step (paper §5)
    precond_rank: int = 0  # preconditioner off in the distributed path
    block_rows: int = 1024  # per-device row tile for the ring MVM

    def __post_init__(self):
        from repro.kernels.registry import get_kernel

        get_kernel(self.kind)  # fail fast on unknown kernel names


CONFIG = GPArchConfig()

SMOKE = GPArchConfig(num_probes=8, num_rff_pairs=64, solver_epochs=5)


def _sweep_entry(kind: str) -> GPArchConfig:
    # Per-kernel RFF feature counts (gp.rff.DEFAULT_NUM_PAIRS): matern12's
    # Cauchy-tailed spectrum needs 4x the pairs of the light-tailed kernels
    # for the same covariance error, and the sweep is where that matters.
    from repro.gp.rff import default_num_pairs

    return GPArchConfig(name=f"gp-iterative-{kind}", kind=kind,
                        num_rff_pairs=default_num_pairs(kind))


# One sweep entry per registered kernel — the multi-kernel scenario grid.
KERNEL_SWEEP = tuple(
    _sweep_entry(k) for k in ("matern12", "matern32", "matern52", "rbf")
)
