"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E (unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 + always-on shared expert. Chunked local attention (8192-token
chunks) 3:1 against global layers (iRoPE-style). Early-fusion multimodality
is a stub (text path exercised; vision enters as precomputed embeddings in
multimodal deployments).
"""
from repro.models.config import (
    ATTN_CHUNKED,
    ATTN_FULL,
    LayerSpec,
    ModelConfig,
    MoEConfig,
)

_C = LayerSpec(kind=ATTN_CHUNKED, window=8192, moe=True)
_G = LayerSpec(kind=ATTN_FULL, moe=True)

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(_C, _C, _C, _G),
    moe=MoEConfig(num_experts=16, top_k=1, shared_expert=True),
    rope_theta=500_000.0,
    mlp_activation="swiglu",
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(
        LayerSpec(kind=ATTN_CHUNKED, window=16, moe=True),
        LayerSpec(kind=ATTN_CHUNKED, window=16, moe=True),
        LayerSpec(kind=ATTN_CHUNKED, window=16, moe=True),
        LayerSpec(kind=ATTN_FULL, moe=True),
    ),
    moe=MoEConfig(num_experts=4, top_k=1, shared_expert=True),
    mlp_activation="swiglu",
)
