"""Static/traced config discipline checker.

PR 5 split solver configuration in two: ``SolverConfig`` is a frozen,
hashable dataclass that participates in jit cache keys (one executable
per static group), while ``SolverNumerics`` is a traced NamedTuple pytree
whose fields (tolerance, max_epochs, learning rate, ...) can vary across
vmap lanes *without* recompiling. The split only works if the two never
mix:

* ``config-static-traced`` — a ``SolverNumerics`` value (or one of its
  fields) must never flow into a hashable static position: a dict key, a
  set element, an argument to ``hash()``, or a ``static_argnums`` /
  ``static_argnames`` entry of a jit wrapper. Doing so either crashes
  (tracers are unhashable) or, worse, silently retraces per value and
  destroys the one-executable-per-group property.
* ``config-static-array`` — a frozen (hashable) config dataclass must not
  declare array-valued fields (``jax.Array``/``jnp.ndarray``/
  ``np.ndarray``): arrays don't hash stably, so such a config poisons
  every cache keyed on it.

Numerics-typed names are recognised from annotations
(``x: SolverNumerics``, ``Optional[SolverNumerics]``) and from
assignments off the canonical constructors (``numerics_of``,
``stack_numerics``, ``broadcast_numerics``).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Sequence, Set

from repro.analysis.common import Finding, call_name, parse_file, rel

_NUMERICS_TYPE = "SolverNumerics"
_NUMERICS_CTORS = {"numerics_of", "stack_numerics", "broadcast_numerics"}
_ARRAY_TYPES = ("jax.Array", "jnp.ndarray", "np.ndarray", "numpy.ndarray",
                "Array", "ndarray", "ArrayLike")


def _annotation_mentions(node: ast.AST, needle: str) -> bool:
    try:
        text = ast.unparse(node)
    except Exception:
        return False
    return needle in text


def _numerics_names(fn: ast.AST) -> Set[str]:
    """Names bound to SolverNumerics values inside ``fn``."""
    names: Set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (list(args.posonlyargs) + list(args.args) +
                  list(args.kwonlyargs)):
            if a.annotation is not None and \
                    _annotation_mentions(a.annotation, _NUMERICS_TYPE):
                names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                _annotation_mentions(node.annotation, _NUMERICS_TYPE):
            names.add(node.target.id)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            ctor = call_name(node.value).split(".")[-1]
            if ctor in _NUMERICS_CTORS or ctor == _NUMERICS_TYPE:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
    return names


def _refers_to_numerics(expr: ast.AST, names: Set[str]) -> bool:
    """``expr`` is a numerics name or an attribute chain rooted at one."""
    node = expr
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id in names


def _static_argname_strings(call: ast.Call) -> List[ast.Constant]:
    """String literals inside a jit call's ``static_argnames=``."""
    out: List[ast.Constant] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.append(n)
    return out


def _is_jit_call(call: ast.Call) -> bool:
    name = call_name(call)
    if name in ("jax.jit", "jit"):
        return True
    if name in ("partial", "functools.partial") and call.args:
        first = call.args[0]
        return ast.unparse(first) in ("jax.jit", "jit") \
            if hasattr(ast, "unparse") else False
    return False


def _check_function(fn: ast.AST, path: str,
                    findings: List[Finding]) -> None:
    names = _numerics_names(fn)
    if not names:
        return

    def flag(node: ast.AST, what: str) -> None:
        findings.append(Finding(
            rule="config-static-traced", path=path, line=node.lineno,
            message=f"SolverNumerics value flows into {what}",
            hint="numerics are traced pytree leaves; key caches on the "
                 "static SolverConfig instead (strip_numerics)",
        ))

    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for node in ast.walk(ast.Module(body=body, type_ignores=[])):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            continue  # nested defs get their own pass
        if isinstance(node, ast.Dict):
            for key in node.keys:
                if key is not None and _refers_to_numerics(key, names):
                    flag(key, "a dict key (hashable static position)")
        elif isinstance(node, ast.Set):
            for elt in node.elts:
                if _refers_to_numerics(elt, names):
                    flag(elt, "a set element (hashable static position)")
        elif isinstance(node, ast.Call):
            if call_name(node) == "hash" and node.args and \
                    _refers_to_numerics(node.args[0], names):
                flag(node, "hash() (static cache key)")


def _jit_static_params(tree: ast.AST, path: str,
                       findings: List[Finding]) -> None:
    """Flag SolverNumerics-annotated params named in static_argnames."""
    # Annotated params per function name, for resolving jit(f) wrappers.
    ann: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            params = set()
            for a in (list(node.args.posonlyargs) + list(node.args.args) +
                      list(node.args.kwonlyargs)):
                if a.annotation is not None and \
                        _annotation_mentions(a.annotation, _NUMERICS_TYPE):
                    params.add(a.arg)
            ann[node.name] = params
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and _is_jit_call(dec):
                    for const in _static_argname_strings(dec):
                        if const.value in params:
                            findings.append(Finding(
                                rule="config-static-traced", path=path,
                                line=const.lineno,
                                message=f"static_argnames marks traced "
                                        f"SolverNumerics param "
                                        f"`{const.value}` static",
                                hint="static args are hashed into the jit "
                                     "cache key; pass numerics as a traced "
                                     "pytree argument",
                            ))
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit_call(node):
            targets: Set[str] = set()
            for arg in node.args[1:] if call_name(node).endswith("partial") \
                    else node.args:
                if isinstance(arg, ast.Name):
                    targets.add(arg.id)
            for const in _static_argname_strings(node):
                for t in targets:
                    if const.value in ann.get(t, set()):
                        findings.append(Finding(
                            rule="config-static-traced", path=path,
                            line=const.lineno,
                            message=f"static_argnames marks traced "
                                    f"SolverNumerics param `{const.value}` "
                                    f"of `{t}` static",
                            hint="static args are hashed into the jit cache "
                                 "key; pass numerics as a traced pytree "
                                 "argument",
                        ))


def _frozen_dataclass_arrays(tree: ast.AST, path: str,
                             findings: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        frozen = False
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and \
                    call_name(dec).split(".")[-1] == "dataclass":
                for kw in dec.keywords:
                    if kw.arg == "frozen" and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value is True:
                        frozen = True
        if not frozen:
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                try:
                    text = ast.unparse(stmt.annotation)
                except Exception:
                    continue
                if any(t in text for t in _ARRAY_TYPES):
                    findings.append(Finding(
                        rule="config-static-array", path=path,
                        line=stmt.lineno,
                        message=f"frozen config `{node.name}` declares "
                                f"array-valued field `{stmt.target.id}`",
                        hint="static configs are jit cache keys and must "
                             "hash stably; carry arrays in a traced pytree "
                             "(e.g. SolverNumerics) instead",
                    ))


def run(paths: Sequence[Path], root: Path) -> List[Finding]:
    """Run the config-discipline checker over ``paths``."""
    findings: List[Finding] = []
    for path in paths:
        try:
            tree, _ = parse_file(path)
        except SyntaxError:
            continue
        p = rel(path, root)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _check_function(node, p, findings)
        _jit_static_params(tree, p, findings)
        _frozen_dataclass_arrays(tree, p, findings)
    # Nested defs are visited by both their own pass and the enclosing
    # function's walk — dedupe identical findings.
    return sorted(set(findings), key=lambda f: (f.path, f.line))
