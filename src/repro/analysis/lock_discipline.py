"""Lock-discipline checker: annotated shared state stays behind its lock.

The serve/obs planes are stdlib-threaded (engine worker, admission,
artifact poller, fleet scraper/monitor, event log). Their shared mutable
attributes are declared with a guard annotation on the attribute's
defining line (``self.x = ...`` in ``__init__``, or a dataclass field)::

    self._replicas = {}          #: guarded by self._lock
    self.scrape_rounds = 0       #: guarded by self._lock

(the comment may also sit on its own line directly above). From those
declarations the checker enforces, per class:

* ``lock-discipline`` — any read or write of a guarded attribute outside
  a lexical ``with self.<lock>`` block (``__init__`` and ``*_locked``
  methods are exempt: construction is single-threaded, and the
  ``_locked`` suffix is this repo's caller-holds-the-lock convention);
* a call to a ``self.*_locked(...)`` helper from outside any ``with
  self.<lock>`` block (the suffix is a contract: the caller must already
  hold the lock);
* any same-file access to a guarded attribute from *outside* the owning
  class (e.g. a handler reaching into ``self.monitor.ticks``): external
  readers must go through a locked accessor method.

The analysis is lexical, not interprocedural — it will not see a lock
held across a method call — which is exactly the granularity the
annotated classes are written to: every public method takes the lock
itself or delegates to a ``*_locked`` helper.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, parse_file, rel

_GUARD_RE = re.compile(r"#:\s*guarded by\s+self\.(\w+)")


def _guard_comments(source: str) -> Dict[int, Tuple[str, bool]]:
    """Line -> (lock name, comment-only?) for every guard annotation.

    A trailing annotation applies to its own line only; a comment-only
    line applies to the statement directly below it.
    """
    out: Dict[int, Tuple[str, bool]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _GUARD_RE.search(text)
        if m:
            out[i] = (m.group(1), text.lstrip().startswith("#"))
    return out


def _guarded_attrs(cls: ast.ClassDef,
                   comments: Dict[int, Tuple[str, bool]]) -> Dict[str, str]:
    """Attr name -> lock name for one class, from annotated declarations."""
    guarded: Dict[str, str] = {}

    def lock_for(line: int) -> Optional[str]:
        same = comments.get(line)
        if same is not None:
            return same[0]
        above = comments.get(line - 1)
        if above is not None and above[1]:  # comment-only line above
            return above[0]
        return None

    for stmt in cls.body:  # dataclass-style class-level fields
        if isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name):
            lock = lock_for(stmt.lineno)
            if lock:
                guarded[stmt.target.id] = lock
    for node in ast.walk(cls):  # self.x = ... in __init__ (or anywhere)
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    lock = lock_for(node.lineno)
                    if lock:
                        guarded[tgt.attr] = lock
    return guarded


def _exempt(name: str) -> bool:
    return name == "__init__" or name.endswith("_locked")


class _MethodScanner(ast.NodeVisitor):
    """Walk one method body tracking lexically held ``self.*`` locks."""

    def __init__(self, guarded: Dict[str, str], path: str, cls: str,
                 method: str, findings: List[Finding]):
        self.guarded = guarded
        self.path = path
        self.cls = cls
        self.method = method
        self.findings = findings
        self.held: Set[str] = set()

    def _flag(self, node: ast.AST, attr: str, lock: str) -> None:
        self.findings.append(Finding(
            rule="lock-discipline", path=self.path, line=node.lineno,
            message=f"`self.{attr}` accessed outside `with self.{lock}` "
                    f"(in `{self.cls}.{self.method}`)",
            hint=f"take `with self.{lock}:` around the access, or move it "
                 f"into a `*_locked` helper called under the lock",
        ))

    def visit_With(self, node: ast.With) -> None:
        added: Set[str] = set()
        for item in node.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Attribute) and \
                    isinstance(ctx.value, ast.Name) and ctx.value.id == "self":
                if ctx.attr not in self.held:
                    added.add(ctx.attr)
        self.held |= added
        for stmt in node.body:
            self.visit(stmt)
        self.held -= added

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self" and \
                node.attr in self.guarded:
            lock = self.guarded[node.attr]
            if lock not in self.held:
                self._flag(node, node.attr, lock)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self" and f.attr.endswith("_locked") \
                and not self.held:
            self.findings.append(Finding(
                rule="lock-discipline", path=self.path, line=node.lineno,
                message=f"`self.{f.attr}()` called without holding a lock "
                        f"(in `{self.cls}.{self.method}`); the `_locked` "
                        "suffix means the caller must hold it",
                hint="call it inside `with self.<lock>:`, or rename the "
                     "helper if it actually takes the lock itself",
            ))
        self.generic_visit(node)

    # Nested defs inherit the enclosing lock scope only if the closure is
    # called inline — too dynamic to track; treat them as lock-free.
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        saved, self.held = self.held, set()
        self.generic_visit(node)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef


def _check_class(cls: ast.ClassDef, guarded: Dict[str, str], path: str,
                 findings: List[Finding]) -> None:
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                not _exempt(stmt.name):
            scanner = _MethodScanner(guarded, path, cls.name, stmt.name,
                                     findings)
            for inner in stmt.body:
                scanner.visit(inner)


def _check_foreign_access(tree: ast.AST, owners: Dict[str, Tuple[str, str]],
                          path: str, findings: List[Finding]) -> None:
    """Flag same-file access to a guarded attr from outside its class."""

    class V(ast.NodeVisitor):
        def __init__(self) -> None:
            self.cls_stack: List[str] = []

        def visit_ClassDef(self, node: ast.ClassDef) -> None:
            self.cls_stack.append(node.name)
            self.generic_visit(node)
            self.cls_stack.pop()

        def visit_Attribute(self, node: ast.Attribute) -> None:
            info = owners.get(node.attr)
            if info is not None:
                owner_cls, lock = info
                in_owner = bool(self.cls_stack) and \
                    self.cls_stack[-1] == owner_cls
                is_self = isinstance(node.value, ast.Name) and \
                    node.value.id == "self"
                if not (in_owner and is_self) and not is_self:
                    findings.append(Finding(
                        rule="lock-discipline", path=path, line=node.lineno,
                        message=f"guarded `{owner_cls}.{node.attr}` read "
                                "from outside its class without "
                                f"`{owner_cls}`'s `{lock}`",
                        hint=f"add a locked accessor on `{owner_cls}` and "
                             "call that instead of reaching into the "
                             "attribute",
                    ))
            self.generic_visit(node)

    V().visit(tree)


def run(paths: Sequence[Path], root: Path) -> List[Finding]:
    """Run the lock-discipline checker over ``paths``."""
    findings: List[Finding] = []
    for path in paths:
        try:
            tree, source = parse_file(path)
        except SyntaxError:
            continue
        comments = _guard_comments(source)
        if not comments:
            continue
        p = rel(path, root)
        owners: Dict[str, Tuple[str, str]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                guarded = _guarded_attrs(node, comments)
                if guarded:
                    for attr, lock in guarded.items():
                        owners[attr] = (node.name, lock)
                    _check_class(node, guarded, p, findings)
        if owners:
            _check_foreign_access(tree, owners, p, findings)
    return sorted(set(findings), key=lambda f: (f.path, f.line))
