"""repro-lint runner: checker dispatch, suppressions, baseline round-trip.

Orchestrates the five checkers over their scoped slices of ``src/repro``
and applies the suppression contract:

1. A finding on a line carrying (or directly below) an inline
   ``# repro-lint: disable=<rule> -- <reason>`` comment is *suppressed*.
2. Every suppressed finding must also appear in
   ``src/repro/analysis/baseline.json`` (rule + path + reason). A
   suppression without a baseline entry is an error — the baseline is the
   reviewed ledger, the comment is the in-situ justification, and both
   must exist.
3. A baseline entry with no live suppressed finding is *stale* and also
   an error, so the ledger can't rot.

``--update-baseline`` regenerates the ledger from the current inline
suppressions (it cannot invent one: a finding without an inline comment
stays active). Exit status: 0 clean, 1 findings or contract violations.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from repro.analysis import (config_discipline, freeze_mask, lock_discipline,
                            telemetry, trace_safety)
from repro.analysis.common import (Finding, dump_baseline, find_suppressions,
                                   iter_py, load_baseline, suppression_for)

#: checker module -> repo-relative directories it scans.
CHECKER_SCOPES = (
    (trace_safety, ("src/repro/solvers", "src/repro/core", "src/repro/gp",
                    "src/repro/online")),
    (config_discipline, ("src/repro",)),
    (freeze_mask, ("src/repro/solvers",)),
    (lock_discipline, ("src/repro",)),
    (telemetry, ("src/repro",)),
)

BASELINE = "src/repro/analysis/baseline.json"


def collect_findings(root: Path) -> List[Finding]:
    """All raw findings from all checkers (suppressions not yet applied)."""
    findings: List[Finding] = []
    for checker, dirs in CHECKER_SCOPES:
        paths = list(iter_py(root, dirs))
        findings.extend(checker.run(paths, root))
    return sorted(set(findings), key=lambda f: (f.path, f.line, f.rule))


def partition(root: Path, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Tuple[Finding, str]],
                         List[str]]:
    """Split findings into (active, suppressed(+reason), errors)."""
    active: List[Finding] = []
    suppressed: List[Tuple[Finding, str]] = []
    errors: List[str] = []
    cache: Dict[str, dict] = {}
    for f in findings:
        if f.path not in cache:
            try:
                cache[f.path] = find_suppressions(
                    (root / f.path).read_text(encoding="utf-8"))
            except OSError:
                cache[f.path] = {}
        sup = suppression_for(f, cache[f.path])
        if sup is None:
            active.append(f)
        elif not sup.reason:
            errors.append(
                f"{f.path}:{sup.line}: suppression for [{f.rule}] has no "
                "reason — write `# repro-lint: disable=<rule> -- <why>`")
            active.append(f)
        else:
            suppressed.append((f, sup.reason))
    return active, suppressed, errors


def check_baseline(root: Path,
                   suppressed: Sequence[Tuple[Finding, str]]) -> List[str]:
    """Cross-validate inline suppressions against baseline.json."""
    errors: List[str] = []
    entries = load_baseline(root / BASELINE)
    baseline_keys = {(e["rule"], e["path"]) for e in entries}
    live_keys = {(f.rule, f.path) for f, _ in suppressed}
    for f, _reason in suppressed:
        if (f.rule, f.path) not in baseline_keys:
            errors.append(
                f"{f.path}:{f.line}: suppressed [{f.rule}] finding missing "
                f"from {BASELINE} — run `python tools/repro_lint.py "
                "--update-baseline` and commit the reviewed entry")
    for rule, path in sorted(baseline_keys - live_keys):
        errors.append(
            f"{BASELINE}: stale entry [{rule}] for {path} — no matching "
            "inline suppression remains; remove it (or re-run "
            "--update-baseline)")
    return errors


def update_baseline(root: Path,
                    suppressed: Sequence[Tuple[Finding, str]]) -> int:
    """Rewrite baseline.json from the current inline suppressions."""
    seen = set()
    entries = []
    for f, reason in suppressed:
        key = (f.rule, f.path)
        if key not in seen:
            seen.add(key)
            entries.append({"rule": f.rule, "path": f.path,
                            "reason": reason})
    dump_baseline(root / BASELINE, entries)
    print(f"wrote {len(entries)} entries to {BASELINE}")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    """CLI entry point; returns the process exit status."""
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="Project-invariant static analysis for this repo "
                    "(trace safety, config discipline, freeze masks, lock "
                    "discipline, telemetry hygiene).")
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    ap.add_argument("--check", action="store_true",
                    help="explicit check mode (the default behaviour; "
                         "exists for CI readability)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate analysis/baseline.json from the "
                         "current inline suppressions")
    ap.add_argument("--verbose", action="store_true",
                    help="also list suppressed findings")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    findings = collect_findings(root)
    active, suppressed, errors = partition(root, findings)

    if args.update_baseline:
        return update_baseline(root, suppressed)

    errors.extend(check_baseline(root, suppressed))
    for f in active:
        print(f.render())
    for e in errors:
        print(e)
    if args.verbose:
        for f, reason in suppressed:
            print(f"suppressed: {f.path}:{f.line} [{f.rule}] — {reason}")
    n = len(active) + len(errors)
    if n:
        print(f"repro-lint: {len(active)} finding(s), "
              f"{len(errors)} contract error(s)")
        return 1
    print(f"repro-lint: clean ({len(suppressed)} baselined suppression(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
