"""repro-lint: stdlib-``ast`` static analysis for this repo's invariants.

Five checkers, each encoding a contract the codebase depends on but
Python cannot express:

* :mod:`repro.analysis.trace_safety` — no host round-trips, Python
  branches on traced values, or wall-clock/entropy reads inside code
  reachable from jit/scan/while_loop/vmap.
* :mod:`repro.analysis.config_discipline` — the static
  ``SolverConfig`` / traced ``SolverNumerics`` split stays intact.
* :mod:`repro.analysis.freeze_mask` — solver while-loop state updates
  stay behind the per-lane ``freeze`` mask.
* :mod:`repro.analysis.lock_discipline` — annotated shared attributes of
  the threaded serve/obs classes are only touched under their lock.
* :mod:`repro.analysis.telemetry` — bounded metric label sets and
  documented ``emit()`` event schemas.

Run via ``python tools/repro_lint.py`` (CI job ``static-lint``); the
suppression / baseline contract lives in :mod:`repro.analysis.runner`.
The whole package imports without jax so it runs in bare CI jobs.
"""
from repro.analysis.common import ALL_RULES, Finding

__all__ = ["ALL_RULES", "Finding"]
