"""Freeze-mask checker: converged lanes must stay frozen.

The lane-batched solvers (PR 3) run all vmap lanes for the same number of
``lax.while_loop`` trips and rely on the per-lane ``active`` mask to make
iteration counts honest: every loop-carried state field must be written
through ``freeze(active, new, old)`` (or ``history_record``, which applies
the mask internally), or advance by an ``active``-gated expression such as
``t + active.astype(int32)``. An unguarded assignment lets a converged
lane keep mutating — residuals drift, ``iters`` lies, and the vmap result
no longer matches the single-lane solve bit-for-bit.

Rule ``freeze-mask``: inside any function passed as the *body* of
``lax.while_loop`` in a solver module, every field of the returned
``_*State(...)`` constructor must be one of

* a ``freeze(...)`` / ``history_record(...)`` call,
* a carry-through of the incoming state (``s.field`` or ``s`` itself),
* an expression that references the ``active`` mask.

Anything else is flagged with the field name. Intentional exceptions
(e.g. SGD advancing its PRNG key on frozen lanes so lane draws stay
decorrelated) carry an inline suppression plus a baseline entry.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.common import Finding, call_name, parse_file, rel

_STATE_CTOR = re.compile(r"^_\w*State$")
_MASK_WRAPPERS = {"freeze", "history_record"}


def _first_param(fn: ast.AST) -> Optional[str]:
    args = getattr(fn, "args", None)
    if args is None:
        return None
    pos = list(args.posonlyargs) + list(args.args)
    return pos[0].arg if pos else None


def _mentions_active(expr: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "active"
               for n in ast.walk(expr))


def _is_carry_through(expr: ast.AST, carry: Optional[str]) -> bool:
    """``s.field`` (possibly nested attributes) or ``s`` itself."""
    node = expr
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id == carry


def _field_ok(expr: ast.AST, carry: Optional[str]) -> bool:
    if isinstance(expr, ast.Call):
        name = call_name(expr).split(".")[-1]
        if name in _MASK_WRAPPERS:
            return True
    if _is_carry_through(expr, carry):
        return True
    return _mentions_active(expr)


def _body_functions(tree: ast.AST) -> List[ast.AST]:
    """Function defs / lambdas passed as the body arg of lax.while_loop."""
    by_name = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)
    bodies: List[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_name(node) in (
                "lax.while_loop", "jax.lax.while_loop", "while_loop"):
            if len(node.args) >= 2:
                body = node.args[1]
                if isinstance(body, ast.Lambda):
                    bodies.append(body)
                elif isinstance(body, ast.Name) and body.id in by_name:
                    bodies.append(by_name[body.id])
    return bodies


def _check_body(fn: ast.AST, path: str) -> List[Finding]:
    carry = _first_param(fn)
    findings: List[Finding] = []
    returns = ([fn.body] if isinstance(fn, ast.Lambda) else
               [n.value for n in ast.walk(fn)
                if isinstance(n, ast.Return) and n.value is not None])
    for ret in returns:
        if not (isinstance(ret, ast.Call) and
                _STATE_CTOR.match(call_name(ret).split(".")[-1] or "")):
            continue
        ctor = call_name(ret).split(".")[-1]
        for kw in ret.keywords:
            if kw.arg is None:  # **splat: can't see the fields — skip
                continue
            if not _field_ok(kw.value, carry):
                findings.append(Finding(
                    rule="freeze-mask", path=path, line=kw.value.lineno,
                    message=f"loop-carried field `{ctor}.{kw.arg}` is not "
                            "frozen for converged lanes",
                    hint="wrap in freeze(active, new, old) / history_record, "
                         "or gate the update on `active`",
                ))
        for i, arg in enumerate(ret.args):
            if not _field_ok(arg, carry):
                findings.append(Finding(
                    rule="freeze-mask", path=path, line=arg.lineno,
                    message=f"loop-carried positional field #{i} of "
                            f"`{ctor}` is not frozen for converged lanes",
                    hint="wrap in freeze(active, new, old) / history_record, "
                         "or gate the update on `active`",
                ))
    return findings


def run(paths: Sequence[Path], root: Path) -> List[Finding]:
    """Run the freeze-mask checker over ``paths``; returns findings."""
    findings: List[Finding] = []
    for path in paths:
        try:
            tree, _ = parse_file(path)
        except SyntaxError:
            continue
        for body in _body_functions(tree):
            findings.extend(_check_body(body, rel(path, root)))
    findings.sort(key=lambda f: (f.path, f.line))
    return findings
