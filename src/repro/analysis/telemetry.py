"""Telemetry hygiene checker: bounded labels, schema'd events.

Two rules protect the PR 7/9 observability plane:

* ``telemetry-label`` — metric label values must come from bounded sets.
  A label built with an f-string / ``.format`` / ``%`` / string
  concatenation of request or traced data mints a new time series per
  distinct value; the PR 9 fleet scraper re-exports every series per
  replica, so one unbounded label cardinality-explodes the whole fleet
  plane. Checked at every ``self._m_*.inc/.set/.observe(...)`` call
  site, including one hop through a local name assigned in the same
  function. (``str(x)`` of an already-bounded value, e.g. a bucket size,
  is the sanctioned spelling.)
* ``telemetry-event-schema`` — ``emit("<kind>", ...)`` events are the
  repo's wire format for ``tools/trace_report.py`` and the tests; their
  kinds and keys are documented in ``docs/observability.md`` /
  ``docs/adaptive.md``. An unknown kind or an off-schema key silently
  breaks every downstream consumer, so both are flagged at the call
  site. ``**dynamic`` payloads are skipped (they are schema'd at the
  producer, e.g. the driver's ``solve_step`` fields).

``EVENT_SCHEMAS`` below is the canonical machine-readable copy of the
documented schemas; extend it in the same PR that documents a new event
kind.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis.common import Finding, parse_file, rel

#: Event kind -> allowed field names (docs/observability.md, docs/adaptive.md).
EVENT_SCHEMAS: Dict[str, frozenset] = {
    "request": frozenset({"method", "path", "status", "dur_ms"}),
    "admission": frozenset({"outcome", "rows", "priority", "retry_after_s",
                            "inflight"}),
    "span": frozenset({"span", "dur_ms", "error", "rows", "bucket"}),
    "solve_step": frozenset({"step", "solver", "lane", "res_y", "res_z",
                             "iters", "epochs", "step_time_s",
                             "res_history"}),
    "fit_done": frozenset({"solver", "num_steps", "total_iters",
                           "total_epochs", "wall_time_s", "solver_time_s"}),
    "budget_decision": frozenset({"step", "solver", "lane", "alloc",
                                  "pred_to_tol", "realised", "res", "slope",
                                  "noise", "perturbation", "grad_noise",
                                  "pool", "epochs_per_iter"}),
    "refresh": frozenset({"mode", "n", "appended", "epochs", "iters",
                          "res_y", "res_z", "escalated", "corrected",
                          "trace_ids"}),
    "slo_alert": frozenset({"slo", "from_state", "to_state", "objective",
                            "burn_rates"}),
}

#: Keys every event may carry (stamped by the EventLog itself or tracing).
GLOBAL_EVENT_KEYS = frozenset({"ts", "kind", "trace_id"})

_LABEL_METHODS = {"inc", "set", "observe"}


def _is_unbounded_expr(expr: ast.AST) -> Optional[str]:
    """Why ``expr`` is an unbounded label value, or None if it's fine."""
    if isinstance(expr, ast.JoinedStr):
        return "f-string"
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Attribute) \
            and expr.func.attr == "format":
        return ".format() call"
    if isinstance(expr, ast.BinOp):
        if isinstance(expr.op, ast.Mod):
            return "%-formatting"
        if isinstance(expr.op, ast.Add):
            for side in (expr.left, expr.right):
                if isinstance(side, ast.Constant) and \
                        isinstance(side.value, str):
                    return "string concatenation"
                if isinstance(side, ast.JoinedStr):
                    return "string concatenation"
    if isinstance(expr, ast.IfExp):
        return _is_unbounded_expr(expr.body) or \
            _is_unbounded_expr(expr.orelse)
    return None


def _local_assignments(fn: ast.AST) -> Dict[str, ast.AST]:
    """Last ``name = <expr>`` value per simple local name in ``fn``."""
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _metric_receiver(call: ast.Call) -> Optional[str]:
    """Instrument attr name if this is a ``*._m_*.<inc|set|observe>()``."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in _LABEL_METHODS and \
            isinstance(f.value, ast.Attribute) and \
            f.value.attr.startswith("_m_"):
        return f.value.attr
    return None


def _check_labels(fn: ast.AST, path: str, findings: List[Finding]) -> None:
    assigns = _local_assignments(fn)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        instrument = _metric_receiver(node)
        if instrument is None:
            continue
        for kw in node.keywords:
            if kw.arg is None:
                continue
            expr = kw.value
            why = _is_unbounded_expr(expr)
            if why is None and isinstance(expr, ast.Name) and \
                    expr.id in assigns:
                why = _is_unbounded_expr(assigns[expr.id])
                if why:
                    why = f"{why} (via `{expr.id} = ...`)"
            if why:
                findings.append(Finding(
                    rule="telemetry-label", path=path, line=node.lineno,
                    message=f"label `{kw.arg}` of `{instrument}` built "
                            f"from {why} — unbounded cardinality",
                    hint="map dynamic values onto a small fixed vocabulary "
                         "before labelling (see the `other` path label); "
                         "each distinct value is a new fleet-wide series",
                ))


def _check_emits(tree: ast.AST, path: str, findings: List[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == "emit"):
            continue
        if not node.args:
            continue
        kind_node = node.args[0]
        if not (isinstance(kind_node, ast.Constant) and
                isinstance(kind_node.value, str)):
            continue  # dynamic kind: schema'd at the producer
        kind = kind_node.value
        schema = EVENT_SCHEMAS.get(kind)
        if schema is None:
            findings.append(Finding(
                rule="telemetry-event-schema", path=path, line=node.lineno,
                message=f"emit of undocumented event kind `{kind}`",
                hint="document the kind in docs/observability.md (or "
                     "docs/adaptive.md) and add it to EVENT_SCHEMAS in "
                     "repro/analysis/telemetry.py in the same PR",
            ))
            continue
        for kw in node.keywords:
            if kw.arg is None:
                continue  # **payload — schema'd at the producer
            if kw.arg not in schema and kw.arg not in GLOBAL_EVENT_KEYS:
                findings.append(Finding(
                    rule="telemetry-event-schema", path=path,
                    line=node.lineno,
                    message=f"event `{kind}` carries undocumented key "
                            f"`{kw.arg}`",
                    hint=f"documented keys: {sorted(schema)}; update the "
                         "docs + EVENT_SCHEMAS if the schema is growing",
                ))


def run(paths: Sequence[Path], root: Path) -> List[Finding]:
    """Run the telemetry checker over ``paths``."""
    findings: List[Finding] = []
    for path in paths:
        try:
            tree, _ = parse_file(path)
        except SyntaxError:
            continue
        p = rel(path, root)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _check_labels(node, p, findings)
        _check_emits(tree, p, findings)
    return sorted(set(findings), key=lambda f: (f.path, f.line))
