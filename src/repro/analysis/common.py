"""Shared plumbing for the repro-lint checkers.

A finding is (rule, path, line, message, hint). Paths are repo-relative
POSIX strings so findings are stable across machines and usable as
baseline keys. Suppressions are inline comments of the form::

    x = bad_thing()  # repro-lint: disable=<rule> -- <reason>

(the separator may be ``--`` or an em/en dash; the reason is mandatory).
A suppression matches findings on its own line or on the line directly
below it (comment-above style). Suppressed findings must additionally be
recorded in ``analysis/baseline.json`` — see :mod:`repro.analysis.runner`
for the round-trip contract.

Everything here is stdlib-only (``ast`` + ``pathlib``): the suite must run
in a bare CI job with no jax installed.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

#: All rule IDs the suite can emit (one entry per checker sub-rule).
ALL_RULES = (
    "trace-host-sync",
    "trace-python-branch",
    "trace-impure-call",
    "config-static-traced",
    "config-static-array",
    "freeze-mask",
    "lock-discipline",
    "telemetry-label",
    "telemetry-event-schema",
)

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)"
    r"(?:\s*(?:--|—|–)\s*(\S[^\n]*))?"
)


@dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location."""

    rule: str
    path: str  # repo-relative POSIX path
    line: int
    message: str
    hint: str = ""

    def render(self) -> str:
        """Human-readable one-liner, ``path:line: [rule] message``."""
        s = f"{self.path}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s


@dataclass(frozen=True)
class Suppression:
    """An inline ``# repro-lint: disable=`` comment."""

    rules: Tuple[str, ...]
    reason: str
    line: int
    comment_only: bool = False  # whole line is a comment (applies below)


def rel(path: Path, root: Path) -> str:
    """``path`` relative to ``root`` as a POSIX string (or absolute posix)."""
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def parse_file(path: Path) -> Tuple[ast.AST, str]:
    """Parse ``path``; returns ``(tree, source)``."""
    source = path.read_text(encoding="utf-8")
    return ast.parse(source, filename=str(path)), source


def iter_py(root: Path, rel_dirs: Sequence[str]) -> Iterator[Path]:
    """Yield ``*.py`` files under each ``root``-relative directory, sorted."""
    for d in rel_dirs:
        base = root / d
        if not base.exists():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" not in p.parts:
                yield p


def find_suppressions(source: str) -> Dict[int, Suppression]:
    """Map line number -> suppression for every inline disable comment.

    A malformed comment (missing reason) is surfaced as a suppression with
    an empty reason; the runner turns that into an error rather than
    honouring it, so a justification can never be silently omitted.
    """
    out: Dict[int, Suppression] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            out[i] = Suppression(rules=rules, reason=(m.group(2) or "").strip(),
                                 line=i,
                                 comment_only=text.lstrip().startswith("#"))
    return out


def suppression_for(finding: Finding,
                    suppressions: Dict[int, Suppression]) -> Optional[Suppression]:
    """The suppression covering ``finding``, if any.

    Matches a comment on the finding's own line, or a comment-only line
    directly above it (a *trailing* comment never leaks downward).
    """
    sup = suppressions.get(finding.line)
    if sup is not None and finding.rule in sup.rules:
        return sup
    sup = suppressions.get(finding.line - 1)
    if sup is not None and sup.comment_only and finding.rule in sup.rules:
        return sup
    return None


def load_baseline(path: Path) -> List[dict]:
    """Read ``baseline.json``; each entry is ``{rule, path, reason}``."""
    if not path.exists():
        return []
    data = json.loads(path.read_text(encoding="utf-8"))
    return list(data.get("suppressions", []))


def dump_baseline(path: Path, entries: Iterable[dict]) -> None:
    """Write ``baseline.json`` (sorted, stable formatting)."""
    entries = sorted(entries, key=lambda e: (e["path"], e["rule"]))
    payload = {
        "_comment": (
            "Reviewed intentional violations. Every entry must have a "
            "matching inline '# repro-lint: disable=<rule> -- <reason>' "
            "comment at the finding site. Regenerate with "
            "'python tools/repro_lint.py --update-baseline'."
        ),
        "suppressions": entries,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def call_name(node: ast.Call) -> str:
    """Dotted name of a call target (``a.b.c(...)`` -> ``"a.b.c"``)."""
    return dotted(node.func)


def dotted(node: ast.AST) -> str:
    """Dotted path of a Name/Attribute chain, '' if not a plain chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""
