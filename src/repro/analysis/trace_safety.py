"""Trace-safety checker: no host round-trips inside traced code.

Functions reachable from a ``jax.jit`` / ``lax.while_loop`` / ``lax.scan``
/ ``jax.vmap`` entry point run under a tracer: a ``float()`` on a traced
array forces a device sync (and a `ConcretizationTypeError` under jit), a
Python ``if`` on a traced value silently bakes one branch into the
compiled program, and a wall-clock or entropy read is frozen at trace
time — all three poison the retrace-free paths PR 5/8 depend on.

The checker walks every module it is given, seeds a call graph from

* decorators / wrappers: ``@jax.jit``, ``@partial(jax.jit, ...)``,
  ``f2 = jax.jit(f)``, ``jax.vmap(f)``,
* loop primitives: the ``cond``/``body`` of ``lax.while_loop`` and the
  body of ``lax.scan`` (their carry parameters are *known traced*),

propagates reachability through same-module and ``from repro.x import f``
call edges, and then scans each reachable function with a deliberately
conservative taint analysis: parameters are only tainted for loop
bodies/conds (where the carry is traced by construction); otherwise taint
enters through ``jnp.*`` / ``jax.*`` / ``lax.*`` expressions and spreads
through assignment. Rules:

* ``trace-host-sync`` — ``float()/int()/bool()`` on a tainted value,
  ``.item()``/``.tolist()`` on a tainted receiver, any ``np.asarray`` /
  ``np.array`` call.
* ``trace-python-branch`` — ``if``/``while`` whose test is tainted
  (``is None`` structure checks and ``isinstance`` are exempt: they are
  resolved at trace time by design).
* ``trace-impure-call`` — ``time.time/perf_counter/monotonic/time_ns``,
  ``datetime.now/utcnow``, ``secrets.*``, ``os.urandom``, ``uuid.uuid4``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, call_name, dotted, parse_file, rel

#: Dotted call targets that read wall clocks or entropy sources.
_IMPURE_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "datetime.now", "datetime.datetime.now",
    "datetime.utcnow", "datetime.datetime.utcnow", "os.urandom",
    "uuid.uuid4",
}
_IMPURE_PREFIXES = ("secrets.",)

#: Roots whose call results are treated as traced values.
_TRACED_ROOTS = ("jnp", "jax", "lax")

_HOST_CASTS = {"float", "int", "bool", "complex"}
_HOST_METHODS = {"item", "tolist"}
_NUMPY_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "onp.asarray", "onp.array"}


class _Fn:
    """A function definition plus where it lives and how it was seeded."""

    def __init__(self, node: ast.AST, path: Path, module: str):
        self.node = node
        self.path = path
        self.module = module
        self.loop_role: Optional[str] = None  # "body"/"cond" of scan/while

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


def _module_name(path: Path, root: Path) -> str:
    """Dotted module path of ``path`` relative to ``root`` (src-aware)."""
    r = rel(path, root)
    r = r[:-3] if r.endswith(".py") else r
    parts = [p for p in r.split("/") if p]
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _index_functions(tree: ast.AST, path: Path,
                     module: str) -> Dict[str, List[_Fn]]:
    """All (async) function defs in ``tree`` keyed by bare name."""
    out: Dict[str, List[_Fn]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(_Fn(node, path, module))
    return out


def _import_map(tree: ast.AST) -> Dict[str, Tuple[str, str]]:
    """``from repro.x import f [as g]`` -> ``{g: ("repro.x", "f")}``."""
    out: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = (node.module, alias.name)
    return out


def _is_jit_like(expr: ast.AST) -> bool:
    """True for ``jax.jit`` / ``jit`` / ``jax.vmap`` / ``vmap`` /
    ``partial(jax.jit, ...)`` expressions."""
    name = dotted(expr)
    if name in ("jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap", "pmap"):
        return True
    if isinstance(expr, ast.Call) and call_name(expr) in ("partial",
                                                          "functools.partial"):
        return bool(expr.args) and _is_jit_like(expr.args[0])
    return False


class _Graph:
    """Seeded call graph over a set of modules."""

    def __init__(self) -> None:
        self.functions: Dict[str, Dict[str, List[_Fn]]] = {}  # module -> name
        self.imports: Dict[str, Dict[str, Tuple[str, str]]] = {}
        self.trees: Dict[str, ast.AST] = {}
        self.sources: Dict[str, str] = {}
        self.paths: Dict[str, Path] = {}
        self.seeds: List[_Fn] = []
        self.lambdas: List[_Fn] = []  # lambdas passed to traced primitives

    def resolve(self, module: str, name: str) -> List[_Fn]:
        """Function defs a bare call name refers to, following imports."""
        fns = self.functions.get(module, {}).get(name)
        if fns:
            return fns
        imp = self.imports.get(module, {}).get(name)
        if imp and imp[0] in self.functions:
            return self.functions[imp[0]].get(imp[1], [])
        return []


def _collect_seeds(graph: _Graph, module: str, tree: ast.AST) -> None:
    """Find traced entry points in one module and add them to the graph."""

    def seed_ref(expr: ast.AST, role: Optional[str] = None) -> None:
        if isinstance(expr, ast.Lambda):
            fn = _Fn(expr, graph.paths[module], module)
            fn.loop_role = role
            graph.lambdas.append(fn)
            graph.seeds.append(fn)
        elif isinstance(expr, ast.Name):
            for fn in graph.resolve(module, expr.id):
                if role and fn.loop_role is None:
                    fn.loop_role = role
                graph.seeds.append(fn)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jit_like(dec):
                    for fn in graph.functions[module].get(node.name, []):
                        if fn.node is node:
                            graph.seeds.append(fn)
        elif isinstance(node, ast.Call):
            name = call_name(node)
            if name in ("lax.while_loop", "jax.lax.while_loop", "while_loop"):
                if len(node.args) >= 2:
                    seed_ref(node.args[0], role="cond")
                    seed_ref(node.args[1], role="body")
            elif name in ("lax.scan", "jax.lax.scan", "scan"):
                if node.args:
                    seed_ref(node.args[0], role="body")
            elif name in ("jax.jit", "jit", "jax.vmap", "vmap"):
                if node.args:
                    seed_ref(node.args[0])
            elif _is_jit_like(node.func):
                # partial(jax.jit, ...)(f)
                if node.args:
                    seed_ref(node.args[0])


def _propagate(graph: _Graph) -> List[_Fn]:
    """BFS the call graph from the seeds; returns reachable functions."""
    seen: Set[int] = set()
    work = list(graph.seeds)
    reachable: List[_Fn] = []
    while work:
        fn = work.pop()
        if id(fn.node) in seen:
            continue
        seen.add(id(fn.node))
        reachable.append(fn)
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                for callee in graph.resolve(fn.module, node.func.id):
                    if id(callee.node) not in seen:
                        work.append(callee)
    return reachable


# ---------------------------------------------------------------------------
# per-function scan


def _expr_tainted(expr: ast.AST, tainted: Set[str]) -> bool:
    """True if ``expr`` references a tainted name or a jnp/jax/lax call."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in tainted:
            return True
        if isinstance(node, ast.Call):
            root = call_name(node).split(".", 1)[0]
            if root in _TRACED_ROOTS:
                return True
    return False


def _collect_taint(fn: _Fn) -> Set[str]:
    """Names holding (potentially) traced values inside ``fn``."""
    tainted: Set[str] = set()
    node = fn.node
    if fn.loop_role is not None:
        args = node.args
        for a in list(args.args) + list(args.posonlyargs) + list(args.kwonlyargs):
            tainted.add(a.arg)
    # Two passes so taint assigned below a use-before-def still lands.
    for _ in range(2):
        for stmt in ast.walk(node):
            if isinstance(stmt, ast.Assign) and _expr_tainted(stmt.value, tainted):
                for tgt in stmt.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                if stmt.value is not None and _expr_tainted(stmt.value, tainted):
                    if isinstance(stmt.target, ast.Name):
                        tainted.add(stmt.target.id)
    return tainted


def _branch_exempt(test: ast.AST) -> bool:
    """Structure checks resolved at trace time: ``is None``, isinstance."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _branch_exempt(test.operand)
    if isinstance(test, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return True
    if isinstance(test, ast.Call) and call_name(test) in ("isinstance",
                                                          "hasattr", "len"):
        return True
    if isinstance(test, ast.BoolOp):
        return all(_branch_exempt(v) for v in test.values)
    return False


def _scan_function(fn: _Fn, root: Path) -> List[Finding]:
    path = rel(fn.path, root)
    tainted = _collect_taint(fn)
    findings: List[Finding] = []

    def add(rule: str, node: ast.AST, message: str, hint: str) -> None:
        findings.append(Finding(rule=rule, path=path, line=node.lineno,
                                message=f"{message} (in traced "
                                        f"`{fn.name}`)", hint=hint))

    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name in _HOST_CASTS and node.args and \
                    _expr_tainted(node.args[0], tainted):
                add("trace-host-sync", node,
                    f"`{name}()` on a traced value forces a host sync",
                    "keep the value as an array (jnp ops) or move the "
                    "conversion outside the jitted region")
            elif name in _NUMPY_CALLS:
                add("trace-host-sync", node,
                    f"`{name}` materialises a traced value on the host",
                    "use jnp.asarray inside traced code; np conversions "
                    "belong in host-side driver code")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _HOST_METHODS and \
                    _expr_tainted(node.func.value, tainted):
                add("trace-host-sync", node,
                    f"`.{node.func.attr}()` on a traced value forces a "
                    "host sync",
                    "return the array and convert in the host-side caller")
            elif name in _IMPURE_CALLS or \
                    any(name.startswith(p) for p in _IMPURE_PREFIXES):
                add("trace-impure-call", node,
                    f"`{name}()` is frozen at trace time inside jit",
                    "pass clocks/randomness in as arguments (jax.random "
                    "keys for entropy); measure time in the caller")
        elif isinstance(node, (ast.If, ast.While)):
            if not _branch_exempt(node.test) and \
                    _expr_tainted(node.test, tainted):
                kind = "if" if isinstance(node, ast.If) else "while"
                add("trace-python-branch", node,
                    f"Python `{kind}` on a traced value bakes one branch "
                    "into the compiled program",
                    "use lax.cond/lax.select/jnp.where (or lax.while_loop "
                    "for loops) so both branches trace")
    return findings


def run(paths: Sequence[Path], root: Path) -> List[Finding]:
    """Run the trace-safety checker over ``paths``; returns findings."""
    graph = _Graph()
    for path in paths:
        try:
            tree, source = parse_file(path)
        except SyntaxError:
            continue
        module = _module_name(path, root)
        graph.trees[module] = tree
        graph.sources[module] = source
        graph.paths[module] = path
        graph.functions[module] = _index_functions(tree, path, module)
        graph.imports[module] = _import_map(tree)
    for module, tree in graph.trees.items():
        _collect_seeds(graph, module, tree)
    findings: List[Finding] = []
    for fn in _propagate(graph):
        findings.extend(_scan_function(fn, root))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
