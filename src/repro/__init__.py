"""repro: iterative-GP linear-system solvers (NeurIPS 2024) at pod scale.

Subpackages:
  core        the paper's contribution (estimators, warm starts, budgets)
  gp          kernel maths, RFF priors, exact baselines
  solvers     CG | AP | SGD on a matrix-free H operator
  kernels     Pallas TPU kernels (fused Matern MVM + VJP)
  models      the 10 assigned LM architectures
  distributed sharding, ring MVM, checkpointing, elastic, compression
  configs     architecture registry (--arch <id>)
  launch      mesh / dryrun / sweep / train / serve entry points
"""
__version__ = "1.0.0"
