"""Prediction serving on top of fitted iterative GPs.

The pathwise estimator makes the solved probe systems *be* posterior samples
(paper eq. 16), so a fitted model can serve posterior mean/variance/samples
with zero lin-solves per request. This package turns that observation into a
serving layer between fitting (`repro.core`) and the CLI (`repro.launch`):

  * :mod:`repro.serve.artifact`   — frozen, checkpointable `ServableGP`
  * :mod:`repro.serve.engine`     — shape-bucketed microbatching engine
  * :mod:`repro.serve.refresh`    — warm-started online model refresh
    (full re-solve or incremental new-row ``mode="block"``)
  * :mod:`repro.serve.multimodel` — several models behind one engine
  * :mod:`repro.serve.cluster`    — multi-process serving: HTTP transport,
    admission control, versioned artifact store, replica supervisor
    (imported explicitly as ``repro.serve.cluster``)
"""
from repro.serve.artifact import (
    ServableGP,
    export_servable,
    load_servable,
    save_servable,
    servable_predict,
)
from repro.serve.engine import BucketedEngine, EngineStats, pad_to_bucket
from repro.serve.multimodel import MultiModelServer
from repro.serve.refresh import (
    AUTO_COUPLING_FACTOR,
    GROWTH_EXACT,
    GROWTH_GEOMETRIC,
    OnlineGP,
    RefreshReport,
    merge_refined_state,
)

__all__ = [
    "ServableGP", "export_servable", "load_servable", "save_servable",
    "servable_predict",
    "BucketedEngine", "EngineStats", "pad_to_bucket",
    "MultiModelServer",
    "AUTO_COUPLING_FACTOR", "GROWTH_EXACT", "GROWTH_GEOMETRIC",
    "OnlineGP", "RefreshReport",
    "merge_refined_state",
]
