"""`ServableGP` — a fitted iterative GP frozen into a serving artifact.

The amortisation contract (paper eq. 16): after a pathwise-estimator fit,
the solver carry ``[v_y | z_hat_1..z_hat_s]`` already contains everything a
posterior needs — the mean weights AND s posterior-sample corrections. The
artifact stores the *pre-concatenated correction matrix*
``[v_y | v_y - z_hat_j]`` (computed once at export), the training inputs,
the fixed RFF base draws and the hyperparameters; a prediction is then one
cross-kernel MVM plus one RFF feature evaluation — zero linear solves,
zero per-request assembly.

Persistence reuses the atomic checkpoint machinery
(`repro.distributed.checkpoint`); the JSON sidecar records shapes and the
static kernel names so `load_servable` can rebuild the restore template
without any Python state from the exporting process.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.outer import OuterState
from repro.core.predict import (
    Predictions,
    correction_matrix,
    pathwise_predict_from_correction,
)
from repro.distributed.checkpoint import (
    load_metadata,
    restore_checkpoint,
    save_checkpoint,
)
from repro.gp.hyperparams import HyperParams
from repro.gp.rff import RFFState


class ServableGP(NamedTuple):
    """Frozen servable model (a pytree; ``kind`` is static aux data).

    Attributes:
      x: (n, d) training inputs.
      correction: (n, 1+s) pre-concatenated ``[v_y | v_y - z_hat_j]``.
      rff: fixed RFF base draws behind the s posterior samples.
      params: hyperparameters at export time.
      kind: effective kernel name (registry key); static so one jitted
        executable exists per (query-shape, kernel) pair.
    """

    x: jax.Array
    correction: jax.Array
    rff: RFFState
    params: HyperParams
    kind: str = "matern32"

    @property
    def n(self) -> int:
        """Training rows frozen into the artifact."""
        return self.x.shape[0]

    @property
    def num_samples(self) -> int:
        """Posterior sample paths s (correction columns minus the mean)."""
        return self.correction.shape[1] - 1


jax.tree_util.register_pytree_node(
    ServableGP,
    lambda m: ((m.x, m.correction, m.rff, m.params), m.kind),
    lambda kind, children: ServableGP(*children, kind=kind),
)


def export_servable(
    state: OuterState, x: jax.Array, kind: Optional[str] = None
) -> ServableGP:
    """Freeze a pathwise-fitted `OuterState` into a `ServableGP`.

    The O(n*s) correction concatenation happens here, once, instead of per
    request inside `pathwise_predict`.
    """
    if state.probes.estimator != "pathwise":
        raise ValueError(
            "export_servable needs a pathwise fit; the standard estimator "
            "has no posterior samples among its solver outputs (run the "
            "s extra pathwise_eval solves first)"
        )
    return ServableGP(
        x=x,
        correction=correction_matrix(state.carry_v),
        rff=state.probes.rff,
        params=state.params,
        kind=kind if kind is not None else state.params.kernel,
    )


def servable_predict(
    model: ServableGP, xq: jax.Array, bm: int = 1024, bn: int = 1024
) -> Predictions:
    """Posterior at ``xq`` from the frozen artifact (jit-friendly).

    Pure function of (pytree, array) — the engine jits exactly this.
    """
    return pathwise_predict_from_correction(
        model.x, xq, model.correction, model.rff, model.params,
        kind=model.kind, bm=bm, bn=bn,
    )


def save_servable(
    ckpt_dir: str, model: ServableGP, step: int = 0, keep: int = 3
) -> str:
    """Atomically persist the artifact; returns the checkpoint path."""
    meta = {
        "artifact": "ServableGP",
        "kind": model.kind,
        "rff_kind": model.rff.kind,
        "kernel": model.params.kernel,
        "n": int(model.x.shape[0]),
        "d": int(model.x.shape[1]),
        "num_samples": int(model.num_samples),
        "num_rff_pairs": int(model.rff.z.shape[0]),
        "dtype": str(model.x.dtype),
    }
    return save_checkpoint(ckpt_dir, step, model, metadata=meta, keep=keep)


def _template_from_meta(meta: dict) -> ServableGP:
    dtype = jnp.dtype(meta["dtype"])
    n, d, s, m = (meta["n"], meta["d"], meta["num_samples"],
                  meta["num_rff_pairs"])
    z = jnp.zeros((m, d), dtype)
    rff = RFFState(
        z=z, u=jnp.zeros((m,), dtype), w=jnp.zeros((2 * m, s), dtype),
        kind=meta["rff_kind"],
    )
    params = HyperParams.create(d, dtype=dtype, kernel=meta["kernel"])
    return ServableGP(
        x=jnp.zeros((n, d), dtype),
        correction=jnp.zeros((n, 1 + s), dtype),
        rff=rff,
        params=params,
        kind=meta["kind"],
    )


def load_servable(ckpt_dir: str, step: Optional[int] = None) -> ServableGP:
    """Restore a `ServableGP` from disk using only the sidecar metadata."""
    meta = load_metadata(ckpt_dir, step)
    if meta.get("artifact") != "ServableGP":
        raise ValueError(
            f"checkpoint under {ckpt_dir} is not a ServableGP artifact "
            f"(metadata: {meta})"
        )
    model, _ = restore_checkpoint(ckpt_dir, _template_from_meta(meta),
                                  step=meta["step"] if step is None else step)
    return model
