"""HTTP front-end for the bucketed serving engine (stdlib only).

Endpoints (JSON in/out):

  * ``POST /predict``  — body ``{"x": [[...], ...], "model": name?,
    "deadline_ms": int?, "priority": "predict|refresh|admin"?,
    "samples": bool?}``; replies ``{"mean": [...], "var": [...], "rows": m,
    "model": name, "version": v, "elapsed_ms": t}`` (+ ``samples``).
    Sheds with ``429`` + ``Retry-After`` when admission refuses, ``504``
    when the request's deadline expired before compute could start.
  * ``GET /healthz``   — liveness + served artifact version (``503`` while
    draining or before a model is loaded).
  * ``GET /stats``     — ``EngineStats.as_dict`` + admission counters +
    per-status HTTP counters (+ an ``OnlineGP.stats_dict`` ``refresh``
    section when the replica refreshes in place); the one stats wire
    format, stamped with ``ts`` + ``schema_version``.
  * ``GET /metrics``   — the process metrics registry in Prometheus text
    exposition format (request/admission/engine/refresh families; see
    ``docs/observability.md``).
  * ``POST /append``   — stream observations into the replica's
    `OnlineGP` (body ``{"x": [[...], ...], "y": [...]}``); the request's
    trace ID is remembered and carried by the `RefreshReport` of the
    refine that absorbs the rows.
  * ``POST /admin/swap`` — fetch a version from the artifact store (body
    ``{"version": v?}``, default LATEST) and atomically swap it in.
  * ``POST /admin/drain`` — stop admitting, report in-flight count (the
    supervisor polls until 0 before stopping the process).

Tracing: every request runs under a trace ID — the inbound ``X-Trace-Id``
header when it passes :func:`repro.obs.trace.sanitize_trace_id`, a fresh ID
otherwise — bound as the handler thread's trace context (admission events
and engine spans pick it up), echoed back as a response header, and stamped
on the per-request ``request`` event in the structured JSONL log.

Deadlines are budgets from request arrival: admission refuses requests
whose estimated queue wait already exceeds the budget, and a request that
aged past its deadline between admission and compute returns ``504``
instead of burning engine time. In-flight requests hold a reference to the
model snapshot they started with, so an ``/admin/swap`` (or poller swap)
never tears a response — the swap is a pointer flip inside the engine.
"""
from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.cluster.admission import (
    AdmissionController,
    Priority,
    parse_priority,
)
from repro.serve.engine import STATS_SCHEMA_VERSION, BucketedEngine
from repro.serve.multimodel import MultiModelServer

DEFAULT_MODEL = "default"

# Known routes: HTTP metric label values. Anything else is labelled
# "other" so scanners probing random paths cannot blow up label
# cardinality in the registry.
ROUTES = ("/predict", "/append", "/healthz", "/stats", "/metrics",
          "/admin/swap", "/admin/drain")


class WireError(Exception):
    """Maps straight to an HTTP status + JSON error body."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


class ServeFrontend:
    """Transport-independent request handling around an engine/registry.

    ``target`` is a `BucketedEngine` (single anonymous model) or a
    `MultiModelServer` (route by the request's ``model`` field).
    ``store_dir`` enables ``/admin/swap`` and version reporting.
    """

    def __init__(
        self,
        target,
        admission: Optional[AdmissionController] = None,
        store_dir: Optional[str] = None,
        version: Optional[str] = None,
        default_model: str = DEFAULT_MODEL,
        refresh_source=None,
        registry: Optional[obs_metrics.MetricsRegistry] = None,
    ):
        self.target = target
        # An OnlineGP (anything with a stats_dict()) feeding this replica:
        # its refresh counters — escalations, coupling residuals, capacity
        # growth — become the "refresh" section of GET /stats, so sequential
        # drivers and operators see WHY a refresh escalated, not just that
        # latency moved.
        self.refresh_source = refresh_source
        self.admission = admission if admission is not None else (
            AdmissionController(
                buckets=getattr(target, "buckets", None)
                or getattr(getattr(target, "engine", None), "buckets", ()),
            )
        )
        self.store_dir = store_dir
        self.version = version
        self.default_model = default_model
        self.draining = False
        self._lock = threading.Lock()
        self.by_status: dict = {}
        # HTTP metrics + the registry GET /metrics renders. None => the
        # process default registry (shared with engine/admission/refresh
        # instruments); pass obs_metrics.NULL_REGISTRY to disable.
        self.registry = (obs_metrics.default_registry() if registry is None
                         else registry)
        self._m_http = self.registry.counter(
            "gp_http_requests_total", "HTTP requests by route and status",
            labelnames=("path", "status"))
        self._m_http_seconds = self.registry.histogram(
            "gp_http_request_seconds", "HTTP request latency by route",
            labelnames=("path",))

    # -- helpers -------------------------------------------------------------
    @property
    def _engine(self) -> BucketedEngine:
        if isinstance(self.target, MultiModelServer):
            return self.target.engine
        return self.target

    def _model_names(self) -> list:
        if isinstance(self.target, MultiModelServer):
            return list(self.target.names())
        try:
            self.target.model
            return [self.default_model]
        except RuntimeError:
            return []

    def _submit(self, name: Optional[str], xq) -> "object":
        if isinstance(self.target, MultiModelServer):
            try:
                model = self.target.get(name or self.default_model)
            except KeyError as e:
                raise WireError(404, str(e)) from None
            self._check_dim(model, xq)
            return self.target.engine.submit(xq, model=model)
        if name is not None and name != self.default_model:
            raise WireError(
                404, f"unknown model {name!r}; this replica serves a single "
                f"anonymous model ({self.default_model!r})"
            )
        try:
            model = self.target.model
        except RuntimeError as e:
            raise WireError(503, str(e)) from None
        self._check_dim(model, xq)
        return self.target.submit(xq, model=model)

    @staticmethod
    def _check_dim(model, xq) -> None:
        d = model.x.shape[1]
        if xq.shape[1] != d:
            raise WireError(
                400, f"'x' has {xq.shape[1]} features, model expects {d}"
            )

    def record_status(self, status: int) -> None:
        """Count one HTTP response by status code (feeds ``GET /stats``)."""
        with self._lock:
            self.by_status[status] = self.by_status.get(status, 0) + 1

    def observe_request(self, path: str, status: int, dur_s: float) -> None:
        """Fold one finished request into the HTTP metric families."""
        route = path if path in ROUTES else "other"
        self._m_http.inc(path=route, status=str(status))
        self._m_http_seconds.observe(dur_s, path=route)

    # -- endpoint bodies -----------------------------------------------------
    def healthz(self) -> tuple[int, dict]:
        """``GET /healthz`` body: 200 when serving, 503 draining/model-less."""
        models = self._model_names()
        if self.draining:
            return 503, {"status": "draining",
                         "inflight": self.admission.inflight}
        if not models:
            return 503, {"status": "no-model"}
        return 200, {"status": "ok", "version": self.version,
                     "models": models}

    def stats(self) -> tuple[int, dict]:
        """``GET /stats`` body: engine + admission + http (+ ``refresh``).

        ``ts`` (epoch seconds) and ``schema_version`` let pollers detect
        stale snapshots and wire-format drift.
        """
        with self._lock:
            by_status = {str(k): v for k, v in sorted(self.by_status.items())}
        body = {
            "ts": time.time(),
            "schema_version": STATS_SCHEMA_VERSION,
            "engine": self._engine.stats_dict(),
            "admission": self.admission.as_dict(),
            "http": {"by_status": by_status},
            "version": self.version,
            "models": self._model_names(),
            "draining": self.draining,
        }
        if self.refresh_source is not None:
            body["refresh"] = self.refresh_source.stats_dict()
        return 200, body

    def metrics(self) -> tuple[int, str, str]:
        """``GET /metrics``: (status, Prometheus text body, content-type)."""
        return 200, self.registry.render(), obs_metrics.CONTENT_TYPE

    def append(self, payload: dict) -> tuple[int, dict]:
        """``POST /append``: stream observations into the replica's OnlineGP.

        The handler's current trace ID is recorded with the rows, so the
        refine that later absorbs them reports which requests triggered it.
        """
        if self.refresh_source is None or not hasattr(
                self.refresh_source, "append"):
            raise WireError(
                400, "this replica has no online refresh source to append to")
        try:
            x_new = np.asarray(payload["x"], dtype=np.float32)
            y_new = np.asarray(payload["y"], dtype=np.float32)
        except KeyError as e:
            raise WireError(400, f"missing required field {e}") from None
        except (TypeError, ValueError) as e:
            raise WireError(400, f"'x'/'y' not numeric arrays: {e}") from None
        if x_new.ndim == 1:
            x_new = x_new[None, :]
        if x_new.ndim != 2 or y_new.ndim != 1 \
                or x_new.shape[0] != y_new.shape[0] or x_new.shape[0] == 0:
            raise WireError(
                400, f"'x' must be (k, d) and 'y' (k,) with k >= 1, got "
                     f"{tuple(x_new.shape)} / {tuple(y_new.shape)}")
        if not (np.all(np.isfinite(x_new)) and np.all(np.isfinite(y_new))):
            raise WireError(400, "'x'/'y' contain non-finite values")
        try:
            self.refresh_source.append(
                x_new, y_new, trace_id=obs_trace.current_trace_id())
        except ValueError as e:
            raise WireError(400, str(e)) from None
        stats = self.refresh_source.stats_dict()
        return 200, {"appended": int(x_new.shape[0]), "n": stats.get("n"),
                     "pending_appends": stats.get("pending_appends")}

    def predict(self, payload: dict, arrival: Optional[float] = None
                ) -> tuple[int, dict, dict]:
        """Returns (status, body, extra_headers)."""
        arrival = time.monotonic() if arrival is None else arrival
        if self.draining:
            raise WireError(503, "draining")
        try:
            xq = np.asarray(payload["x"], dtype=np.float32)
        except KeyError:
            raise WireError(400, "missing required field 'x'") from None
        except (TypeError, ValueError) as e:
            raise WireError(400, f"field 'x' is not a numeric matrix: {e}") \
                from None
        if xq.ndim == 1:
            xq = xq[None, :]
        if xq.ndim != 2 or xq.shape[0] == 0 or xq.shape[1] == 0:
            raise WireError(400, f"'x' must be a non-empty (rows, d) matrix, "
                                 f"got shape {tuple(xq.shape)}")
        if not np.all(np.isfinite(xq)):
            raise WireError(400, "'x' contains non-finite values")
        deadline_ms = payload.get("deadline_ms")
        if deadline_ms is not None and (not isinstance(deadline_ms, (int, float))
                                        or deadline_ms <= 0):
            raise WireError(400, f"'deadline_ms' must be a positive number, "
                                 f"got {deadline_ms!r}")
        priority = Priority.PREDICT
        if "priority" in payload:
            try:
                priority = parse_priority(str(payload["priority"]))
            except ValueError as e:
                raise WireError(400, str(e)) from None

        # Version label snapshot. The label is advisory during a swap
        # window: the poller swaps the model before it bumps
        # ``self.version``, so a request racing the swap may carry the
        # neighbouring label. The prediction itself is never torn (it is
        # computed from one model snapshot); correlate via /healthz when
        # exactness matters.
        version = self.version
        decision = self.admission.admit(
            rows=xq.shape[0], deadline_ms=deadline_ms, priority=priority
        )
        if not decision.admitted:
            retry = max(1, math.ceil(decision.retry_after_s))
            return 429, {
                "error": "overloaded",
                "reason": decision.reason,
                "retry_after_s": decision.retry_after_s,
            }, {"Retry-After": str(retry)}

        with self.admission.track():
            if deadline_ms is not None:
                aged_ms = (time.monotonic() - arrival) * 1e3
                if aged_ms > deadline_ms:
                    raise WireError(
                        504, f"deadline exceeded before compute "
                             f"({aged_ms:.0f}ms > {deadline_ms}ms)"
                    )
            name = payload.get("model")
            pred = self._submit(name, xq)
            mean = np.asarray(pred.mean)
            var = np.asarray(pred.var)
        body = {
            "mean": [float(v) for v in mean],
            "var": [float(v) for v in var],
            "rows": int(xq.shape[0]),
            "model": name or self.default_model,
            "version": version,
            "elapsed_ms": (time.monotonic() - arrival) * 1e3,
        }
        if payload.get("samples"):
            body["samples"] = np.asarray(pred.samples).tolist()
        return 200, body, {}

    def admin_swap(self, payload: dict) -> tuple[int, dict]:
        """``POST /admin/swap``: fetch a store version and hot-swap it in."""
        from repro.serve.cluster.store import fetch_servable

        if self.store_dir is None:
            raise WireError(400, "no artifact store configured on this replica")
        version = payload.get("version")
        try:
            model, version, manifest = fetch_servable(self.store_dir, version)
        except FileNotFoundError as e:
            raise WireError(404, str(e)) from None
        except ValueError as e:  # integrity failure
            raise WireError(409, str(e)) from None
        name = manifest.get("name", self.default_model)
        if isinstance(self.target, MultiModelServer):
            self.target.engine.warmup(model)
            if name in self.target.names():
                self.target.swap(name, model)
            else:
                self.target.register(name, model)
        else:
            self.target.warmup(model)
            self.target.swap_model(model)
        self.version = version
        return 200, {"swapped": True, "version": version, "model": name}

    def admin_drain(self) -> tuple[int, dict]:
        """``POST /admin/drain``: refuse new work, let in-flight finish."""
        self.draining = True
        return 200, {"draining": True, "inflight": self.admission.inflight}


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    frontend: ServeFrontend = None  # set by the server class

    # Silence the default per-request stderr logging (stats cover it).
    def log_message(self, fmt, *args):  # pragma: no cover - logging
        pass

    def _reply(self, status: int, body: dict, headers: Optional[dict] = None):
        data = json.dumps(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        tid = getattr(self, "_trace_id", None)
        if tid is not None:
            self.send_header(obs_trace.TRACE_HEADER, tid)
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)
        self._status = status
        self.frontend.record_status(status)

    def _reply_text(self, status: int, text: str, content_type: str):
        data = text.encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        tid = getattr(self, "_trace_id", None)
        if tid is not None:
            self.send_header(obs_trace.TRACE_HEADER, tid)
        self.end_headers()
        self.wfile.write(data)
        self._status = status
        self.frontend.record_status(status)

    def _read_json(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b""
        if not raw:
            return {}
        try:
            payload = json.loads(raw)
        except json.JSONDecodeError as e:
            raise WireError(400, f"invalid JSON body: {e}") from None
        if not isinstance(payload, dict):
            raise WireError(400, "JSON body must be an object")
        return payload

    def _traced(self, method: str, run) -> None:
        """Run one request under its trace context + request-event logging.

        The trace ID is the sanitised inbound ``X-Trace-Id`` (a fresh one
        when absent/unsafe), bound as the thread's context for the whole
        handler — admission events and engine spans inherit it — echoed on
        the response, and stamped on the structured ``request`` event along
        with route, status and duration.
        """
        t0 = time.perf_counter()
        inbound = obs_trace.sanitize_trace_id(
            self.headers.get(obs_trace.TRACE_HEADER))
        with obs_trace.trace_context(inbound) as tid:
            self._trace_id = tid
            self._status = 500
            try:
                run()
            finally:
                dur = time.perf_counter() - t0
                self.frontend.observe_request(self.path, self._status, dur)
                obs_trace.emit(
                    "request", method=method, path=self.path,
                    status=self._status, dur_ms=dur * 1e3,
                )

    def do_GET(self):
        self._traced("GET", self._do_get)

    def _do_get(self):
        try:
            if self.path == "/metrics":
                status, text, ctype = self.frontend.metrics()
                self._reply_text(status, text, ctype)
                return
            if self.path == "/healthz":
                status, body = self.frontend.healthz()
            elif self.path == "/stats":
                status, body = self.frontend.stats()
            else:
                status, body = 404, {"error": f"no route {self.path}"}
            self._reply(status, body)
        except Exception as e:  # pragma: no cover - defensive
            self._reply(500, {"error": f"{type(e).__name__}: {e}"})

    def do_POST(self):
        self._traced("POST", self._do_post)

    def _do_post(self):
        arrival = time.monotonic()
        try:
            payload = self._read_json()
            if self.path == "/predict":
                status, body, headers = self.frontend.predict(
                    payload, arrival=arrival
                )
                self._reply(status, body, headers)
                return
            if self.path == "/append":
                status, body = self.frontend.append(payload)
            elif self.path == "/admin/swap":
                status, body = self.frontend.admin_swap(payload)
            elif self.path == "/admin/drain":
                status, body = self.frontend.admin_drain()
            else:
                status, body = 404, {"error": f"no route {self.path}"}
            self._reply(status, body)
        except WireError as e:
            self._reply(e.status, {"error": str(e)})
        except Exception as e:
            self._reply(500, {"error": f"{type(e).__name__}: {e}"})


class GPHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to one `ServeFrontend`."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, frontend: ServeFrontend, host: str = "127.0.0.1",
                 port: int = 0):
        handler = type("BoundHandler", (_Handler,), {"frontend": frontend})
        super().__init__((host, port), handler)
        self.frontend = frontend

    @property
    def port(self) -> int:
        """The bound TCP port (resolved even when constructed with port 0)."""
        return self.server_address[1]


def start_http_server(
    frontend: ServeFrontend, host: str = "127.0.0.1", port: int = 0
) -> tuple[GPHTTPServer, threading.Thread]:
    """Bind (port 0 => ephemeral) and serve on a daemon thread."""
    server = GPHTTPServer(frontend, host, port)
    thread = threading.Thread(
        target=server.serve_forever, name="gp-http", daemon=True
    )
    thread.start()
    return server, thread
