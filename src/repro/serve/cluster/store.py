"""Versioned artifact distribution for `ServableGP` models.

Layout (one directory per published version, plus an atomic pointer):

    store/
      v0000001/
        step_0.npz        # checkpoint payload (repro.distributed.checkpoint)
        step_0.json       # checkpoint sidecar (shapes, kernel kind, ...)
        manifest.json     # content hashes + model name + publisher metadata
      v0000002/...
      LATEST              # text file naming the current version

Publish protocol: the version directory is assembled under a hidden temp
name and ``os.rename``d into place, THEN ``LATEST`` is swapped via
write-temp + rename. Readers that follow ``LATEST`` therefore never observe
a half-written version; the manifest's sha256 hashes additionally catch
torn copies when the store lives on a shared/remote filesystem. N replica
processes poll ``LATEST`` (see :class:`ArtifactPoller`) and swap the new
model into their engine — cross-process distribution with no coordination
service beyond a filesystem.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Optional

from repro.distributed.checkpoint import checkpoint_manifest, verify_manifest
from repro.serve.artifact import ServableGP, load_servable, save_servable

LATEST = "LATEST"
MANIFEST = "manifest.json"
_VERSION_FMT = "v{:07d}"


def _version_num(name: str) -> Optional[int]:
    if name.startswith("v") and name[1:].isdigit():
        return int(name[1:])
    return None


def list_versions(store_dir: str) -> list[str]:
    """All published version names, oldest first."""
    if not os.path.isdir(store_dir):
        return []
    names = [n for n in os.listdir(store_dir)
             if _version_num(n) is not None
             and os.path.isdir(os.path.join(store_dir, n))]
    return sorted(names, key=_version_num)


def latest_version(store_dir: str) -> Optional[str]:
    """The version named by the LATEST pointer (None before first publish)."""
    path = os.path.join(store_dir, LATEST)
    try:
        with open(path) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    return name or None


def read_manifest(store_dir: str, version: str) -> dict:
    """Load ``<store>/<version>/manifest.json`` (hashes, files, metadata)."""
    with open(os.path.join(store_dir, version, MANIFEST)) as f:
        return json.load(f)


def publish_servable(
    store_dir: str,
    model: ServableGP,
    name: str = "default",
    extra_metadata: Optional[dict] = None,
) -> str:
    """Publish ``model`` as the next version; returns the version name.

    The write is atomic at two levels: the version directory appears fully
    formed (temp dir + rename), and ``LATEST`` flips in one rename after
    the directory exists. Concurrent publishers are serialised by the
    rename: the loser's temp rename fails and is retried on the next
    version number.
    """
    os.makedirs(store_dir, exist_ok=True)
    versions = list_versions(store_dir)
    next_num = (_version_num(versions[-1]) + 1) if versions else 1
    while True:
        version = _VERSION_FMT.format(next_num)
        final = os.path.join(store_dir, version)
        tmp = os.path.join(store_dir, f".tmp-{version}-{os.getpid()}")
        os.makedirs(tmp)
        save_servable(tmp, model, step=0, keep=1)
        manifest = checkpoint_manifest(tmp, step=0)
        manifest.update({
            "version": version,
            "artifact": "ServableGP",
            "name": name,
            "published_unix": time.time(),
        })
        manifest.update(extra_metadata or {})
        mpath = os.path.join(tmp, MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        try:
            os.rename(tmp, final)
        except OSError:
            # A concurrent publisher claimed this version; retry the next.
            import shutil

            shutil.rmtree(tmp, ignore_errors=True)
            next_num += 1
            continue
        break

    _advance_latest(store_dir, version)
    return version


def _advance_latest(store_dir: str, version: str) -> None:
    """Advance LATEST to the newest published version (>= ``version``).

    Racing publishers flip the pointer in arbitrary order, so flipping to
    one's OWN version could clobber a newer one. Instead every publisher
    loops re-reading the directory listing (version dirs appear atomically
    via rename) and re-flipping until the pointer names the current
    maximum — the unique stable outcome, never a stale pointer.
    """
    while True:
        target = list_versions(store_dir)[-1]  # >= version; dirs are atomic
        if latest_version(store_dir) == target:
            return
        ptr_tmp = os.path.join(store_dir, f".tmp-{LATEST}-{os.getpid()}")
        with open(ptr_tmp, "w") as f:
            f.write(target + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.rename(ptr_tmp, os.path.join(store_dir, LATEST))


def fetch_servable(
    store_dir: str,
    version: Optional[str] = None,
    verify: bool = True,
) -> tuple[ServableGP, str, dict]:
    """Load (model, version, manifest); default: whatever LATEST names.

    ``verify=True`` re-hashes the payload against the manifest before
    deserialising — a corrupt or torn artifact raises instead of serving
    garbage predictions.
    """
    if version is None:
        version = latest_version(store_dir)
        if version is None:
            raise FileNotFoundError(f"no published versions under {store_dir}")
    vdir = os.path.join(store_dir, version)
    manifest = read_manifest(store_dir, version)
    if verify:
        verify_manifest(vdir, manifest)
    model = load_servable(vdir, step=manifest.get("step", 0))
    return model, version, manifest


class ArtifactPoller:
    """Poll LATEST and swap new versions into an engine (one per replica).

    ``target`` is a `BucketedEngine` (swap via ``swap_model``) or a
    `MultiModelServer` (swap/register by the manifest's model ``name``).
    A failed fetch (torn copy, transient FS error) leaves the currently
    served version untouched and is retried on the next tick.
    """

    def __init__(
        self,
        store_dir: str,
        target,
        interval_s: float = 2.0,
        warmup: bool = True,
        on_swap: Optional[Callable[[str, dict], None]] = None,
    ):
        self.store_dir = store_dir
        self.target = target
        self.interval_s = float(interval_s)
        self.warmup = warmup
        self.on_swap = on_swap
        # Poll state is written by the daemon thread and read by the
        # replica main thread (/stats, startup error reporting) — all
        # access goes through self._lock; external readers use status().
        self._lock = threading.Lock()
        self.version: Optional[str] = None  #: guarded by self._lock
        self.last_error: Optional[str] = None  #: guarded by self._lock
        self.swaps = 0  #: guarded by self._lock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _swap_into_target(self, model: ServableGP, name: str) -> None:
        from repro.serve.multimodel import MultiModelServer

        if isinstance(self.target, MultiModelServer):
            if self.warmup:
                self.target.engine.warmup(model)
            if name in self.target.names():
                self.target.swap(name, model)
            else:
                self.target.register(name, model)
        else:
            if self.warmup:
                self.target.warmup(model)
            self.target.swap_model(model)

    def status(self) -> dict:
        """Consistent snapshot of the poll state (thread-safe)."""
        with self._lock:
            return {"version": self.version, "swaps": self.swaps,
                    "last_error": self.last_error}

    def poll_once(self) -> bool:
        """Check LATEST; fetch + swap if it moved. Returns True on a swap.

        The fetch + warmup + swap runs outside the lock (it does file IO
        and possibly a compile); only the published poll state is guarded.
        Called from the daemon thread and, for the initial fetch, from the
        replica main thread before the thread starts — never concurrently
        with itself.
        """
        try:
            version = latest_version(self.store_dir)
            with self._lock:
                current = self.version
            if version is None or version == current:
                return False
            model, version, manifest = fetch_servable(self.store_dir, version)
            self._swap_into_target(model, manifest.get("name", "default"))
            with self._lock:
                self.version = version
                self.swaps += 1
                self.last_error = None
            if self.on_swap is not None:
                self.on_swap(version, manifest)
            return True
        except Exception as e:  # keep serving the old version
            with self._lock:
                self.last_error = f"{type(e).__name__}: {e}"
            return False

    def start(self) -> None:
        """Begin polling LATEST on a daemon thread (no-op if running)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.interval_s):
                self.poll_once()

        self._thread = threading.Thread(
            target=_loop, name="artifact-poller", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Stop the polling thread (joins with a timeout; idempotent)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
