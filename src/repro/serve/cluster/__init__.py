"""Multi-process cluster serving on top of the bucketed engine.

The step from "a library you can call" to "a service you can run":

  * :mod:`repro.serve.cluster.transport` — stdlib HTTP front-end
    (``/predict``, ``/healthz``, ``/stats``, ``/admin/swap``) with a JSON
    wire format and per-request deadlines;
  * :mod:`repro.serve.cluster.admission` — per-bucket token buckets,
    bounded concurrency, deadline-aware load shedding (429 + Retry-After)
    and priority classes;
  * :mod:`repro.serve.cluster.store` — versioned artifact distribution
    with content-hash manifests and an atomic ``LATEST`` pointer;
  * :mod:`repro.serve.cluster.replica` — worker processes + a supervisor
    that spawns, monitors and drains them;
  * :mod:`repro.serve.cluster.monitor` — the fleet monitor: scrapes every
    replica's ``/metrics`` + ``/stats``, evaluates SLO burn rates, and
    serves the aggregated ``/fleet/*`` endpoints the autoscaler consumes.
"""
from repro.serve.cluster.admission import (
    AdmissionController,
    AdmissionStats,
    Decision,
    Priority,
    TokenBucket,
    parse_priority,
)
from repro.serve.cluster.monitor import (
    FleetMonitor,
    MonitorHTTPServer,
    start_monitor_server,
)
from repro.serve.cluster.replica import ReplicaSupervisor, run_worker
from repro.serve.cluster.store import (
    ArtifactPoller,
    fetch_servable,
    latest_version,
    list_versions,
    publish_servable,
    read_manifest,
)
from repro.serve.cluster.transport import (
    GPHTTPServer,
    ServeFrontend,
    WireError,
    start_http_server,
)

__all__ = [
    "AdmissionController", "AdmissionStats", "Decision", "Priority",
    "TokenBucket", "parse_priority",
    "FleetMonitor", "MonitorHTTPServer", "start_monitor_server",
    "ReplicaSupervisor", "run_worker",
    "ArtifactPoller", "fetch_servable", "latest_version", "list_versions",
    "publish_servable", "read_manifest",
    "GPHTTPServer", "ServeFrontend", "WireError", "start_http_server",
]
