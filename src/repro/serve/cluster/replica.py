"""Replica workers + supervisor: N processes serving one artifact store.

A *worker* is a fresh process (``multiprocessing`` spawn context, so jax
state is never forked mid-flight) that:

  1. polls the artifact store until a first version is published,
  2. builds a `MultiModelServer` (+ admission controller) and warms every
     bucket executable for the fetched model,
  3. binds the HTTP front-end (port 0 => ephemeral) and writes the chosen
     port to a ``replica_<i>.port`` file (write-temp + rename, so the
     supervisor never reads a half-written port),
  4. keeps polling ``LATEST`` and atomically swaps new versions in while
     serving (in-flight requests finish on the model snapshot they
     started with).

The *supervisor* spawns the workers, waits for them to report healthy,
restarts any that die, and on ``stop()`` drains them (POST /admin/drain,
then wait for in-flight to hit zero) before terminating — a swap or a
shutdown never drops an admitted request.
"""
from __future__ import annotations

import json
import multiprocessing
import os
import signal
import threading
import time
import urllib.error
import urllib.request
from typing import Optional

DEFAULT_BUCKETS = (16, 64, 256)


def _http_json(
    url: str,
    payload: Optional[dict] = None,
    timeout: float = 10.0,
) -> tuple[int, dict]:
    """Tiny stdlib HTTP client; returns (status, parsed body)."""
    data = None if payload is None else json.dumps(payload).encode()
    req = urllib.request.Request(
        url, data=data,
        headers={"Content-Type": "application/json"} if data else {},
        method="POST" if data is not None else "GET",
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read() or b"{}")
    except urllib.error.HTTPError as e:
        try:
            body = json.loads(e.read() or b"{}")
        except json.JSONDecodeError:
            body = {}
        return e.code, body


def run_worker(cfg: dict) -> None:
    """Worker process entry point; ``cfg`` is a plain dict of primitives
    (spawn-pickle friendly). Blocks until SIGTERM/SIGINT, then drains."""
    # Imports happen here, inside the spawned process.
    from repro.obs import trace as obs_trace
    from repro.serve.cluster.admission import AdmissionController
    from repro.serve.cluster.store import ArtifactPoller, latest_version
    from repro.serve.cluster.transport import ServeFrontend, start_http_server
    from repro.serve.multimodel import MultiModelServer

    # Structured request log: one JSONL file per replica process, so the
    # per-request / admission / engine events of concurrent replicas never
    # interleave mid-line. Configured before the front-end exists so even
    # warmup-era events land in the file.
    request_log = cfg.get("request_log")
    if request_log:
        obs_trace.configure(path=request_log)

    buckets = tuple(cfg.get("buckets", DEFAULT_BUCKETS))
    server = MultiModelServer(
        buckets=buckets, bm=cfg.get("bm", 1024), bn=cfg.get("bn", 1024)
    )
    admission = AdmissionController(
        buckets=buckets,
        rate_qps=cfg.get("rate_qps"),
        burst=cfg.get("burst"),
        max_inflight=cfg.get("max_inflight", 64),
        default_deadline_ms=cfg.get("default_deadline_ms"),
    )
    frontend = ServeFrontend(
        server, admission, store_dir=cfg["store_dir"],
        default_model=cfg.get("default_model", "default"),
    )
    poller = ArtifactPoller(
        cfg["store_dir"], server,
        interval_s=cfg.get("poll_interval_s", 0.5),
        on_swap=lambda version, manifest: setattr(frontend, "version", version),
    )

    # Wait for the first published version (the supervisor may start us
    # before the publisher finishes).
    deadline = time.monotonic() + cfg.get("wait_for_artifact_s", 120.0)
    while latest_version(cfg["store_dir"]) is None:
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"no artifact published under {cfg['store_dir']}"
            )
        time.sleep(0.2)
    if not poller.poll_once():
        raise RuntimeError(
            f"initial artifact fetch failed: {poller.status()['last_error']}"
        )

    httpd, _ = start_http_server(
        frontend, host=cfg.get("host", "127.0.0.1"), port=cfg.get("port", 0)
    )
    port_file = cfg.get("port_file")
    if port_file:
        tmp = port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{httpd.port}\n")
        os.rename(tmp, port_file)

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    poller.start()
    stop.wait()

    # Drain: refuse new work, let in-flight requests finish, then exit.
    frontend.draining = True
    drain_deadline = time.monotonic() + cfg.get("drain_timeout_s", 10.0)
    while admission.inflight > 0 and time.monotonic() < drain_deadline:
        time.sleep(0.05)
    poller.stop()
    httpd.shutdown()


class ReplicaSupervisor:
    """Spawn, monitor and drain N HTTP replica workers over one store."""

    def __init__(
        self,
        store_dir: str,
        num_replicas: int = 2,
        host: str = "127.0.0.1",
        base_port: int = 0,
        run_dir: Optional[str] = None,
        request_log_dir: Optional[str] = None,
        **worker_kwargs,
    ):
        if num_replicas < 1:
            raise ValueError("need at least one replica")
        self.store_dir = store_dir
        self.num_replicas = int(num_replicas)
        self.host = host
        self.base_port = int(base_port)  # 0 => ephemeral; else port+i per replica
        self.run_dir = run_dir if run_dir is not None else os.path.join(
            store_dir, ".run"
        )
        self.request_log_dir = request_log_dir
        self.worker_kwargs = worker_kwargs
        self._ctx = multiprocessing.get_context("spawn")
        self._procs: list = [None] * self.num_replicas
        self.ports: list = [None] * self.num_replicas
        self.restarts = 0

    # -- lifecycle -----------------------------------------------------------
    def _port_file(self, i: int) -> str:
        return os.path.join(self.run_dir, f"replica_{i}.port")

    def _spawn(self, i: int) -> None:
        pf = self._port_file(i)
        if os.path.exists(pf):
            os.remove(pf)
        cfg = {
            "store_dir": self.store_dir,
            "host": self.host,
            "port": (self.base_port + i) if self.base_port else 0,
            "port_file": pf,
            **self.worker_kwargs,
        }
        if self.request_log_dir:
            cfg["request_log"] = os.path.join(
                self.request_log_dir, f"replica_{i}.jsonl"
            )
        proc = self._ctx.Process(
            target=run_worker, args=(cfg,), name=f"gp-replica-{i}", daemon=True
        )
        proc.start()
        self._procs[i] = proc
        self.ports[i] = None

    def start(self, timeout_s: float = 180.0) -> list:
        """Spawn all replicas, wait until each reports healthy over HTTP.

        Returns the list of endpoint URLs. Raises on timeout or if a
        worker dies during startup (its exitcode is in the message).
        """
        os.makedirs(self.run_dir, exist_ok=True)
        for i in range(self.num_replicas):
            self._spawn(i)
        deadline = time.monotonic() + timeout_s
        pending = set(range(self.num_replicas))
        while pending:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"replicas {sorted(pending)} not healthy after "
                    f"{timeout_s:.0f}s"
                )
            for i in sorted(pending):
                proc = self._procs[i]
                if not proc.is_alive():
                    raise RuntimeError(
                        f"replica {i} died during startup "
                        f"(exitcode={proc.exitcode})"
                    )
                if self.ports[i] is None:
                    try:
                        with open(self._port_file(i)) as f:
                            self.ports[i] = int(f.read().strip())
                    except (FileNotFoundError, ValueError):
                        continue
                try:
                    status, _ = _http_json(
                        self.endpoint(i) + "/healthz", timeout=2.0
                    )
                except OSError:
                    continue
                if status == 200:
                    pending.discard(i)
            if pending:
                time.sleep(0.2)
        return self.endpoints()

    def endpoint(self, i: int) -> str:
        """Base URL of replica ``i`` (RuntimeError before it reports a port)."""
        if self.ports[i] is None:
            raise RuntimeError(f"replica {i} has not reported a port yet")
        return f"http://{self.host}:{self.ports[i]}"

    def endpoints(self) -> list:
        """Base URLs of all replicas, in index order."""
        return [self.endpoint(i) for i in range(self.num_replicas)]

    def targets(self) -> dict:
        """Scrape-target map ``{replica_name: base_url}`` for the monitor.

        Every replica with a known port is listed — including dead ones,
        deliberately: a crashed replica stays a fleet member until the
        supervisor decides otherwise, and keeping its target is what lets
        the scraper observe the miss and flip ``gp_fleet_replica_up`` to 0
        instead of silently shrinking the fleet.
        """
        out = {}
        for i in range(self.num_replicas):
            if self.ports[i] is None:
                # A respawned worker reports its port via the port file;
                # pick it up opportunistically so the target set heals.
                try:
                    with open(self._port_file(i)) as f:
                        self.ports[i] = int(f.read().strip())
                except (FileNotFoundError, ValueError):
                    continue
            out[f"replica_{i}"] = f"http://{self.host}:{self.ports[i]}"
        return out

    def kill(self, i: int) -> None:
        """Hard-kill replica ``i`` without draining or respawning (chaos
        hook for staleness/alerting tests — :meth:`check` still respawns
        it if called afterwards)."""
        proc = self._procs[i]
        if proc is not None and proc.is_alive():
            proc.kill()
            proc.join(timeout=10.0)

    def check(self) -> int:
        """Respawn any dead replica; returns how many were restarted."""
        restarted = 0
        for i, proc in enumerate(self._procs):
            if proc is not None and not proc.is_alive():
                self._spawn(i)
                restarted += 1
        self.restarts += restarted
        return restarted

    def stop(self, drain: bool = True, timeout_s: float = 15.0) -> None:
        """Drain (refuse new work, finish in-flight) then stop every worker."""
        if drain:
            for i in range(self.num_replicas):
                if self.ports[i] is None or not self._procs[i].is_alive():
                    continue
                try:
                    _http_json(self.endpoint(i) + "/admin/drain",
                               payload={}, timeout=2.0)
                except OSError:
                    pass
        for proc in self._procs:
            if proc is not None and proc.is_alive():
                proc.terminate()
        deadline = time.monotonic() + timeout_s
        for proc in self._procs:
            if proc is None:
                continue
            proc.join(timeout=max(0.1, deadline - time.monotonic()))
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
