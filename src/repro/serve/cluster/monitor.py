"""Fleet monitor: one process watching N replicas (stdlib HTTP).

Composes the two halves of the fleet observability plane — the
:class:`repro.obs.scrape.FleetScraper` (sensing) and the
:class:`repro.obs.slo.SLOEngine` (deciding) — behind three read-only HTTP
endpoints:

  * ``GET /fleet/metrics`` — the aggregated Prometheus exposition: every
    scraped family re-labelled per replica, the scraper's ``gp_fleet_*``
    meta families, and the SLO engine's ``gp_slo_*`` gauges, in one body;
  * ``GET /fleet/slo``     — JSON burn/alert state per SLO (the same dict
    the evaluator produced on the last tick);
  * ``GET /fleet/health``  — per-replica up/EWMA/shed-rate/queue-depth —
    the sensing contract a load balancer or autoscaler consumes (see
    ``docs/fleet.md`` for the field-by-field schema);
  * ``GET /healthz``       — the monitor's own liveness.

The monitor ticks on an interval: refresh targets (from a live
:class:`repro.serve.cluster.replica.ReplicaSupervisor` when embedded, or a
static target map when standalone), scrape every replica, evaluate the
SLOs. Alert transitions stream as ``slo_alert`` JSONL events through the
observability event log. Embed it via :func:`repro.launch.serve`'s
``--monitor HOST:PORT`` flag or run it standalone::

    python -m repro.serve.cluster.monitor --targets \\
        replica_0=http://127.0.0.1:8101,replica_1=http://127.0.0.1:8102
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional

from repro.obs import trace as obs_trace
from repro.obs.metrics import CONTENT_TYPE
from repro.obs.scrape import FleetScraper
from repro.obs.slo import SLO, AvailabilitySLO, LatencySLO, SLOEngine


def default_slos(fast_window_s: float = 300.0,
                 slow_window_s: float = 3600.0) -> List[SLO]:
    """The stock SLO set: 99% availability + 95% of predicts under 250ms."""
    from repro.obs.slo import default_rules

    rules = default_rules(fast_window_s, slow_window_s)
    return [
        AvailabilitySLO(objective=0.99, rules=list(rules)),
        LatencySLO(objective=0.95, threshold_s=0.25, rules=list(rules)),
    ]


class FleetMonitor:
    """Scrape + evaluate + serve: the whole monitor in one object.

    Args:
      targets: initial ``{replica_name: base_url}`` scrape map.
      supervisor: optional live :class:`ReplicaSupervisor`; when given, each
        tick refreshes the target set from ``supervisor.targets()`` so
        spawns/exits change what is scraped without restarts.
      interval_s: tick period (scrape round + SLO evaluation).
      slos: SLO set (default: :func:`default_slos` over windows derived
        from ``interval_s`` when small, else the stock 5min/1h pair).
      event_log: alert sink; None falls back to the process-wide log.
      scraper_kwargs: forwarded to :class:`FleetScraper` (``ttl_s``,
        ``stale_after_misses``, injectable ``clock``/``fetch`` in tests).
    """

    def __init__(
        self,
        targets: Optional[Dict[str, str]] = None,
        supervisor=None,
        interval_s: float = 1.0,
        slos: Optional[List[SLO]] = None,
        event_log: Optional[obs_trace.EventLog] = None,
        **scraper_kwargs,
    ):
        self.interval_s = float(interval_s)
        self.supervisor = supervisor
        self.scraper = FleetScraper(
            targets=targets, interval_s=interval_s, **scraper_kwargs)
        if slos is None:
            slos = default_slos()
        log = event_log if event_log is not None \
            else obs_trace.get_event_log()
        self.slo_engine = SLOEngine(
            self.scraper, slos, event_log=log,
            clock=scraper_kwargs.get("clock", time.monotonic))
        self._slo_status: Dict[str, dict] = {}  #: guarded by self._status_lock
        self._status_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.ticks = 0  #: guarded by self._status_lock

    # -- the tick -------------------------------------------------------------
    def tick(self) -> Dict[str, dict]:
        """One monitor cycle: refresh targets, scrape, evaluate SLOs.

        Synchronous and injectable-clock friendly — tests drive it
        directly; production runs it on the :meth:`start` thread.
        """
        if self.supervisor is not None:
            self.scraper.set_targets(self.supervisor.targets())
        self.scraper.scrape_once()
        status = self.slo_engine.evaluate()
        with self._status_lock:
            self._slo_status = status
            self.ticks += 1
        return status

    def start(self) -> None:
        """Tick every ``interval_s`` on a daemon thread (idempotent)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.interval_s):
                try:
                    self.tick()
                except Exception:  # a failed tick must not kill the loop
                    pass

        self._thread = threading.Thread(
            target=_loop, name="fleet-monitor", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the tick thread."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=self.interval_s + 30.0)
        self._thread = None

    def tick_count(self) -> int:
        """Completed monitor cycles (thread-safe read for handlers)."""
        with self._status_lock:
            return self.ticks

    # -- endpoint payloads ----------------------------------------------------
    def fleet_metrics(self) -> str:
        """``/fleet/metrics`` body: scraper aggregate + ``gp_slo_*`` gauges."""
        return self.scraper.render() + self.slo_engine.registry.render()

    def fleet_slo(self) -> dict:
        """``/fleet/slo`` body: last tick's per-SLO burn/alert state."""
        with self._status_lock:
            status = dict(self._slo_status)
            ticks = self.ticks
        return {
            "ts": time.time(),
            "ticks": ticks,
            "worst_state": self.slo_engine.worst_state(),
            "slos": status,
        }

    def fleet_health(self) -> dict:
        """``/fleet/health`` body: the autoscaler's sensing contract."""
        health = self.scraper.health()
        up = sum(1 for h in health.values() if h["up"])
        return {
            "ts": time.time(),
            "replicas": health,
            "num_replicas": len(health),
            "num_up": up,
            "up_fraction": self.scraper.up_fraction(),
            "worst_slo_state": self.slo_engine.worst_state(),
        }


class _MonitorHandler(BaseHTTPRequestHandler):
    """Read-only JSON/text routes over one :class:`FleetMonitor`."""

    protocol_version = "HTTP/1.1"
    monitor: FleetMonitor = None  # set by the server class

    def log_message(self, fmt, *args):  # pragma: no cover - logging
        pass

    def _send(self, status: int, data: bytes, ctype: str) -> None:
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):
        try:
            if self.path == "/fleet/metrics":
                body = self.monitor.fleet_metrics().encode("utf-8")
                self._send(200, body, CONTENT_TYPE)
                return
            if self.path == "/fleet/slo":
                payload = self.monitor.fleet_slo()
            elif self.path == "/fleet/health":
                payload = self.monitor.fleet_health()
            elif self.path == "/healthz":
                payload = {"ok": True, "ticks": self.monitor.tick_count()}
            else:
                self._send(404, json.dumps(
                    {"error": f"no route {self.path}"}).encode(),
                    "application/json")
                return
            self._send(200, json.dumps(payload).encode(),
                       "application/json")
        except Exception as e:  # pragma: no cover - defensive
            self._send(500, json.dumps(
                {"error": f"{type(e).__name__}: {e}"}).encode(),
                "application/json")


class MonitorHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to one :class:`FleetMonitor`."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, monitor: FleetMonitor, host: str = "127.0.0.1",
                 port: int = 0):
        handler = type("BoundMonitorHandler", (_MonitorHandler,),
                       {"monitor": monitor})
        super().__init__((host, port), handler)
        self.monitor = monitor

    @property
    def port(self) -> int:
        """The bound TCP port (resolved even when constructed with port 0)."""
        return self.server_address[1]


def start_monitor_server(
    monitor: FleetMonitor, host: str = "127.0.0.1", port: int = 0,
) -> tuple:
    """Serve the monitor on a daemon thread; returns (server, thread).

    Also starts the monitor's tick loop. Callers own shutdown:
    ``server.shutdown(); monitor.stop()``.
    """
    server = MonitorHTTPServer(monitor, host=host, port=port)
    thread = threading.Thread(
        target=server.serve_forever, name="gp-fleet-monitor-http",
        daemon=True)
    thread.start()
    monitor.start()
    return server, thread


def parse_targets(spec: str) -> Dict[str, str]:
    """Parse ``name=url,name=url`` (CLI) into a target map."""
    out: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"target {part!r} is not name=url")
        name, url = part.split("=", 1)
        out[name.strip()] = url.strip().rstrip("/")
    if not out:
        raise ValueError("no targets parsed")
    return out


def main(argv=None) -> int:
    """Standalone monitor CLI (static target set)."""
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--targets", required=True,
                    help="comma-separated name=url scrape targets")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--interval", type=float, default=1.0,
                    help="scrape/evaluate tick period (s)")
    ap.add_argument("--alert-log", default=None,
                    help="JSONL file for slo_alert events")
    ap.add_argument("--fast-window", type=float, default=300.0)
    ap.add_argument("--slow-window", type=float, default=3600.0)
    args = ap.parse_args(argv)

    log = obs_trace.configure(path=args.alert_log) if args.alert_log else None
    monitor = FleetMonitor(
        targets=parse_targets(args.targets),
        interval_s=args.interval,
        slos=default_slos(args.fast_window, args.slow_window),
        event_log=log,
    )
    server, _ = start_monitor_server(monitor, host=args.host, port=args.port)
    print(f"[monitor] serving /fleet/* on http://{args.host}:{server.port} "
          f"({len(monitor.scraper.targets())} targets, "
          f"interval {args.interval}s)")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        monitor.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
