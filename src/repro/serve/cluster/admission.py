"""Deadline-aware admission control for the serving front-end.

Overload policy (the transport maps every rejection to ``429`` with a
``Retry-After`` header):

  * **per-bucket token buckets** — each engine row bucket gets its own
    refill rate, so one class of large queries cannot exhaust the budget
    of the cheap ones (the engine pads to the bucket anyway, so the bucket
    IS the cost class);
  * **bounded concurrency** — at most ``max_inflight`` requests may be
    inside compute at once; beyond that the request would only queue, so
    it is shed instead of parked;
  * **deadline-aware shedding** — a request whose deadline cannot be met
    given the current queue (estimated wait = inflight x EWMA service
    time) is rejected *immediately*: failing fast at admission is cheaper
    for everyone than timing out after burning a slot;
  * **priority classes** — refresh/admin traffic (model swaps, drains,
    health checks) bypasses the rate limiter and the inflight cap, so
    operational work is never starved by a prediction flood.

Everything is stdlib + a single lock; the clock is injectable so tests are
deterministic.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from enum import IntEnum
from typing import Dict, Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class Priority(IntEnum):
    """Higher value = more important; ADMIN/REFRESH are never shed."""

    PREDICT = 0
    REFRESH = 1
    ADMIN = 2


def parse_priority(name: str) -> Priority:
    """Case-insensitive wire-string -> :class:`Priority` (ValueError lists options)."""
    try:
        return Priority[name.upper()]
    except KeyError:
        raise ValueError(
            f"unknown priority {name!r}; options: "
            f"{[p.name.lower() for p in Priority]}"
        ) from None


@dataclass
class Decision:
    """Admission verdict; ``retry_after_s`` is meaningful when shed."""

    admitted: bool
    reason: str = "ok"  # ok | rate | inflight | deadline | bypass
    retry_after_s: float = 0.0


# Closed label vocabulary for the decisions counter: a new shed reason
# cannot silently mint a new metric series without touching this table.
_SHED_LABELS = {
    "rate": "shed_rate",
    "inflight": "shed_inflight",
    "deadline": "shed_deadline",
}


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` capacity.

    ``try_acquire`` never blocks; on refusal it reports how long until the
    requested tokens would be available (the Retry-After hint).
    """

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be positive, got "
                             f"rate={rate} burst={burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t = None  # lazily pinned to the first observed clock

    def _refill(self, now: float) -> None:
        if self._t is None:
            self._t = now
        self._tokens = min(self.burst, self._tokens + (now - self._t) * self.rate)
        self._t = now

    def try_acquire(self, tokens: float = 1.0,
                    now: Optional[float] = None) -> tuple[bool, float]:
        """Returns (acquired, retry_after_s)."""
        now = time.monotonic() if now is None else now
        self._refill(now)
        if self._tokens >= tokens:
            self._tokens -= tokens
            return True, 0.0
        return False, (tokens - self._tokens) / self.rate

    def available(self, now: Optional[float] = None) -> float:
        """Current token fill after refill (the explainability export)."""
        now = time.monotonic() if now is None else now
        self._refill(now)
        return self._tokens


@dataclass
class AdmissionStats:
    """Cumulative admission counters (all monotone; lock held by caller)."""

    admitted: int = 0
    shed_rate: int = 0
    shed_inflight: int = 0
    shed_deadline: int = 0
    bypassed: int = 0  # REFRESH/ADMIN admissions that skipped the limits

    def as_dict(self) -> dict:
        """Counters as a JSON-ready dict (adds the aggregate ``shed``)."""
        shed = self.shed_rate + self.shed_inflight + self.shed_deadline
        return {
            "admitted": self.admitted,
            "bypassed": self.bypassed,
            "shed": shed,
            "shed_rate": self.shed_rate,
            "shed_inflight": self.shed_inflight,
            "shed_deadline": self.shed_deadline,
        }


class AdmissionController:
    """Gate in front of the engine; one instance per serving process.

    Args:
      buckets: engine row buckets (each gets its own token bucket).
      rate_qps: sustained admitted requests/s per bucket class (None
        disables rate limiting — the inflight cap still applies).
      burst: token-bucket capacity (defaults to ``2 * rate_qps``).
      max_inflight: concurrent in-compute requests before load shedding.
      default_deadline_ms: applied when a request carries no deadline;
        None disables deadline shedding for deadline-less requests.
    """

    def __init__(
        self,
        buckets: Sequence[int] = (),
        rate_qps: Optional[float] = None,
        burst: Optional[float] = None,
        max_inflight: int = 64,
        default_deadline_ms: Optional[float] = None,
        service_ewma_alpha: float = 0.2,
        registry: Optional[obs_metrics.MetricsRegistry] = None,
    ):
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        self.max_inflight = int(max_inflight)
        self.default_deadline_ms = default_deadline_ms
        self._alpha = float(service_ewma_alpha)
        self._limiters: Dict[int, TokenBucket] = {}
        if rate_qps is not None:
            b = burst if burst is not None else 2.0 * rate_qps
            keys = self.buckets if self.buckets else (0,)
            self._limiters = {k: TokenBucket(rate_qps, b) for k in keys}
        self._lock = threading.Lock()
        self._inflight = 0  #: guarded by self._lock
        self._service_ewma_s = 0.0  #: guarded by self._lock
        self.stats = AdmissionStats()  #: guarded by self._lock
        # Observability: None => process default registry; pass
        # obs_metrics.NULL_REGISTRY to disable. Every admit() outcome becomes
        # a labelled counter tick and a structured "admission" event carrying
        # the caller's current trace ID (no-op when no event log is active).
        reg = obs_metrics.default_registry() if registry is None else registry
        self._m_decisions = reg.counter(
            "gp_admission_decisions_total", "Admission outcomes",
            labelnames=("outcome",))
        self._m_inflight = reg.gauge(
            "gp_admission_inflight", "Requests between admit and release")
        self._m_ewma = reg.gauge(
            "gp_admission_service_ewma_seconds",
            "EWMA per-request service time driving deadline shedding")
        self._m_tokens = reg.gauge(
            "gp_admission_bucket_tokens", "Token-bucket fill per row bucket",
            labelnames=("bucket",))

    # -- helpers -------------------------------------------------------------
    def _bucket_for(self, rows: int) -> int:
        for b in self.buckets:
            if rows <= b:
                return b
        return self.buckets[-1] if self.buckets else 0

    @property
    def inflight(self) -> int:
        """Requests currently between :meth:`admit` and :meth:`release`."""
        with self._lock:
            return self._inflight

    @property
    def service_ewma_s(self) -> float:
        """EWMA of per-request compute time (seconds); drives deadline shedding."""
        with self._lock:
            return self._service_ewma_s

    # -- the gate ------------------------------------------------------------
    def admit(
        self,
        rows: int = 1,
        deadline_ms: Optional[float] = None,
        priority: Priority = Priority.PREDICT,
        now: Optional[float] = None,
    ) -> Decision:
        """Admit or shed one request of ``rows`` query rows.

        Admitted requests MUST be paired with :meth:`release` (use
        :meth:`track` for the with-statement form) or the inflight gauge
        leaks and eventually sheds everything.
        """
        now = time.monotonic() if now is None else now
        with self._lock:
            decision = self._admit_locked(rows, deadline_ms, priority, now)
            inflight = self._inflight
        # Instrumentation outside the admission lock (the event log does
        # file IO): one labelled counter tick + one structured event that
        # carries the handler thread's current trace ID.
        if decision.reason == "bypass":
            outcome = "bypass"
        elif decision.admitted:
            outcome = "admitted"
        else:
            outcome = _SHED_LABELS.get(decision.reason, "shed_other")
        self._m_decisions.inc(outcome=outcome)
        self._m_inflight.set(inflight)
        obs_trace.emit(
            "admission", outcome=outcome, rows=rows,
            priority=priority.name.lower(),
            retry_after_s=decision.retry_after_s, inflight=inflight,
        )
        return decision

    def _admit_locked(
        self, rows: int, deadline_ms: Optional[float], priority: Priority,
        now: float,
    ) -> Decision:
        """The admission decision proper; caller holds ``self._lock``."""
        if priority >= Priority.REFRESH:
            self._inflight += 1
            self.stats.bypassed += 1
            self.stats.admitted += 1
            return Decision(True, "bypass")

        # Cheap checks first; the token is only spent on requests that
        # every other gate would admit (an inflight- or deadline-shed
        # request must not burn rate budget).
        if self._inflight >= self.max_inflight:
            self.stats.shed_inflight += 1
            # Everything queued ahead must drain first.
            retry = max(0.001, self._inflight * self._service_ewma_s)
            return Decision(False, "inflight", retry_after_s=retry)

        dl = deadline_ms if deadline_ms is not None else self.default_deadline_ms
        if dl is not None:
            est_wait_s = self._inflight * self._service_ewma_s
            if est_wait_s * 1e3 > dl:
                self.stats.shed_deadline += 1
                return Decision(False, "deadline",
                                retry_after_s=max(0.001, est_wait_s))

        bucket = self._bucket_for(rows)
        limiter = self._limiters.get(bucket)
        if limiter is not None:
            ok, retry = limiter.try_acquire(1.0, now=now)
            self._m_tokens.set(limiter._tokens, bucket=str(bucket))
            if not ok:
                self.stats.shed_rate += 1
                return Decision(False, "rate", retry_after_s=retry)

        self._inflight += 1
        self.stats.admitted += 1
        return Decision(True, "ok")

    def release(self, service_s: Optional[float] = None) -> None:
        """Return an admitted request's inflight slot; ``service_s`` feeds the EWMA."""
        with self._lock:
            self._inflight = max(0, self._inflight - 1)
            if service_s is not None:
                if self._service_ewma_s == 0.0:
                    self._service_ewma_s = float(service_s)
                else:
                    self._service_ewma_s += self._alpha * (
                        float(service_s) - self._service_ewma_s
                    )
            inflight, ewma = self._inflight, self._service_ewma_s
        self._m_inflight.set(inflight)
        self._m_ewma.set(ewma)

    class _Tracker:
        def __init__(self, ctrl: "AdmissionController"):
            self._ctrl = ctrl
            self._t0 = time.monotonic()

        def __enter__(self):
            return self

        def __exit__(self, exc_type, *exc):
            # Failed-fast requests (aged-out deadline, bad model, engine
            # error) must not drag the service-time EWMA toward zero —
            # that would disable deadline shedding exactly under overload.
            # Only successful compute contributes a service sample.
            service = None if exc_type is not None else (
                time.monotonic() - self._t0
            )
            self._ctrl.release(service)
            return False

    def track(self) -> "AdmissionController._Tracker":
        """Pair an already-admitted request with its release + timing."""
        return AdmissionController._Tracker(self)

    def as_dict(self) -> dict:
        """Stats + live gauges for the ``GET /stats`` admission section.

        ``service_ewma_ms`` and ``bucket_tokens`` (current fill per rate-
        limited bucket) make shed decisions explainable post-hoc: a shed
        with near-zero tokens was rate, one with a large EWMA x inflight
        product was deadline.
        """
        with self._lock:
            now = time.monotonic()
            tokens = {
                str(b): lim.available(now) for b, lim in self._limiters.items()
            }
            d = self.stats.as_dict()
            d.update({
                "inflight": self._inflight,
                "max_inflight": self.max_inflight,
                "service_ewma_ms": self._service_ewma_s * 1e3,
                "rate_limited_buckets": sorted(self._limiters),
                "bucket_tokens": tokens,
            })
        for b, v in tokens.items():
            self._m_tokens.set(v, bucket=b)
        return d
