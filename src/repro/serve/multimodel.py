"""Several `ServableGP`s (per kernel / per dataset) behind one engine.

One `BucketedEngine` means ONE jitted predict whose executable cache is
shared: jax specialises per (query bucket, training-set shape, kernel kind)
— the kernel rides along as static pytree aux data from the kernel registry
— so e.g. four kernels x three buckets warm exactly twelve executables, and
models with identical shapes and kernel share executables outright.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import jax

from repro.core.predict import Predictions
from repro.serve.artifact import ServableGP
from repro.serve.engine import DEFAULT_BUCKETS, BucketedEngine


class MultiModelServer:
    """Named-model registry delegating all compute to a shared engine."""

    def __init__(
        self,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        bm: int = 1024,
        bn: int = 1024,
        engine: Optional[BucketedEngine] = None,
    ):
        self.engine = engine if engine is not None else BucketedEngine(
            None, buckets=buckets, bm=bm, bn=bn
        )
        self._models: Dict[str, ServableGP] = {}
        self._lock = threading.Lock()

    # -- registry -----------------------------------------------------------
    def register(
        self, name: str, model: ServableGP, warmup: bool = False
    ) -> None:
        """Add a named model (optionally precompiling every bucket)."""
        with self._lock:
            if name in self._models:
                raise ValueError(
                    f"model {name!r} already registered; use swap()"
                )
            self._models[name] = model
        if warmup:
            self.engine.warmup(model)

    def swap(self, name: str, model: ServableGP) -> None:
        """Atomic replacement (the refresh handoff for named models)."""
        with self._lock:
            if name not in self._models:
                raise KeyError(f"unknown model {name!r}")
            self._models[name] = model

    def unregister(self, name: str) -> ServableGP:
        """Remove and return a named model (KeyError if absent)."""
        with self._lock:
            return self._models.pop(name)

    def get(self, name: str) -> ServableGP:
        """Look up a registered model by name (KeyError lists options)."""
        with self._lock:
            try:
                return self._models[name]
            except KeyError:
                raise KeyError(
                    f"unknown model {name!r}; registered: {sorted(self._models)}"
                ) from None

    def names(self) -> tuple:
        """Sorted names of all registered models."""
        with self._lock:
            return tuple(sorted(self._models))

    # -- serving ------------------------------------------------------------
    def warmup(self) -> Optional[int]:
        """Compile all buckets for every registered model; returns #compiles
        (None when jit cache introspection is unavailable)."""
        for name in self.names():
            self.engine.warmup(self.get(name))
        return self.engine.num_compiles()

    def submit(self, name: str, xq: jax.Array) -> Predictions:
        """Synchronous predict at ``xq`` through the named model."""
        return self.engine.submit(xq, model=self.get(name))

    def enqueue(self, name: str, xq: jax.Array):
        """Queued predict through the named model; returns a Future."""
        return self.engine.enqueue(xq, model=self.get(name))
