"""Shape-bucketed microbatching prediction engine.

XLA specialises one executable per input shape, so naive serving (one trace
per ragged request shape) retraces forever. The engine instead:

  * pads every query batch to a small set of power-of-two-ish row *buckets*,
    so the steady-state executable set is ``len(buckets) x #kernels`` — all
    compiled up front by :meth:`BucketedEngine.warmup`, ZERO retraces after;
  * *microbatches*: queued requests are coalesced into one padded bucket run
    when they fit, amortising dispatch overhead across requests (eq. 16 makes
    the per-row cost one cross-kernel MVM row — batching is pure win);
  * swaps models atomically: the jitted function closes over nothing, the
    `ServableGP` pytree is an argument, so a same-shape refresh swap reuses
    the warm executables (a grown training set recompiles once per bucket on
    first use, which `warmup` can also do eagerly).

Queries larger than the largest bucket are chunked; results are sliced back
to the exact request rows before they leave the engine.
"""
from __future__ import annotations

import math
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.predict import Predictions
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.artifact import ServableGP, servable_predict

DEFAULT_BUCKETS = (16, 64, 256)

# Version of the stats wire format (`EngineStats.as_dict` / GET /stats).
# Bump on any key rename/removal so pollers can detect format drift.
# v3: added latency_p50 / latency_p99 (seconds, None until first dispatch).
STATS_SCHEMA_VERSION = 3

# Batch-latency buckets for the in-process p50/p99 estimate — the same
# boundaries the Prometheus histogram uses, so /stats and scrape-side
# quantiles agree.
_LATENCY_BOUNDS = obs_metrics.DEFAULT_BUCKETS


def pad_to_bucket(xq: jax.Array, bucket: int) -> jax.Array:
    """Zero-pad query rows up to ``bucket`` (rows are independent in eq. 16,
    so phantom rows produce garbage that is sliced off, never wrong answers).
    """
    m = xq.shape[0]
    if m == bucket:
        return xq
    if m > bucket:
        raise ValueError(f"query rows {m} exceed bucket {bucket}")
    return jnp.pad(xq, ((0, bucket - m), (0, 0)))


def _slice_rows(pred: Predictions, lo: int, hi: int) -> Predictions:
    return Predictions(
        mean=pred.mean[lo:hi], var=pred.var[lo:hi], samples=pred.samples[lo:hi]
    )


@dataclass
class EngineStats:
    """Cumulative serving counters (padding waste is the bucketing tax).

    Updated from both the caller thread (sync `submit`) and the queue worker,
    so increments go through an internal lock.
    """

    requests: int = 0  #: guarded by self._lock
    batches: int = 0  #: guarded by self._lock (jitted executions)
    rows: int = 0  #: guarded by self._lock (real query rows served)
    padded_rows: int = 0  #: guarded by self._lock (bucketing phantoms)
    coalesced: int = 0  #: guarded by self._lock (requests sharing a batch)
    #: guarded by self._lock
    per_bucket: dict = field(default_factory=dict)
    # Per-boundary (non-cumulative) dispatch-latency counts over
    # ``_LATENCY_BOUNDS`` plus a final +Inf slot; feeds latency_p50/p99.
    #: guarded by self._lock
    latency_counts: list = field(
        default_factory=lambda: [0] * (len(_LATENCY_BOUNDS) + 1)
    )
    _lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record(self, bucket: int, batch_rows: int, num_requests: int,
               dur_s: Optional[float] = None) -> None:
        """Count one engine dispatch (bucket rows, real rows, requests,
        and — when given — its wall duration for the latency quantiles)."""
        with self._lock:
            self.requests += num_requests
            self.batches += 1
            self.rows += batch_rows
            self.padded_rows += bucket - batch_rows
            if num_requests > 1:
                self.coalesced += num_requests
            self.per_bucket[bucket] = self.per_bucket.get(bucket, 0) + 1
            if dur_s is not None:
                for i, bound in enumerate(_LATENCY_BOUNDS):
                    if dur_s <= bound:
                        self.latency_counts[i] += 1
                        break
                else:
                    self.latency_counts[-1] += 1

    def as_dict(self, num_compiles: Optional[int] = None) -> dict:
        """JSON-serialisable snapshot — THE stats wire format.

        One shape shared by ``GET /stats``, ``benchmarks/serve_throughput``
        and ``benchmarks/serve_cluster``; ``padding_waste`` is the fraction
        of executed rows that were bucketing phantoms, ``num_compiles`` the
        engine's executable count (None = introspection unavailable, which
        consumers must NOT read as zero). ``latency_p50``/``latency_p99``
        are per-dispatch wall-time quantiles in seconds, interpolated from
        the same bucket boundaries as the Prometheus histogram (None until
        the first timed dispatch). ``ts`` (epoch seconds) and
        ``schema_version`` let pollers detect stale snapshots and format
        drift.
        """
        with self._lock:
            executed = self.rows + self.padded_rows
            cum, running = [], 0
            for c in self.latency_counts:
                running += c
                cum.append(float(running))
            p50 = obs_metrics.quantile_from_buckets(_LATENCY_BOUNDS, cum, 0.5)
            p99 = obs_metrics.quantile_from_buckets(_LATENCY_BOUNDS, cum, 0.99)
            return {
                "ts": time.time(),
                "schema_version": STATS_SCHEMA_VERSION,
                "requests": self.requests,
                "batches": self.batches,
                "rows": self.rows,
                "padded_rows": self.padded_rows,
                "padding_waste": (self.padded_rows / executed) if executed else 0.0,
                "coalesced": self.coalesced,
                "per_bucket": {str(b): c for b, c in sorted(self.per_bucket.items())},
                "num_compiles": num_compiles,
                "latency_p50": None if math.isnan(p50) else p50,
                "latency_p99": None if math.isnan(p99) else p99,
            }


class BucketedEngine:
    """Serve `ServableGP` predictions with bucketed shapes and a request queue.

    Synchronous path: :meth:`submit` pads, runs, slices. Asynchronous path:
    :meth:`enqueue` returns a `Future`; a background worker drains the queue,
    coalescing same-model requests into shared bucket runs.
    """

    def __init__(
        self,
        model: Optional[ServableGP] = None,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        bm: int = 1024,
        bn: int = 1024,
        registry: Optional[obs_metrics.MetricsRegistry] = None,
    ):
        if not buckets:
            raise ValueError("need at least one bucket size")
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        self.bm = int(bm)
        self.bn = int(bn)
        self._model = model  #: guarded by self._model_lock
        self._model_lock = threading.Lock()

        # Observability: None => the process default registry (scraped by
        # GET /metrics); pass obs_metrics.NULL_REGISTRY to disable (the
        # overhead benchmark's baseline arm). Getters are idempotent, so
        # several engines in one process share the same instruments.
        reg = obs_metrics.default_registry() if registry is None else registry
        self._m_requests = reg.counter(
            "gp_engine_requests_total", "Requests served by the engine")
        self._m_batches = reg.counter(
            "gp_engine_batches_total", "Jitted bucket executions",
            labelnames=("bucket",))
        self._m_rows = reg.counter(
            "gp_engine_rows_total", "Query rows executed by kind",
            labelnames=("kind",))  # kind: real | padded
        self._m_coalesced = reg.counter(
            "gp_engine_coalesced_total",
            "Requests that shared a microbatch with another")
        self._m_queue_depth = reg.gauge(
            "gp_engine_queue_depth", "Requests waiting in the engine queue")
        self._m_batch_seconds = reg.histogram(
            "gp_engine_batch_seconds", "Engine dispatch latency per bucket",
            labelnames=("bucket",))

        # A fresh function object per engine: jit caches are keyed by the
        # wrapped callable, so this keeps the executable cache (and hence the
        # zero-retrace accounting in `num_compiles`) private to this engine
        # instead of shared process-wide through the module-level function.
        def _predict(model, xq, bm, bn):
            return servable_predict(model, xq, bm=bm, bn=bn)

        self._predict = jax.jit(_predict, static_argnames=("bm", "bn"))
        self.stats = EngineStats()
        self._queue: queue.Queue = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- model management ---------------------------------------------------
    @property
    def model(self) -> ServableGP:
        """The currently served artifact (raises before the first swap)."""
        with self._model_lock:
            if self._model is None:
                raise RuntimeError("engine has no model; pass one or swap_model")
            return self._model

    def swap_model(self, model: ServableGP) -> None:
        """Atomically replace the served model (refresh handoff).

        Same (n, s) shapes and kernel => the warm executables are reused;
        a grown training set compiles once per bucket on next use/warmup.
        """
        with self._model_lock:
            self._model = model

    # -- compilation --------------------------------------------------------
    def warmup(self, model: Optional[ServableGP] = None) -> Optional[int]:
        """Compile every bucket executable up front; returns #compiles held.

        After warmup, steady-state serving of this model never traces again
        (asserted by tests and the throughput benchmark via `num_compiles`).
        """
        model = model if model is not None else self.model
        d = model.x.shape[1]
        for b in self.buckets:
            dummy = jnp.zeros((b, d), dtype=model.x.dtype)
            jax.block_until_ready(
                self._predict(model, dummy, bm=self.bm, bn=self.bn).mean
            )
        return self.num_compiles()

    def num_compiles(self) -> Optional[int]:
        """Executable-cache size of the jitted predict (retrace detector).

        Returns None when the cache-size introspection is unavailable (it is
        a private jax API) — callers must treat None as "accounting
        unavailable", NEVER as zero retraces.
        """
        try:
            return int(self._predict._cache_size())
        except Exception:  # pragma: no cover - private API moved
            return None

    def stats_dict(self) -> dict:
        """`EngineStats.as_dict` with this engine's compile count folded in."""
        return self.stats.as_dict(num_compiles=self.num_compiles())

    def _observe(self, bucket: int, batch_rows: int, num_requests: int,
                 dur_s: float) -> None:
        """Fold one dispatch into stats + metrics (both paths share this)."""
        self.stats.record(bucket, batch_rows, num_requests, dur_s=dur_s)
        self._m_requests.inc(num_requests)
        self._m_batches.inc(bucket=str(bucket))
        self._m_rows.inc(batch_rows, kind="real")
        self._m_rows.inc(bucket - batch_rows, kind="padded")
        if num_requests > 1:
            self._m_coalesced.inc(num_requests)
        self._m_batch_seconds.observe(dur_s, bucket=str(bucket))

    # -- synchronous serving ------------------------------------------------
    def bucket_for(self, m: int) -> int:
        """Smallest bucket covering ``m`` rows (largest bucket if none)."""
        for b in self.buckets:
            if m <= b:
                return b
        return self.buckets[-1]

    def submit(
        self, xq: jax.Array, model: Optional[ServableGP] = None
    ) -> Predictions:
        """Predict at ``xq`` (m, d); pads to a bucket, slices back to m rows.

        Oversized queries are chunked into largest-bucket pieces.
        """
        model = model if model is not None else self.model
        m = xq.shape[0]
        bmax = self.buckets[-1]
        if m > bmax:
            parts = [
                self.submit(xq[lo : lo + bmax], model=model)
                for lo in range(0, m, bmax)
            ]
            return Predictions(
                mean=jnp.concatenate([p.mean for p in parts]),
                var=jnp.concatenate([p.var for p in parts]),
                samples=jnp.concatenate([p.samples for p in parts]),
            )
        bucket = self.bucket_for(m)
        # Span rides the caller's trace context (the HTTP handler thread on
        # the sync serving path); no-op unless an event log is configured.
        with obs_trace.span("engine.submit", bucket=bucket, rows=m):
            t0 = time.perf_counter()
            pred = self._predict(
                model, pad_to_bucket(xq, bucket), bm=self.bm, bn=self.bn
            )
            self._observe(bucket, m, 1, time.perf_counter() - t0)
        return _slice_rows(pred, 0, m)

    # -- queued / microbatched serving --------------------------------------
    def enqueue(
        self, xq: jax.Array, model: Optional[ServableGP] = None
    ) -> Future:
        """Queue a request; the worker thread resolves the returned Future."""
        fut: Future = Future()
        self._queue.put((xq, model, fut))
        self._m_queue_depth.set(self._queue.qsize())
        if self._worker is None:
            self.start()
        return fut

    def start(self) -> None:
        """Start the microbatching worker thread (idempotent)."""
        if self._worker is not None:
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._worker_loop, name="serve-engine", daemon=True
        )
        self._worker.start()

    def stop(self) -> None:
        """Stop the worker thread, draining the queue first."""
        if self._worker is None:
            return
        self._stop.set()
        self._queue.put(None)  # wake the worker
        self._worker.join(timeout=10.0)
        self._worker = None

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            item = self._queue.get()
            self._m_queue_depth.set(self._queue.qsize())
            if item is None:
                continue
            self._run_coalesced(item)

    def _run_coalesced(self, first) -> None:
        """One microbatch: the head request plus any queued same-model
        requests that still fit in the largest bucket."""
        batch = [first]
        total = first[0].shape[0]
        bmax = self.buckets[-1]
        while total < bmax:
            try:
                nxt = self._queue.queue[0]  # peek
            except IndexError:
                break
            if nxt is None:
                break
            if nxt[1] is not first[1]:  # different explicit model: own batch
                break
            if total + nxt[0].shape[0] > bmax:
                break
            self._queue.get()
            batch.append(nxt)
            total += nxt[0].shape[0]
        self._m_queue_depth.set(self._queue.qsize())

        try:
            model = (first[1] if first[1] is not None else self.model)
            xq = (batch[0][0] if len(batch) == 1
                  else jnp.concatenate([b[0] for b in batch], axis=0))
            bucket = self.bucket_for(total)
            if total > bucket:  # only when a single oversized request
                pred = self.submit(xq, model=model)
            else:
                t0 = time.perf_counter()
                pred = _slice_rows(
                    self._predict(model, pad_to_bucket(xq, bucket),
                                  bm=self.bm, bn=self.bn),
                    0, total,
                )
                self._observe(bucket, total, len(batch),
                              time.perf_counter() - t0)
            lo = 0
            for xq_i, _, fut in batch:
                hi = lo + xq_i.shape[0]
                fut.set_result(_slice_rows(pred, lo, hi))
                lo = hi
        except Exception as e:  # surface errors through the futures
            for _, _, fut in batch:
                if not fut.done():
                    fut.set_exception(e)
