"""Warm-started online model refresh (append -> refine -> atomic swap).

The same carry that amortises outer MLL steps (paper §4) amortises *model
refresh* when observations stream in (Dong et al., 2025, "Warm-Starting
Iterative Gaussian Processes for Faster Sequential Inference"): the old
solutions, zero-padded on the appended rows, are an excellent initialisation
for the enlarged system, so a budgeted warm solve reaches tolerance in far
fewer epochs than a cold start. `OnlineGP` owns the mutable (data, state)
pair; serving stays on the frozen `ServableGP` until `refine` finishes and
the engine swap makes the new artifact visible atomically.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import replace
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import build_system_targets
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    extend_state,
    outer_step,
)
from repro.serve.artifact import ServableGP, export_servable
from repro.solvers import HOperator, solve


def merge_refined_state(
    current: OuterState, refined: OuterState
) -> OuterState:
    """Fold a refinement computed on an n-row snapshot into ``current``.

    ``current`` may have grown past the snapshot (appends that raced a
    background refine): its extra carry/probe rows — zero carry plus fresh
    base noise from `extend_state` — must survive the commit, so the solved
    rows overwrite only the snapshot's prefix. ``current``'s probes and key
    are kept (they include the concurrent extensions and key advances);
    hyperparameter/Adam/step progress is taken from ``refined``.
    """
    n_solved = refined.carry_v.shape[0]
    if current.carry_v.shape[0] > n_solved:
        carry = jnp.concatenate(
            [refined.carry_v, current.carry_v[n_solved:]], axis=0
        )
    else:
        carry = refined.carry_v
    return current._replace(
        carry_v=carry,
        params=refined.params,
        adam=refined.adam,
        step=refined.step,
        last_res_y=refined.last_res_y,
        last_res_z=refined.last_res_z,
        last_iters=refined.last_iters,
        last_epochs=refined.last_epochs,
    )


class RefreshReport(NamedTuple):
    """What one `refine` cost and achieved."""

    n: int  # training rows after the refresh
    appended: int  # rows appended since the last refine
    epochs: float  # solver epochs consumed
    iters: int  # inner iterations
    res_y: float  # final mean-system relative residual
    res_z: float  # final probe-average relative residual
    warm: bool  # warm-started from the extended carry?


class OnlineGP:
    """A fitted GP that can absorb new observations and refresh in place.

    Typical loop:

        online = OnlineGP(x, y, fit_result.state, cfg)
        engine = BucketedEngine(online.export()); engine.warmup()
        ...
        online.append(x_new, y_new)
        online.refresh_into(engine, budget_epochs=10.0)   # solve + swap
    """

    def __init__(
        self, x: jax.Array, y: jax.Array, state: OuterState, cfg: OuterConfig
    ):
        self.x = x
        self.y = y
        self.state = state
        self.cfg = cfg
        self._appended = 0
        self._lock = threading.Lock()

    @property
    def n(self) -> int:
        return self.x.shape[0]

    def append(self, x_new: jax.Array, y_new: jax.Array) -> None:
        """Add observations; extends the warm-start carry with zero rows and
        draws fixed base-probe randomness for the new rows (core hook)."""
        if x_new.ndim != 2 or x_new.shape[1] != self.x.shape[1]:
            raise ValueError(
                f"x_new must be (k, {self.x.shape[1]}), got {x_new.shape}"
            )
        with self._lock:
            k = x_new.shape[0]
            self.x = jnp.concatenate([self.x, x_new], axis=0)
            self.y = jnp.concatenate([self.y, y_new], axis=0)
            self.state = extend_state(self.state, k, dtype=self.x.dtype)
            self._appended += k

    def refine(
        self,
        budget_epochs: Optional[float] = None,
        warm: bool = True,
        mode: str = "solve",
        key: Optional[jax.Array] = None,
    ) -> RefreshReport:
        """Budgeted refinement of the enlarged system (paper §5 budgets).

        ``mode="solve"`` re-solves the linear systems at fixed hyperparameters
        (the serving-refresh fast path: tolerance is the early stop, the
        epoch budget the cap). ``mode="step"`` runs one full `outer_step`
        (hyperparameters move too). ``warm=False`` is the cold-start control
        the throughput benchmark compares against.
        """
        with self._lock:
            state, x, y, cfg = self.state, self.x, self.y, self.cfg
            appended = self._appended
        kind = effective_kind(cfg, state.params)
        if mode == "step":
            scfg = cfg.solver if budget_epochs is None else replace(
                cfg.solver, max_epochs=budget_epochs
            )
            step_cfg = replace(cfg, solver=scfg, warm_start=warm)
            new_state, metrics = outer_step(state, x, y, step_cfg)
            report = RefreshReport(
                n=x.shape[0], appended=appended,
                epochs=float(metrics["epochs"]), iters=int(metrics["iters"]),
                res_y=float(metrics["res_y"]), res_z=float(metrics["res_z"]),
                warm=warm,
            )
        elif mode == "solve":
            targets = build_system_targets(state.probes, x, y, state.params)
            op = HOperator(x=x, params=state.params, kind=kind,
                           backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
            scfg = cfg.solver if cfg.solver.kind == kind else replace(
                cfg.solver, kind=kind
            )
            if budget_epochs is not None:
                scfg = replace(scfg, max_epochs=budget_epochs)
            v0 = state.carry_v if warm else None
            ksolve = key if key is not None else jax.random.fold_in(state.key, 13)
            res = solve(op, targets, v0, scfg, key=ksolve)
            new_state = state._replace(carry_v=res.v)
            report = RefreshReport(
                n=x.shape[0], appended=appended,
                epochs=float(res.epochs), iters=int(res.iters),
                res_y=float(res.res_y), res_z=float(res.res_z), warm=warm,
            )
        else:
            raise ValueError(f"unknown refine mode {mode!r}")
        with self._lock:
            # Appends may have raced this refine (background mode): commit the
            # solved rows into the CURRENT state so their extensions survive.
            self.state = merge_refined_state(self.state, new_state)
            self._appended = max(0, self._appended - appended)
        return report

    def export(self) -> ServableGP:
        """Freeze the current state into a serving artifact."""
        with self._lock:
            return export_servable(
                self.state, self.x, kind=effective_kind(self.cfg, self.state.params)
            )

    def refresh_into(
        self,
        engine,
        name: Optional[str] = None,
        budget_epochs: Optional[float] = None,
        mode: str = "solve",
        background: bool = False,
    ):
        """Refine, then atomically swap the new artifact into ``engine``.

        ``engine`` is a `BucketedEngine` (or a `MultiModelServer` with
        ``name``). ``background=True`` runs the whole refresh on a daemon
        thread — serving continues on the old artifact until the swap — and
        returns a `concurrent.futures.Future` resolving to the
        `RefreshReport` (or carrying the exception, so failures are
        observable instead of dying with the thread). Otherwise returns the
        `RefreshReport` directly.
        """

        def _do():
            report = self.refine(budget_epochs=budget_epochs, mode=mode)
            model = self.export()
            if name is not None:
                engine.swap(name, model)
            else:
                engine.swap_model(model)
            return report

        if background:
            fut: Future = Future()

            def _run():
                try:
                    fut.set_result(_do())
                except BaseException as e:
                    fut.set_exception(e)

            threading.Thread(target=_run, name="gp-refresh", daemon=True).start()
            return fut
        return _do()
