"""Warm-started online model refresh (append -> refine -> atomic swap).

The same carry that amortises outer MLL steps (paper §4) amortises *model
refresh* when observations stream in (Dong et al., 2025, "Warm-Starting
Iterative Gaussian Processes for Faster Sequential Inference"): the old
solutions, zero-padded on the appended rows, are an excellent initialisation
for the enlarged system, so a budgeted warm solve reaches tolerance in far
fewer epochs than a cold start. `OnlineGP` owns the mutable (data, state)
pair; serving stays on the frozen `ServableGP` until `refine` finishes and
the engine swap makes the new artifact visible atomically.

Two properties matter for *sequential* workloads (a BO loop appending one
row per round for hundreds of rounds):

  * **Geometric capacity growth** (``growth="geometric"``): instead of
    growing every array by the exact append size — a new system shape, and
    therefore a solver retrace AND an engine-bucket retrace, every round —
    the training arrays are padded up a geometric capacity ladder
    (:func:`repro.core.outer.grow_capacity`) with inert *ghost rows*:
    points placed hundreds of lengthscales away from the data, where every
    registered stationary kernel underflows to exactly 0.0 in fp32. The
    kernel matrix is then exactly block-diagonal, the ghost block is
    near-identity (solved in O(1) iterations), and the real-row solutions
    are bit-for-bit unaffected. N appends compile O(log N) solver
    executables instead of N.

  * **Damped old-row correction** (``correction="damped"``): the block
    refresh (``mode="block"``) deliberately leaves the old-row back-coupling
    ``K12 dv`` unpaid. When appends land near the bulk (the common case in
    BO — acquisition picks points near the data), that coupling is large and
    plain ``mode="auto"`` escalates to a full re-solve every round. The
    damped correction repairs the old rows at ~block cost instead: a free
    damped-Jacobi step ``dv1 = -omega * K12 dv / (signal^2 + noise^2)``
    (the cross-MVM is already computed for the coupling estimate), then a
    small budgeted warm solve of the FULL system (``correction_epochs``,
    default 2) that both polishes the correction and reports an HONEST
    full-system residual. Auto-escalation then fires only when the corrected
    residual is still above threshold — rarely — and starts warm from the
    corrected carry with the budget it has already spent subtracted.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import replace
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import build_system_targets
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    extend_state,
    grow_capacity,
    outer_step,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.artifact import ServableGP, export_servable
from repro.solvers import (
    HOperator,
    kernel_mvm_tiled,
    numerics_of,
    solve,
    strip_numerics,
)


def merge_refined_state(
    current: OuterState, refined: OuterState
) -> OuterState:
    """Fold a refinement computed on an n-row snapshot into ``current``.

    ``current`` may have grown past the snapshot (appends that raced a
    background refine): its extra carry/probe rows — zero carry plus fresh
    base noise from `extend_state` — must survive the commit, so the solved
    rows overwrite only the snapshot's prefix. ``current``'s probes and key
    are kept (they include the concurrent extensions and key advances);
    hyperparameter/Adam/step progress is taken from ``refined``.
    """
    n_solved = refined.carry_v.shape[0]
    if current.carry_v.shape[0] > n_solved:
        carry = jnp.concatenate(
            [refined.carry_v, current.carry_v[n_solved:]], axis=0
        )
    else:
        carry = refined.carry_v
    return current._replace(
        carry_v=carry,
        params=refined.params,
        adam=refined.adam,
        step=refined.step,
        last_res_y=refined.last_res_y,
        last_res_z=refined.last_res_z,
        last_iters=refined.last_iters,
        last_epochs=refined.last_epochs,
    )


# refine(mode="auto") escalation threshold, in units of the solver
# tolerance: the block refresh's reported coupling residual sits at ~1-2x
# tolerance in its validity regime (weakly coupled appends) and orders of
# magnitude above it when the appended rows overlap the bulk, so a few
# tolerances cleanly separates the two (measured: ~2x vs ~9000x on the
# regression fixtures). Override per call with ``coupling_threshold``.
AUTO_COUPLING_FACTOR = 5.0

# Growth policies for appended observations.
GROWTH_EXACT = "exact"  # arrays grow by the exact append size
GROWTH_GEOMETRIC = "geometric"  # capacity ladder + inert ghost rows

# Ghost rows are placed on the diagonal ray ``j * unit * (1, ..., 1)`` with
# ``unit = GHOST_UNIT_FACTOR * (data span + max lengthscale + 1)``: every
# ghost sits >= GHOST_UNIT_FACTOR lengthscales from all real points and from
# every other ghost. exp(-256) (Matérn-1/2, the slowest-decaying registered
# kernel) underflows to exactly 0.0 in fp32, so the padded kernel matrix is
# EXACTLY block-diagonal and ghost rows cannot perturb real solutions.
GHOST_UNIT_FACTOR = 256.0

# Damped old-row correction defaults: the damping factor of the free Jacobi
# step and the full-system epoch budget of the warm polish that makes the
# post-correction residual honest.
CORRECTION_DAMPING = 0.5
CORRECTION_EPOCHS = 2.0


class RefreshReport(NamedTuple):
    """What one `refine` cost and achieved.

    ``epochs`` is always in FULL-system epoch units (one epoch = every
    entry of the n x n H computed once, where n is the PADDED capacity when
    geometric growth is active — padding waste is real compute and is
    charged), so block and full refreshes are directly comparable: a block
    refresh on k new rows charges k/n of an epoch for the cross MVM plus
    ``block_epochs * (k/n)^2`` for the solve on the k x k sub-system. An
    escalated ``mode="auto"`` charges the block attempt (plus any
    correction) PLUS the full re-solve it triggered.
    """

    n: int  # REAL training rows after the refresh (ghost rows excluded)
    appended: int  # rows appended since the last refine
    epochs: float  # solver epochs consumed (full-system units)
    iters: int  # inner iterations
    res_y: float  # final mean-system relative residual
    res_z: float  # final probe-average relative residual
    warm: bool  # warm-started from the extended carry?
    mode: str = "solve"  # solve | step | block | auto
    block_rows: int = 0  # rows of the block sub-system (mode="block"/"auto")
    block_epochs: float = 0.0  # solver epochs in k-system units (block/auto)
    escalated: bool = False  # auto mode fell back to a full re-solve?
    corrected: bool = False  # damped old-row correction ran?
    correction_epochs: float = 0.0  # full-system epochs spent by it
    capacity: int = 0  # padded system rows (== n under growth="exact")
    # Trace IDs of the requests whose appends this refine absorbed (the
    # /append -> refresh causality link in the structured event logs).
    trace_ids: tuple = ()


class OnlineGP:
    """A fitted GP that can absorb new observations and refresh in place.

    Typical loop:

        online = OnlineGP(x, y, fit_result.state, cfg)
        engine = BucketedEngine(online.export()); engine.warmup()
        ...
        online.append(x_new, y_new)
        online.refresh_into(engine, budget_epochs=10.0)   # solve + swap

    Args:
      x: (n, d) training inputs of the fitted state.
      y: (n,) training targets.
      state: the fitted `OuterState` (pathwise carry for serving export).
      cfg: the `OuterConfig` the state was fitted under.
      growth: ``"exact"`` (default) grows arrays by the exact append size —
        every distinct n is a new solver executable. ``"geometric"`` pads
        up a capacity ladder with inert far-away ghost rows so N sequential
        appends compile only O(log N) executables and the exported
        `ServableGP` keeps a stable shape between growth events (zero
        engine retraces). Real-row solutions are unaffected (the ghost
        cross-kernel underflows to exactly 0 in fp32).
      reserve: with geometric growth, pre-extend capacity to cover this
        many future appended rows up front — a driver that knows its
        horizon (e.g. a BO loop of R rounds) gets ZERO growth events and
        therefore zero retraces after the first solve/warmup.
    """

    def __init__(
        self,
        x: jax.Array,
        y: jax.Array,
        state: OuterState,
        cfg: OuterConfig,
        growth: str = GROWTH_EXACT,
        reserve: int = 0,
    ):
        if growth not in (GROWTH_EXACT, GROWTH_GEOMETRIC):
            raise ValueError(
                f"growth must be {GROWTH_EXACT!r} or {GROWTH_GEOMETRIC!r}, "
                f"got {growth!r}"
            )
        self.x = x
        self.y = y
        self.state = state
        self.cfg = cfg
        self.growth = growth
        self._n = int(x.shape[0])
        self._appended = 0
        self._ghost_count = 0
        self._ghost_unit_val: Optional[float] = None
        self._lock = threading.Lock()
        self._last_report: Optional[RefreshReport] = None
        self._counters = {
            "refines": 0, "appends": 0, "appended_rows": 0,
            "escalations": 0, "corrections": 0, "growth_events": 0,
            "cum_epochs": 0.0, "cum_iters": 0,
        }
        # Trace IDs of requests whose appends are awaiting a refine; the
        # next refine drains them into its RefreshReport / "refresh" event.
        self._pending_traces: list = []
        reg = obs_metrics.default_registry()
        self._m_refines = reg.counter(
            "gp_refresh_refines_total", "Refine operations by mode",
            labelnames=("mode",))
        self._m_appended = reg.counter(
            "gp_refresh_appended_rows_total", "Observations appended")
        self._m_escalations = reg.counter(
            "gp_refresh_escalations_total", "auto-mode full-solve escalations")
        self._m_epochs = reg.counter(
            "gp_refresh_epochs_total", "Solver epochs spent by refines")
        self._m_pending = reg.gauge(
            "gp_refresh_pending_appends", "Appended rows awaiting a refine")

        kind = effective_kind(cfg, state.params)
        self._kind = kind
        base = cfg.solver if cfg.solver.kind == kind else replace(
            cfg.solver, kind=kind
        )
        # Numeric values (tolerance/budget/lr/...) always ride in as a
        # traced SolverNumerics pytree, so ONE executable per system shape
        # serves every budget — `_scfg_*` keeps the caller's values as the
        # numerics source, the jitted wrappers close over the stripped
        # static half.
        self._scfg_full = base
        self._scfg_block = replace(base, name="cg")
        self._jit_full = self._make_jit_solve(strip_numerics(self._scfg_full))
        self._jit_block = self._make_jit_solve(
            strip_numerics(self._scfg_block)
        )
        if growth == GROWTH_GEOMETRIC and reserve > 0:
            with self._lock:
                self._grow_to(self._n + int(reserve))

    # -- sizes ---------------------------------------------------------------
    @property
    def n(self) -> int:
        """Number of REAL training rows (ghost padding excluded)."""
        return self._n

    @property
    def capacity(self) -> int:
        """Padded row count of the stored arrays (== n under exact growth)."""
        return int(self.x.shape[0])

    # -- solver plumbing -----------------------------------------------------
    def _make_jit_solve(self, scfg):
        """One jitted solve entry per static solver config.

        Shapes are the only retrace axis (numerics are traced), so with
        geometric growth the jit cache size IS the O(log N) compile count —
        see :meth:`num_solve_compiles`.
        """
        cfg, kind = self.cfg, self._kind

        def _solve(xs, b, v0, params, key, numerics):
            op = HOperator(x=xs, params=params, kind=kind,
                           backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
            return solve(op, b, v0, scfg, key=key, numerics=numerics)

        return jax.jit(_solve)

    def num_solve_compiles(self) -> Optional[int]:
        """Executable count across the refine solve paths (retrace detector).

        Returns None when jit cache introspection (a private jax API) is
        unavailable — callers must treat None as "accounting unavailable",
        never as zero.
        """
        try:
            return int(self._jit_full._cache_size()) + int(
                self._jit_block._cache_size()
            )
        except Exception:  # pragma: no cover - private API moved
            return None

    # -- growth --------------------------------------------------------------
    def _ghost_unit(self) -> float:
        """Spacing of the ghost ray (computed once, from data + lengthscale)."""
        if self._ghost_unit_val is None:
            span = float(jnp.max(jnp.abs(self.x[: self._n]))) if self._n else 1.0
            ls = float(jnp.max(self.state.params.lengthscales))
            self._ghost_unit_val = GHOST_UNIT_FACTOR * (span + ls + 1.0)
        return self._ghost_unit_val

    def _ghost_inputs(self, k: int) -> jax.Array:
        """(k, d) inert pad points: far from the data AND from each other."""
        unit = self._ghost_unit()
        d = self.x.shape[1]
        idx = jnp.arange(1, k + 1, dtype=self.x.dtype) + jnp.asarray(
            self._ghost_count, self.x.dtype
        )
        self._ghost_count += k
        return idx[:, None] * unit * jnp.ones((1, d), self.x.dtype)

    def _grow_to(self, needed: int) -> None:
        """Extend capacity up the geometric ladder (lock held by caller)."""
        cap = self.capacity
        new_cap = grow_capacity(cap, needed)
        if new_cap <= cap:
            return
        pad = new_cap - cap
        self.x = jnp.concatenate([self.x, self._ghost_inputs(pad)], axis=0)
        self.y = jnp.concatenate(
            [self.y, jnp.zeros((pad,), self.y.dtype)], axis=0
        )
        self.state = extend_state(self.state, pad, dtype=self.x.dtype)
        self._counters["growth_events"] += 1

    def append(self, x_new: jax.Array, y_new: jax.Array,
               trace_id: Optional[str] = None) -> None:
        """Add observations; extends the warm-start carry with zero rows and
        draws fixed base-probe randomness for the new rows (core hook).

        Under geometric growth the rows are written into reserved ghost
        slots (their probe randomness was drawn at growth time and stays
        fixed — same warm-start contract); capacity only grows, by
        :func:`repro.core.outer.grow_capacity`, when the slots run out.

        ``trace_id`` (default: the caller's current trace context) is
        remembered until the next :meth:`refine`, whose `RefreshReport` and
        "refresh" event carry every trace that contributed appends — the
        causality link from a ``POST /append`` request to the refresh it
        triggered.
        """
        if x_new.ndim != 2 or x_new.shape[1] != self.x.shape[1]:
            raise ValueError(
                f"x_new must be (k, {self.x.shape[1]}), got {x_new.shape}"
            )
        tid = trace_id if trace_id is not None else obs_trace.current_trace_id()
        with self._lock:
            k = x_new.shape[0]
            if self.growth == GROWTH_GEOMETRIC:
                self._grow_to(self._n + k)
                lo = self._n
                self.x = self.x.at[lo:lo + k].set(x_new.astype(self.x.dtype))
                self.y = self.y.at[lo:lo + k].set(y_new.astype(self.y.dtype))
                self.state = self.state._replace(
                    carry_v=self.state.carry_v.at[lo:lo + k].set(0.0)
                )
            else:
                self.x = jnp.concatenate([self.x, x_new], axis=0)
                self.y = jnp.concatenate([self.y, y_new], axis=0)
                self.state = extend_state(self.state, k, dtype=self.x.dtype)
            self._n += k
            self._appended += k
            self._counters["appends"] += 1
            self._counters["appended_rows"] += k
            if tid is not None:
                self._pending_traces.append(tid)
            pending = self._appended
        self._m_appended.inc(k)
        self._m_pending.set(pending)

    # -- refinement ----------------------------------------------------------
    def _record(self, report: RefreshReport) -> None:
        """Fold one refine into the cumulative counters (lock held)."""
        self._counters["refines"] += 1
        self._counters["cum_epochs"] += float(report.epochs)
        self._counters["cum_iters"] += int(report.iters)
        if report.escalated:
            self._counters["escalations"] += 1
        if report.corrected:
            self._counters["corrections"] += 1
        self._last_report = report
        self._m_refines.inc(mode=report.mode)
        self._m_epochs.inc(float(report.epochs))
        if report.escalated:
            self._m_escalations.inc()
        self._m_pending.set(self._appended)

    def _emit_refresh(self, report: RefreshReport) -> None:
        """One structured "refresh" event per refine (no-op when no log)."""
        obs_trace.emit(
            "refresh", mode=report.mode, n=report.n,
            appended=report.appended, epochs=report.epochs,
            iters=report.iters, res_y=report.res_y, res_z=report.res_z,
            escalated=report.escalated, corrected=report.corrected,
            trace_ids=list(report.trace_ids),
        )

    def refine(
        self,
        budget_epochs: Optional[float] = None,
        warm: bool = True,
        mode: str = "solve",
        key: Optional[jax.Array] = None,
        coupling_threshold: Optional[float] = None,
        correction: str = "none",
        correction_epochs: float = CORRECTION_EPOCHS,
        correction_damping: float = CORRECTION_DAMPING,
    ) -> RefreshReport:
        """Budgeted refinement of the enlarged system (paper §5 budgets).

        ``mode="solve"`` re-solves the linear systems at fixed hyperparameters
        (the serving-refresh fast path: tolerance is the early stop, the
        epoch budget the cap). ``mode="step"`` runs one full `outer_step`
        (hyperparameters move too; unsupported under geometric growth, where
        ghost rows would bias the MLL gradient). ``warm=False`` is the
        cold-start control the throughput benchmark compares against.

        ``mode="block"`` is the incremental refresh: the zero-padded old
        solution already satisfies the old rows to solver tolerance (the
        warm-start observation of Dong et al., 2025), so the residual of the
        enlarged system is concentrated on the k appended rows. The solver
        therefore runs ONLY on the k x k sub-system

            (K(x_new, x_new) + sigma^2 I) dv = b_new - H[new, :] @ v_old,

        and the correction ``dv`` lands on the new carry rows. The old rows'
        back-coupling ``H11^{-1} K12 dv`` is deliberately left unpaid — that
        is the whole saving — so the block refresh is exact up to coupling:
        machine-level parity with the full re-solve when the appended rows
        are weakly correlated with the bulk (new input region, or k << n),
        degrading as coupling grows. The report's ``res_y``/``res_z`` are an
        honest full-system residual estimate (``||K12 dv|| / ||b||``, the
        norm of the neglected old-row residual): ~solver tolerance in the
        valid regime, large when a full ``mode="solve"`` is actually needed.
        ``epochs`` reports full-system equivalents (2k/n for the two cross
        MVMs + block epochs scaled by (k/n)^2) so the saving is visible in
        the same units as ``mode="solve"``.

        ``correction="damped"`` (block/auto) repairs the old rows whenever
        the coupling residual exceeds tolerance, at ~block cost instead of
        a full re-solve: a free damped-Jacobi step
        ``dv1 = -correction_damping * K12 dv / (signal^2 + noise^2)``
        (reusing the cross-MVM already computed for the coupling estimate)
        followed by a warm full-system polish budgeted at
        ``correction_epochs`` epochs. The polish's solver residual replaces
        the coupling estimate, so the reported ``res_y``/``res_z`` stay
        honest after the correction.

        ``mode="auto"`` makes the block-vs-full decision itself: it runs
        the block refresh (plus the damped correction when enabled) and,
        when the resulting residual ``max(res_y, res_z)`` exceeds
        ``coupling_threshold`` (default ``AUTO_COUPLING_FACTOR x`` the
        solver tolerance), escalates to a full re-solve — warm-started from
        the block-corrected carry with the epochs already spent subtracted
        from ``budget_epochs``, so the block work is a head start, not
        waste, and the budget is never double-charged. In the weak-coupling
        regime auto costs the same as "block"; under strongly coupled
        appends it pays the correction (and only then, rarely, the full
        solve) instead of silently leaving a large ``res_y``. The report's
        ``escalated``/``corrected`` flags say which path ran.

        Returns:
          A :class:`RefreshReport`; the refined carry is committed into the
          live state (merged with any appends that raced this refine).
        """
        if correction not in ("none", "damped"):
            raise ValueError(
                f"correction must be 'none' or 'damped', got {correction!r}"
            )
        with self._lock:
            state, x, y, cfg = self.state, self.x, self.y, self.cfg
            appended = self._appended
            n_real = self._n
            trace_ids = tuple(self._pending_traces)
        kind = self._kind
        cap = int(x.shape[0])
        if mode == "step":
            if self.growth == GROWTH_GEOMETRIC:
                raise ValueError(
                    "mode='step' moves hyperparameters on the padded system; "
                    "ghost rows would bias the MLL gradient — use "
                    "growth='exact' for refresh-with-hyperparameter-updates"
                )
            scfg = cfg.solver if budget_epochs is None else replace(
                cfg.solver, max_epochs=budget_epochs
            )
            step_cfg = replace(cfg, solver=scfg, warm_start=warm)
            new_state, metrics = outer_step(state, x, y, step_cfg)
            report = RefreshReport(
                n=n_real, appended=appended,
                epochs=float(metrics["epochs"]), iters=int(metrics["iters"]),
                res_y=float(metrics["res_y"]), res_z=float(metrics["res_z"]),
                warm=warm, mode=mode, capacity=cap,
            )
        elif mode == "solve":
            targets = build_system_targets(state.probes, x, y, state.params)
            nm = numerics_of(self._scfg_full)
            if budget_epochs is not None:
                nm = nm._replace(max_epochs=jnp.float32(budget_epochs))
            v0 = state.carry_v if warm else None
            ksolve = key if key is not None else jax.random.fold_in(state.key, 13)
            res = self._jit_full(x, targets, v0, state.params, ksolve, nm)
            new_state = state._replace(
                carry_v=res.v,
                last_res_y=res.res_y.astype(jnp.float32),
                last_res_z=res.res_z.astype(jnp.float32),
                last_iters=res.iters,
                last_epochs=res.epochs.astype(jnp.float32),
            )
            report = RefreshReport(
                n=n_real, appended=appended,
                epochs=float(res.epochs), iters=int(res.iters),
                res_y=float(res.res_y), res_z=float(res.res_z), warm=warm,
                mode=mode, capacity=cap,
            )
        elif mode in ("block", "auto"):
            if not warm:
                raise ValueError(
                    "block refresh refines the warm carry; it has no "
                    "cold-start variant (use mode='solve', warm=False)"
                )
            k = appended
            if k == 0:
                report = RefreshReport(
                    n=n_real, appended=0, epochs=0.0, iters=0,
                    res_y=float(state.last_res_y),
                    res_z=float(state.last_res_z), warm=True, mode=mode,
                    capacity=cap, trace_ids=trace_ids,
                )
                with self._lock:
                    self._pending_traces = self._pending_traces[len(trace_ids):]
                    self._record(report)
                self._emit_refresh(report)
                return report
            n0 = n_real - k
            tol = float(self._scfg_full.tolerance)
            targets = build_system_targets(state.probes, x, y, state.params)
            x_new = x[n0:n_real]
            # Residual restricted to the new rows: one (k x cap) cross MVM
            # against the FULL carry (k/cap of an epoch) — the new carry
            # rows are zero right after append but may be nonzero after a
            # previous block refine, so no shortcut is taken.
            kv = kernel_mvm_tiled(
                x_new, x, state.carry_v, state.params, kind=kind,
                bm=cfg.bm, bn=cfg.bn,
            )
            noise_var = state.params.noise ** 2
            r_new = targets[n0:n_real] - kv - noise_var * state.carry_v[n0:n_real]
            # The k x k sub-system is tiny; CG-to-tolerance is the right
            # tool regardless of which solver fitted the model (AP/SGD
            # block sizes need not divide k).
            nm_blk = numerics_of(self._scfg_block)
            if budget_epochs is not None:
                # budget is in full-system units; charge BOTH cross MVMs
                # (residual assembly + coupling estimate), convert the
                # remainder to k-system epochs.
                block_budget = max(0.0, budget_epochs - 2 * k / cap) * (cap / k) ** 2
                nm_blk = nm_blk._replace(max_epochs=jnp.float32(block_budget))
            bkey = jax.random.fold_in(state.key, 11)
            res = self._jit_block(x_new, r_new, None, state.params, bkey, nm_blk)
            new_carry = state.carry_v.at[n0:n_real].add(res.v)
            block_epochs = float(res.epochs)
            iters_total = int(res.iters)
            # The unpaid back-coupling K12 @ dv IS the residual the block
            # update leaves on the old rows — one more cross MVM (k/cap of
            # an epoch; computed at full capacity so the shape stays on the
            # growth ladder, with the block rows masked out — ghost rows
            # contribute exactly 0) turns it into an honest full-system
            # residual estimate: ~solver tolerance when the new rows are
            # weakly coupled to the bulk, large when more work is actually
            # needed. Operators alert on this.
            neglected = kernel_mvm_tiled(
                x, x_new, res.v, state.params, kind=kind,
                bm=cfg.bm, bn=cfg.bn,
            )
            rows = jnp.arange(cap)
            outside = jnp.logical_or(rows < n0, rows >= n_real)[:, None]
            neglected = jnp.where(outside, neglected, 0.0)
            bscale = jnp.linalg.norm(targets, axis=0) + 1e-10
            coupling = jnp.linalg.norm(neglected, axis=0) / bscale
            res_y = float(coupling[0])
            res_z = float(jnp.mean(coupling[1:])) if coupling.shape[0] > 1 \
                else res_y
            epochs_equiv = 2 * k / cap + block_epochs * (k / cap) ** 2
            corrected = False
            corr_epochs = 0.0
            if correction == "damped" and max(res_y, res_z) > tol:
                if correction_epochs <= 0:
                    raise ValueError(
                        "correction_epochs must be > 0: the budgeted polish "
                        "is what keeps the reported residual honest after "
                        "the damped step"
                    )
                # Free damped-Jacobi head start on the old rows (H's
                # diagonal is signal^2 * kappa(0) + noise^2 = signal^2 +
                # noise^2 for every registered stationary kernel), then a
                # small warm full-system polish whose solver residual is
                # the honest post-correction report.
                diag = state.params.signal ** 2 + state.params.noise ** 2
                head = new_carry - (correction_damping / diag) * neglected
                nm_c = numerics_of(self._scfg_full)._replace(
                    max_epochs=jnp.float32(correction_epochs)
                )
                ckey = jax.random.fold_in(state.key, 19)
                pres = self._jit_full(x, targets, head, state.params, ckey, nm_c)
                new_carry = pres.v
                res_y, res_z = float(pres.res_y), float(pres.res_z)
                corr_epochs = float(pres.epochs)
                epochs_equiv += corr_epochs
                iters_total += int(pres.iters)
                corrected = True
            # Fold the residual into the rolling diagnostics so a later
            # no-append refine (or a checkpoint reader) sees the TRUE state
            # of the system, not the pre-append residual.
            new_state = state._replace(
                carry_v=new_carry,
                last_res_y=jnp.float32(res_y),
                last_res_z=jnp.float32(res_z),
                last_iters=jnp.int32(iters_total),
                last_epochs=jnp.float32(epochs_equiv),
            )
            report = RefreshReport(
                n=n_real, appended=appended,
                epochs=epochs_equiv,
                iters=iters_total,
                res_y=res_y, res_z=res_z, warm=True,
                mode=mode, block_rows=k, block_epochs=block_epochs,
                corrected=corrected, correction_epochs=corr_epochs,
                capacity=cap,
            )
            threshold = (coupling_threshold if coupling_threshold is not None
                         else AUTO_COUPLING_FACTOR * tol)
            if mode == "auto" and max(res_y, res_z) > threshold:
                # The appends are too strongly coupled for the block
                # update (and the correction, if enabled): pay the full
                # warm re-solve, starting from the block-corrected carry
                # (strictly closer than the zero-padded one, so nothing
                # was wasted) with the epochs already spent subtracted
                # from the budget (no double-charging).
                nm_f = numerics_of(self._scfg_full)
                if budget_epochs is not None:
                    nm_f = nm_f._replace(max_epochs=jnp.float32(
                        max(0.0, budget_epochs - epochs_equiv)
                    ))
                fkey = key if key is not None else jax.random.fold_in(
                    state.key, 17)
                fres = self._jit_full(x, targets, new_carry, state.params,
                                      fkey, nm_f)
                new_state = state._replace(
                    carry_v=fres.v,
                    last_res_y=fres.res_y.astype(jnp.float32),
                    last_res_z=fres.res_z.astype(jnp.float32),
                    last_iters=fres.iters,
                    last_epochs=fres.epochs.astype(jnp.float32),
                )
                report = report._replace(
                    epochs=epochs_equiv + float(fres.epochs),
                    iters=iters_total + int(fres.iters),
                    res_y=float(fres.res_y), res_z=float(fres.res_z),
                    escalated=True,
                )
        else:
            raise ValueError(f"unknown refine mode {mode!r}")
        report = report._replace(trace_ids=trace_ids)
        with self._lock:
            # Appends may have raced this refine (background mode): commit the
            # solved rows into the CURRENT state so their extensions survive.
            self.state = merge_refined_state(self.state, new_state)
            if self._n > n_real:
                # Rows appended mid-refine live inside the refined capacity
                # under geometric growth (their slots pre-existed): re-zero
                # their carry so the zero-padded warm-start contract holds.
                self.state = self.state._replace(
                    carry_v=self.state.carry_v.at[n_real:self._n].set(0.0)
                )
            self._appended = max(0, self._appended - appended)
            # Drain exactly the traces this refine absorbed; ones appended
            # mid-refine stay pending for the next one.
            self._pending_traces = self._pending_traces[len(trace_ids):]
            self._record(report)
        self._emit_refresh(report)
        return report

    # -- observability -------------------------------------------------------
    def stats_dict(self) -> dict:
        """JSON-serialisable refresh counters — the ``refresh`` section of
        ``GET /stats`` (see `repro.serve.cluster.transport.ServeFrontend`).

        Cumulative: refines / escalations / corrections / growth events /
        appended rows / epochs / iters; point-in-time: real rows ``n``,
        padded ``capacity``, pending (un-refined) appends, the solve-path
        compile count, and the last `RefreshReport` (mode, epochs, coupling
        residuals, escalated/corrected flags) so a sequential driver — or an
        operator watching ``/stats`` — can see every escalation and the
        coupling residual that caused it.
        """
        with self._lock:
            out = dict(self._counters)
            rep = self._last_report
            out.update({
                "n": self._n,
                "capacity": self.capacity,
                "growth": self.growth,
                "pending_appends": self._appended,
                "num_solve_compiles": self.num_solve_compiles(),
            })
        if rep is not None:
            out["last"] = {
                "mode": rep.mode, "appended": rep.appended,
                "epochs": rep.epochs, "iters": rep.iters,
                "res_y": rep.res_y, "res_z": rep.res_z,
                "block_rows": rep.block_rows,
                "block_epochs": rep.block_epochs,
                "escalated": rep.escalated, "corrected": rep.corrected,
                "correction_epochs": rep.correction_epochs,
            }
        return out

    def export(self) -> ServableGP:
        """Freeze the current state into a serving artifact.

        Under geometric growth the artifact keeps the padded capacity shape:
        ghost rows contribute exactly 0 to every prediction (their cross-
        kernel underflows) but keep the engine's bucket executables warm
        across refreshes — the whole point of the capacity ladder.
        """
        with self._lock:
            return export_servable(
                self.state, self.x, kind=effective_kind(self.cfg, self.state.params)
            )

    def refresh_into(
        self,
        engine,
        name: Optional[str] = None,
        budget_epochs: Optional[float] = None,
        mode: str = "solve",
        warm: bool = True,
        background: bool = False,
        coupling_threshold: Optional[float] = None,
        correction: str = "none",
        correction_epochs: float = CORRECTION_EPOCHS,
        correction_damping: float = CORRECTION_DAMPING,
    ):
        """Refine, then atomically swap the new artifact into ``engine``.

        ``engine`` is a `BucketedEngine` (or a `MultiModelServer` with
        ``name``). All refinement knobs (``mode``/``warm``/``correction``/
        thresholds) pass straight through to :meth:`refine`.
        ``background=True`` runs the whole refresh on a daemon
        thread — serving continues on the old artifact until the swap — and
        returns a `concurrent.futures.Future` resolving to the
        `RefreshReport` (or carrying the exception, so failures are
        observable instead of dying with the thread). Otherwise returns the
        `RefreshReport` directly.
        """

        def _do():
            report = self.refine(budget_epochs=budget_epochs, mode=mode,
                                 warm=warm,
                                 coupling_threshold=coupling_threshold,
                                 correction=correction,
                                 correction_epochs=correction_epochs,
                                 correction_damping=correction_damping)
            model = self.export()
            if name is not None:
                engine.swap(name, model)
            else:
                engine.swap_model(model)
            return report

        if background:
            fut: Future = Future()

            def _run():
                try:
                    fut.set_result(_do())
                except BaseException as e:
                    fut.set_exception(e)

            threading.Thread(target=_run, name="gp-refresh", daemon=True).start()
            return fut
        return _do()
