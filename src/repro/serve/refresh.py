"""Warm-started online model refresh (append -> refine -> atomic swap).

The same carry that amortises outer MLL steps (paper §4) amortises *model
refresh* when observations stream in (Dong et al., 2025, "Warm-Starting
Iterative Gaussian Processes for Faster Sequential Inference"): the old
solutions, zero-padded on the appended rows, are an excellent initialisation
for the enlarged system, so a budgeted warm solve reaches tolerance in far
fewer epochs than a cold start. `OnlineGP` owns the mutable (data, state)
pair; serving stays on the frozen `ServableGP` until `refine` finishes and
the engine swap makes the new artifact visible atomically.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import replace
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import build_system_targets
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    extend_state,
    outer_step,
)
from repro.serve.artifact import ServableGP, export_servable
from repro.solvers import HOperator, kernel_mvm_tiled, solve


def merge_refined_state(
    current: OuterState, refined: OuterState
) -> OuterState:
    """Fold a refinement computed on an n-row snapshot into ``current``.

    ``current`` may have grown past the snapshot (appends that raced a
    background refine): its extra carry/probe rows — zero carry plus fresh
    base noise from `extend_state` — must survive the commit, so the solved
    rows overwrite only the snapshot's prefix. ``current``'s probes and key
    are kept (they include the concurrent extensions and key advances);
    hyperparameter/Adam/step progress is taken from ``refined``.
    """
    n_solved = refined.carry_v.shape[0]
    if current.carry_v.shape[0] > n_solved:
        carry = jnp.concatenate(
            [refined.carry_v, current.carry_v[n_solved:]], axis=0
        )
    else:
        carry = refined.carry_v
    return current._replace(
        carry_v=carry,
        params=refined.params,
        adam=refined.adam,
        step=refined.step,
        last_res_y=refined.last_res_y,
        last_res_z=refined.last_res_z,
        last_iters=refined.last_iters,
        last_epochs=refined.last_epochs,
    )



# refine(mode="auto") escalation threshold, in units of the solver
# tolerance: the block refresh's reported coupling residual sits at ~1-2x
# tolerance in its validity regime (weakly coupled appends) and orders of
# magnitude above it when the appended rows overlap the bulk, so a few
# tolerances cleanly separates the two (measured: ~2x vs ~9000x on the
# regression fixtures). Override per call with ``coupling_threshold``.
AUTO_COUPLING_FACTOR = 5.0


class RefreshReport(NamedTuple):
    """What one `refine` cost and achieved.

    ``epochs`` is always in FULL-system epoch units (one epoch = every
    entry of the n x n H computed once), so block and full refreshes are
    directly comparable: a block refresh on k new rows charges k/n of an
    epoch for the cross MVM plus ``block_epochs * (k/n)^2`` for the solve
    on the k x k sub-system. An escalated ``mode="auto"`` charges the block
    attempt PLUS the full re-solve it triggered.
    """

    n: int  # training rows after the refresh
    appended: int  # rows appended since the last refine
    epochs: float  # solver epochs consumed (full-system units)
    iters: int  # inner iterations
    res_y: float  # final mean-system relative residual
    res_z: float  # final probe-average relative residual
    warm: bool  # warm-started from the extended carry?
    mode: str = "solve"  # solve | step | block | auto
    block_rows: int = 0  # rows of the block sub-system (mode="block"/"auto")
    block_epochs: float = 0.0  # solver epochs in k-system units (block/auto)
    escalated: bool = False  # auto mode fell back to a full re-solve?


class OnlineGP:
    """A fitted GP that can absorb new observations and refresh in place.

    Typical loop:

        online = OnlineGP(x, y, fit_result.state, cfg)
        engine = BucketedEngine(online.export()); engine.warmup()
        ...
        online.append(x_new, y_new)
        online.refresh_into(engine, budget_epochs=10.0)   # solve + swap
    """

    def __init__(
        self, x: jax.Array, y: jax.Array, state: OuterState, cfg: OuterConfig
    ):
        self.x = x
        self.y = y
        self.state = state
        self.cfg = cfg
        self._appended = 0
        self._lock = threading.Lock()

    @property
    def n(self) -> int:
        return self.x.shape[0]

    def append(self, x_new: jax.Array, y_new: jax.Array) -> None:
        """Add observations; extends the warm-start carry with zero rows and
        draws fixed base-probe randomness for the new rows (core hook)."""
        if x_new.ndim != 2 or x_new.shape[1] != self.x.shape[1]:
            raise ValueError(
                f"x_new must be (k, {self.x.shape[1]}), got {x_new.shape}"
            )
        with self._lock:
            k = x_new.shape[0]
            self.x = jnp.concatenate([self.x, x_new], axis=0)
            self.y = jnp.concatenate([self.y, y_new], axis=0)
            self.state = extend_state(self.state, k, dtype=self.x.dtype)
            self._appended += k

    def refine(
        self,
        budget_epochs: Optional[float] = None,
        warm: bool = True,
        mode: str = "solve",
        key: Optional[jax.Array] = None,
        coupling_threshold: Optional[float] = None,
    ) -> RefreshReport:
        """Budgeted refinement of the enlarged system (paper §5 budgets).

        ``mode="solve"`` re-solves the linear systems at fixed hyperparameters
        (the serving-refresh fast path: tolerance is the early stop, the
        epoch budget the cap). ``mode="step"`` runs one full `outer_step`
        (hyperparameters move too). ``warm=False`` is the cold-start control
        the throughput benchmark compares against.

        ``mode="block"`` is the incremental refresh: the zero-padded old
        solution already satisfies the old rows to solver tolerance (the
        warm-start observation of Dong et al., 2025), so the residual of the
        enlarged system is concentrated on the k appended rows. The solver
        therefore runs ONLY on the k x k sub-system

            (K(x_new, x_new) + sigma^2 I) dv = b_new - H[new, :] @ v_old,

        and the correction ``dv`` lands on the new carry rows. The old rows'
        back-coupling ``H11^{-1} K12 dv`` is deliberately left unpaid — that
        is the whole saving — so the block refresh is exact up to coupling:
        machine-level parity with the full re-solve when the appended rows
        are weakly correlated with the bulk (new input region, or k << n),
        degrading as coupling grows. The report's ``res_y``/``res_z`` are an
        honest full-system residual estimate (``||K12 dv|| / ||b||``, the
        norm of the neglected old-row residual): ~solver tolerance in the
        valid regime, large when a full ``mode="solve"`` is actually needed.
        ``epochs`` reports full-system equivalents (2k/n for the two cross
        MVMs + block epochs scaled by (k/n)^2) so the saving is visible in
        the same units as ``mode="solve"``.

        ``mode="auto"`` makes the block-vs-full decision itself: it runs
        the block refresh and, when the reported coupling residual
        ``max(res_y, res_z)`` exceeds ``coupling_threshold`` (default
        ``AUTO_COUPLING_FACTOR x`` the solver tolerance), escalates to a
        full re-solve — warm-started from the block-corrected carry, so the
        block work is a head start, not waste. In the weak-coupling regime
        auto costs the same as "block"; under strongly coupled appends it
        pays the full solve instead of silently leaving a large ``res_y``.
        The report's ``escalated`` flag says which path ran.
        """
        with self._lock:
            state, x, y, cfg = self.state, self.x, self.y, self.cfg
            appended = self._appended
        kind = effective_kind(cfg, state.params)
        if mode == "step":
            scfg = cfg.solver if budget_epochs is None else replace(
                cfg.solver, max_epochs=budget_epochs
            )
            step_cfg = replace(cfg, solver=scfg, warm_start=warm)
            new_state, metrics = outer_step(state, x, y, step_cfg)
            report = RefreshReport(
                n=x.shape[0], appended=appended,
                epochs=float(metrics["epochs"]), iters=int(metrics["iters"]),
                res_y=float(metrics["res_y"]), res_z=float(metrics["res_z"]),
                warm=warm, mode=mode,
            )
        elif mode == "solve":
            targets = build_system_targets(state.probes, x, y, state.params)
            op = HOperator(x=x, params=state.params, kind=kind,
                           backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
            scfg = cfg.solver if cfg.solver.kind == kind else replace(
                cfg.solver, kind=kind
            )
            if budget_epochs is not None:
                scfg = replace(scfg, max_epochs=budget_epochs)
            v0 = state.carry_v if warm else None
            ksolve = key if key is not None else jax.random.fold_in(state.key, 13)
            res = solve(op, targets, v0, scfg, key=ksolve)
            new_state = state._replace(
                carry_v=res.v,
                last_res_y=res.res_y.astype(jnp.float32),
                last_res_z=res.res_z.astype(jnp.float32),
                last_iters=res.iters,
                last_epochs=res.epochs.astype(jnp.float32),
            )
            report = RefreshReport(
                n=x.shape[0], appended=appended,
                epochs=float(res.epochs), iters=int(res.iters),
                res_y=float(res.res_y), res_z=float(res.res_z), warm=warm,
                mode=mode,
            )
        elif mode in ("block", "auto"):
            if not warm:
                raise ValueError(
                    "block refresh refines the warm carry; it has no "
                    "cold-start variant (use mode='solve', warm=False)"
                )
            n, k = x.shape[0], appended
            if k == 0:
                return RefreshReport(
                    n=n, appended=0, epochs=0.0, iters=0,
                    res_y=float(state.last_res_y),
                    res_z=float(state.last_res_z), warm=True, mode=mode,
                )
            n0 = n - k
            targets = build_system_targets(state.probes, x, y, state.params)
            x_new = x[n0:]
            # Residual restricted to the new rows: one (k x n) cross MVM
            # against the FULL carry (k/n of an epoch) — the new carry rows
            # are zero right after extend_state but may be nonzero after a
            # previous block refine, so no shortcut is taken.
            kv = kernel_mvm_tiled(
                x_new, x, state.carry_v, state.params, kind=kind,
                bm=cfg.bm, bn=cfg.bn,
            )
            noise_var = state.params.noise ** 2
            r_new = targets[n0:] - kv - noise_var * state.carry_v[n0:]
            # The k x k sub-system is tiny; CG-to-tolerance is the right
            # tool regardless of which solver fitted the model (AP/SGD
            # block sizes need not divide k).
            scfg = replace(cfg.solver, name="cg", kind=kind)
            if budget_epochs is not None:
                # budget is in full-system units; charge BOTH cross MVMs
                # (residual assembly + coupling estimate), convert the
                # remainder to k-system epochs.
                block_budget = max(0.0, budget_epochs - 2 * k / n) * (n / k) ** 2
                scfg = replace(scfg, max_epochs=block_budget)
            op = HOperator(x=x_new, params=state.params, kind=kind,
                           backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
            res = solve(op, r_new, None, scfg)
            new_carry = jnp.concatenate(
                [state.carry_v[:n0], state.carry_v[n0:] + res.v], axis=0
            )
            new_state = state._replace(carry_v=new_carry)
            block_epochs = float(res.epochs)
            # The unpaid back-coupling K12 @ dv IS the residual the block
            # update leaves on the old rows — one more (n0 x k) cross MVM
            # (another k/n of an epoch) turns it into an honest full-system
            # residual estimate: ~solver tolerance when the new rows are
            # weakly coupled to the bulk, large when a full re-solve is
            # actually needed. Operators alert on this.
            neglected = kernel_mvm_tiled(
                x[:n0], x_new, res.v, state.params, kind=kind,
                bm=cfg.bm, bn=cfg.bn,
            )
            bscale = jnp.linalg.norm(targets, axis=0) + 1e-10
            coupling = jnp.linalg.norm(neglected, axis=0) / bscale
            res_y = float(coupling[0])
            res_z = float(jnp.mean(coupling[1:])) if coupling.shape[0] > 1 \
                else res_y
            epochs_equiv = 2 * k / n + block_epochs * (k / n) ** 2
            # Fold the coupling residual into the rolling diagnostics so a
            # later no-append refine (or a checkpoint reader) sees the
            # TRUE state of the system, not the pre-append residual.
            new_state = new_state._replace(
                last_res_y=jnp.float32(res_y),
                last_res_z=jnp.float32(res_z),
                last_iters=res.iters,
                last_epochs=jnp.float32(epochs_equiv),
            )
            report = RefreshReport(
                n=n, appended=appended,
                epochs=epochs_equiv,
                iters=int(res.iters),
                res_y=res_y, res_z=res_z, warm=True,
                mode=mode, block_rows=k, block_epochs=block_epochs,
            )
            threshold = (coupling_threshold if coupling_threshold is not None
                         else AUTO_COUPLING_FACTOR * cfg.solver.tolerance)
            if mode == "auto" and max(res_y, res_z) > threshold:
                # The appends are too strongly coupled for the block
                # update: pay the full warm re-solve, starting from the
                # block-corrected carry (strictly closer than the
                # zero-padded one, so nothing was wasted).
                op = HOperator(x=x, params=state.params, kind=kind,
                               backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
                fcfg = cfg.solver if cfg.solver.kind == kind else replace(
                    cfg.solver, kind=kind
                )
                if budget_epochs is not None:
                    fcfg = replace(fcfg, max_epochs=budget_epochs)
                fkey = key if key is not None else jax.random.fold_in(
                    state.key, 17)
                fres = solve(op, targets, new_state.carry_v, fcfg, key=fkey)
                new_state = state._replace(
                    carry_v=fres.v,
                    last_res_y=fres.res_y.astype(jnp.float32),
                    last_res_z=fres.res_z.astype(jnp.float32),
                    last_iters=fres.iters,
                    last_epochs=fres.epochs.astype(jnp.float32),
                )
                report = report._replace(
                    epochs=epochs_equiv + float(fres.epochs),
                    iters=int(res.iters) + int(fres.iters),
                    res_y=float(fres.res_y), res_z=float(fres.res_z),
                    escalated=True,
                )
        else:
            raise ValueError(f"unknown refine mode {mode!r}")
        with self._lock:
            # Appends may have raced this refine (background mode): commit the
            # solved rows into the CURRENT state so their extensions survive.
            self.state = merge_refined_state(self.state, new_state)
            self._appended = max(0, self._appended - appended)
        return report

    def export(self) -> ServableGP:
        """Freeze the current state into a serving artifact."""
        with self._lock:
            return export_servable(
                self.state, self.x, kind=effective_kind(self.cfg, self.state.params)
            )

    def refresh_into(
        self,
        engine,
        name: Optional[str] = None,
        budget_epochs: Optional[float] = None,
        mode: str = "solve",
        background: bool = False,
        coupling_threshold: Optional[float] = None,
    ):
        """Refine, then atomically swap the new artifact into ``engine``.

        ``engine`` is a `BucketedEngine` (or a `MultiModelServer` with
        ``name``). ``background=True`` runs the whole refresh on a daemon
        thread — serving continues on the old artifact until the swap — and
        returns a `concurrent.futures.Future` resolving to the
        `RefreshReport` (or carrying the exception, so failures are
        observable instead of dying with the thread). Otherwise returns the
        `RefreshReport` directly.
        """

        def _do():
            report = self.refine(budget_epochs=budget_epochs, mode=mode,
                                 coupling_threshold=coupling_threshold)
            model = self.export()
            if name is not None:
                engine.swap(name, model)
            else:
                engine.swap_model(model)
            return report

        if background:
            fut: Future = Future()

            def _run():
                try:
                    fut.set_result(_do())
                except BaseException as e:
                    fut.set_exception(e)

            threading.Thread(target=_run, name="gp-refresh", daemon=True).start()
            return fut
        return _do()
