from repro.distributed.checkpoint import (
    latest_step,
    load_metadata,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.compression import EFState, compress, decompress, ef_init
from repro.distributed.elastic import reshard, row_sharded_builder
from repro.distributed.sharding import (
    DP,
    FSDP,
    TP,
    constrain,
    get_global_mesh,
    set_global_mesh,
    valid_spec,
)

__all__ = [
    "latest_step", "load_metadata", "restore_checkpoint", "save_checkpoint",
    "EFState", "compress", "decompress", "ef_init",
    "reshard", "row_sharded_builder",
    "DP", "FSDP", "TP", "constrain", "get_global_mesh", "set_global_mesh",
    "valid_spec",
]
