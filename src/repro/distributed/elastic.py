"""Elastic re-sharding: move a checkpointed state between meshes.

Degraded-pod fallback (DESIGN.md §6): the dry-run proves the same program
compiles on 256 and 512 chips; this module moves the live state between
those meshes. Because every state pytree in the framework is dense arrays
with mesh-agnostic *rules* (PartitionSpec builders take the target mesh),
elastic re-sharding is a `jax.device_put` per leaf — no layout surgery.

Typical restart-on-smaller-fleet flow:
    state, step = restore_checkpoint(dir, template)         # host arrays
    state = reshard(state, new_mesh, spec_builder)          # place on mesh
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import valid_spec


def reshard(
    tree: Any,
    mesh: Mesh,
    spec_builder: Optional[Callable[[tuple, Any], tuple]] = None,
) -> Any:
    """device_put every leaf with specs from ``spec_builder(path, leaf)``.

    ``spec_builder`` returns a per-dimension axis tuple (as used by
    ``valid_spec``); default replicates everything.
    """

    def place(path, leaf):
        spec = spec_builder(path, leaf) if spec_builder else ()
        sh = NamedSharding(mesh, valid_spec(mesh, getattr(leaf, "shape", ()), spec))
        return jax.device_put(leaf, sh)

    return jax.tree_util.tree_map_with_path(place, tree)


def row_sharded_builder(axes=("pod", "data", "model")):
    """All leaves with ndim>=1 row-sharded over every mesh axis (GP state)."""

    def builder(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return ()
        return (axes,) + (None,) * (nd - 1)

    return builder
