"""Mesh-aware sharding policy for the LM path.

Axes convention (DESIGN.md §6):

  single-pod mesh  (16, 16)      -> ("data", "model")
  multi-pod mesh   (2, 16, 16)   -> ("pod", "data", "model")

* batch / tokens  : sharded over ("pod", "data")   [DP]
* weight TP dim   : sharded over "model"           [TP: d_ff, flattened q/kv
                    out-features, vocab, expert ffn dim]
* weight other dim: sharded over "data"            [FSDP/ZeRO-3 storage;
                    XLA all-gathers at use; per-pod FSDP — pods keep their
                    own replica and sync gradients across the pod axis]
* attention       : head-parallel over "model" when num_heads divides, else
                    Q-sequence-parallel (train) / KV-sequence-parallel
                    (decode) — divisibility-robust for all 10 archs.

`constrain` applies `with_sharding_constraint` against the process-global
mesh if one is active, silently dropping axes that do not divide the
corresponding dimension (so the same model code runs on 1-device CPU smoke
tests and 512-device dry-runs).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_GLOBAL_MESH: Optional[Mesh] = None

AxisSpec = Union[None, str, tuple]


def set_global_mesh(mesh: Optional[Mesh]) -> None:
    global _GLOBAL_MESH
    _GLOBAL_MESH = mesh


def get_global_mesh() -> Optional[Mesh]:
    return _GLOBAL_MESH


def axis_size(mesh: Mesh, axis: AxisSpec) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return math.prod(mesh.shape[a] for a in axis)


def _present(mesh: Mesh, axis: AxisSpec) -> AxisSpec:
    """Drop mesh axes that the current mesh does not have (e.g. 'pod' on the
    single-pod mesh); preserves tuple vs str structure."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    kept = tuple(a for a in axis if a in mesh.shape)
    return kept if kept else None


def valid_spec(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisSpec]) -> P:
    """PartitionSpec with non-dividing / missing axes dropped per-dimension."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        axis = _present(mesh, axis)
        if axis is not None and dim % axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, *spec: AxisSpec) -> jax.Array:
    """with_sharding_constraint against the global mesh (no-op without one)."""
    mesh = _GLOBAL_MESH
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, valid_spec(mesh, x.shape, spec))
    )


def named_sharding(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisSpec]):
    return NamedSharding(mesh, valid_spec(mesh, shape, spec))


# Logical axis names used by the model code; resolved to mesh axes here.
DP = ("pod", "data")  # batch / tokens
FSDP = "data"  # weight storage sharding (gathered at use)
TP = "model"  # tensor-parallel weight dim


def batch_spec(ndim: int) -> tuple:
    """Batch-leading activation spec: (DP, None, ...)."""
    return (DP,) + (None,) * (ndim - 1)
