"""Gradient compression with error feedback (DP all-reduce volume, DESIGN §6).

bf16 compression halves the gradient-exchange volume of the data-parallel
all-reduce. Error feedback keeps the optimiser unbiased over time: the
quantisation residual of step t is added back into the gradient at t+1
(Seide et al. / Karimireddy et al. pattern).

In the pjit data flow the cross-replica reduction happens inside backward;
casting the loss's gradients to bf16 *before* accumulation is what makes
XLA carry and reduce bf16 tensors. `ErrorFeedback` wraps the boundary
between accumulated grads and Adam:

    g_c, state = ef.compress(grads, state)     # bf16 + carried residual
    ... all-reduce happens on g_c's dtype ...
    adam_update(ef.decompress(g_c), ...)

The GP path does not use this (its gradient is d_theta ~ tens of scalars);
it exists for the LM substrate and is covered by unit tests.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # fp32 pytree, same structure as grads


def ef_init(params: Any) -> EFState:
    return EFState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def compress(grads: Any, state: EFState, dtype=jnp.bfloat16):
    """(compressed_grads, new_state): bf16 quantisation with error feedback."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(dtype)
        return q, corrected - q.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    qs, rs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r))) if flat_g else ((), ())
    return treedef.unflatten(list(qs)), EFState(residual=treedef.unflatten(list(rs)))


def decompress(grads_c: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads_c)
