"""Distributed GP outer step for the production mesh (the paper's technique
at 256/512-chip scale).

Rows of (x, y, probes, solver carry) are sharded over every mesh axis; the
H MVM is the hierarchical ring of `repro.distributed.ring`. One outer step:

  1. pathwise targets xi = Phi(x_loc) w + sigma * w_eps   (O(n m) local)
  2. warm-started CG for a FIXED epoch budget (paper §5 budget mode; the
     global residual norms are tracked for reporting, not for termination,
     so the loop is a reverse-differentiable `lax.scan`)
  3. gradient assembly: AD of sum_t c_t a_t^T H b_t through the ring MVM
  4. Adam update of the (replicated) hyperparameters

The carry (solutions V) is returned for the next step's warm start — the
paper's amortisation; it is also the checkpoint payload (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.ring import _present_axes, ring_h_mvm
from repro.gp.hyperparams import HyperParams
from repro.gp.rff import RFFState, rff_features
from repro.train.adam import AdamConfig, AdamState, adam_init, adam_update


class GPStepState(NamedTuple):
    params: HyperParams
    adam: AdamState
    carry_v: jax.Array  # (n, 1+s) row-sharded
    res_y: jax.Array
    res_z: jax.Array


def _targets(x, y, params, rff: RFFState, w_eps):
    f = rff_features(x, rff, params) @ rff.w  # (n, s) prior sample
    xi = f + params.noise * w_eps
    return jnp.concatenate([y[:, None], xi], axis=1)


def _cg_budget(x, b, v0, params, mesh, iters: int, kind: str,
               tile_dtype=jnp.float32):
    """Unpreconditioned CG for a fixed iteration budget (1 iter = 1 epoch).

    All vectors row-sharded; column dots are global reductions (XLA inserts
    the psums). `lax.scan` so the outer gradient assembly can differentiate
    through... actually the solve output is stop-gradiented; scan is used so
    trip cost appears once and is corrected analytically in the roofline.
    """
    scale = jnp.sqrt(jnp.sum(b * b, axis=0)) + 1e-10
    bn = b / scale
    v = v0 / scale
    r = bn - ring_h_mvm(x, v, params, mesh, kind=kind, tile_dtype=tile_dtype)
    d = r
    gamma = jnp.sum(r * r, axis=0)

    def body(carry, _):
        v, r, d, gamma = carry
        hd = ring_h_mvm(x, d, params, mesh, kind=kind, tile_dtype=tile_dtype)
        denom = jnp.sum(d * hd, axis=0)
        alpha = jnp.where(denom > 0, gamma / jnp.where(denom > 0, denom, 1.0), 0.0)
        v = v + alpha * d
        r = r - alpha * hd
        gamma_new = jnp.sum(r * r, axis=0)
        beta = jnp.where(gamma > 0, gamma_new / jnp.where(gamma > 0, gamma, 1.0), 0.0)
        d = r + beta * d
        return (v, r, d, gamma_new), None

    (v, r, d, gamma), _ = jax.lax.scan(body, (v, r, d, gamma), None, length=iters)
    res = jnp.sqrt(jnp.sum(r * r, axis=0))  # relative (b normalised)
    return v * scale, res


def make_gp_outer_step(
    mesh: Mesh,
    num_probes: int,
    solver_epochs: int,
    kind: str = "matern32",
    adam_lr: float = 0.03,
    tile_dtype=jnp.float32,
):
    adam_cfg = AdamConfig(learning_rate=adam_lr)

    def outer_step(state: GPStepState, x, y, rff: RFFState, w_eps):
        params = state.params
        targets = _targets(x, y, params, rff, w_eps)
        v, res = _cg_budget(
            x, targets, state.carry_v, params, mesh, solver_epochs, kind,
            tile_dtype=tile_dtype,
        )
        v = jax.lax.stop_gradient(v)

        # Pathwise gradient: 1/2 v_y^T dH v_y - 1/(2s) sum_j v_j^T dH v_j
        s = num_probes
        weights = jnp.concatenate(
            [jnp.array([0.5], v.dtype), jnp.full((s,), -0.5 / s, v.dtype)]
        )

        def quad(p):
            hv = ring_h_mvm(x, v, p, mesh, kind=kind, tile_dtype=tile_dtype)
            return jnp.sum(weights * jnp.sum(v * hv, axis=0))

        grads = jax.grad(quad)(params)
        new_params, new_adam = adam_update(
            grads, state.adam, params, adam_cfg, maximize=True
        )
        return GPStepState(
            params=new_params,
            adam=new_adam,
            carry_v=v,
            res_y=res[0],
            res_z=jnp.mean(res[1:]),
        )

    return outer_step


def lower_gp_outer_step(shape, mesh: Mesh, tile_dtype=jnp.float32):
    """AOT-lower one distributed outer step for the dry-run (abstract args)."""
    from repro.configs.gp_iterative import CONFIG as GP_CFG

    n, d, s = shape.n, shape.d, shape.num_probes
    m = GP_CFG.num_rff_pairs
    axes = _present_axes(mesh)
    row = NamedSharding(mesh, P(axes, None))
    row1 = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())

    f32 = jnp.float32
    params_abs = jax.eval_shape(lambda: HyperParams.create(d))
    adam_abs = jax.eval_shape(adam_init, params_abs)
    state_abs = GPStepState(
        params=params_abs,
        adam=adam_abs,
        carry_v=jax.ShapeDtypeStruct((n, 1 + s), f32),
        res_y=jax.ShapeDtypeStruct((), f32),
        res_z=jax.ShapeDtypeStruct((), f32),
    )
    x_abs = jax.ShapeDtypeStruct((n, d), f32)
    y_abs = jax.ShapeDtypeStruct((n,), f32)
    rff_abs = RFFState(
        z=jax.ShapeDtypeStruct((m, d), f32),
        u=jax.ShapeDtypeStruct((m,), f32),
        w=jax.ShapeDtypeStruct((2 * m, s), f32),
        kind=GP_CFG.kind,
    )
    weps_abs = jax.ShapeDtypeStruct((n, s), f32)

    state_sh = GPStepState(
        params=jax.tree.map(lambda _: repl, params_abs),
        adam=AdamState(
            step=repl,
            mu=jax.tree.map(lambda _: repl, params_abs),
            nu=jax.tree.map(lambda _: repl, params_abs),
        ),
        carry_v=row, res_y=repl, res_z=repl,
    )
    rff_sh = RFFState(z=repl, u=repl, w=repl, kind=GP_CFG.kind)

    step = make_gp_outer_step(mesh, s, shape.solver_epochs, GP_CFG.kind,
                              tile_dtype=tile_dtype)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, row, row1, rff_sh, row),
        out_shardings=state_sh,
        donate_argnums=(0,),
    )
    lowered = jitted.lower(state_abs, x_abs, y_abs, rff_abs, weps_abs)

    # MODEL_FLOPS for the GP cell: the paper's epoch accounting — one epoch
    # touches every H entry once: kernel eval ~ (3d+8) flops/entry + MVM
    # 2(1+s) flops/entry. (epochs+2 ring sweeps: +1 initial residual, +1
    # gradient pass.)
    per_entry = 3 * d + 8 + 2 * (1 + s)
    model_flops = float(n) * n * per_entry * (shape.solver_epochs + 2)
    return lowered, model_flops, f"cg_epochs={shape.solver_epochs}"
