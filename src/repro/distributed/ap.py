"""Distributed Alternating Projections with PER-SHARD greedy block selection.

The paper's AP (Alg. 2) picks the single globally-worst block per
iteration — a global argmax on the critical path every iteration, which at
512 chips is a straggler/sync hazard. The distributed variant (DESIGN.md
§6) applies the paper's greedy rule WITHIN each shard: every device solves
its own worst local block simultaneously, then the residual is updated
globally with one ring sweep over the (block, delta) pairs.

Semantics: simultaneous disjoint block updates = one sweep of damped block
Jacobi over the selected subset (Gauss-Seidel within a shard's history).
This is NOT the paper's sequential AP: with P shards a fraction P*b/n of
the rows updates at once, and the raw simultaneous update diverges when
those blocks are kernel-coupled (measured: P*b/n = 1/2 on a toy mesh
diverges even at omega=0.3). The implementation therefore applies the
additive-Schwarz safeguard: each shard's correction is scaled by
``omega / P``. For SPD H the additive block-Schwarz operator's spectrum
is bounded by the number of participating subdomains, so the scaled
update converges for any mesh size whenever ``omega < 2`` — robustness
over per-mesh damping tuning, and the price of removing the global-argmax
sync from the critical path. At production scale where coupling is weak
(512 shards, b=1000, n=1.8M, shuffled rows) ``omega`` can be raised
toward ``P`` to recover per-shard step sizes; epoch accounting
(b*devices/n of an epoch per iteration) is unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.ring import _present_axes, _rotate
from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import profile_from_r2, scaled_sqdist


def distributed_ap_sweeps(
    x: jax.Array,  # (n, d) row-sharded over all mesh axes
    b_rhs: jax.Array,  # (n, t) row-sharded targets
    v0: jax.Array,  # (n, t) row-sharded warm start
    params: HyperParams,
    mesh: Mesh,
    block_size: int,
    num_iters: int,
    kind: str = "matern32",
    omega: float = 0.3,
) -> tuple[jax.Array, jax.Array]:
    """Run ``num_iters`` per-shard-greedy AP iterations. Returns (v, r)."""
    axes = _present_axes(mesh)
    sizes = [mesh.shape[a] for a in axes]
    num_shards = 1
    for sz in sizes:
        num_shards *= sz
    # Additive-Schwarz safeguard: P simultaneous block corrections can each
    # overshoot along shared kernel-coupled directions; 1/P scaling bounds
    # the combined step (spectral radius < 1 for omega < 2, any mesh).
    omega_eff = omega / num_shards
    profile = profile_from_r2(kind)
    ls, sig = params.lengthscales, params.signal
    noise_var = params.noise**2

    def local(x_loc, b_loc, v_loc):
        n_loc, d = x_loc.shape
        nb = n_loc // block_size

        # Per-block Cholesky cache (paper: factorise once per outer step).
        xb = x_loc.reshape(nb, block_size, d)

        def chol_one(xblk):
            r2 = scaled_sqdist(xblk, xblk, ls)
            h = profile(r2, sig) + noise_var * jnp.eye(block_size)
            return jnp.linalg.cholesky(h)

        chols = jax.lax.map(chol_one, xb)

        def kv_tile(xq, xr, vr):
            r2 = scaled_sqdist(xq, xr, ls)
            return profile(r2, sig) @ vr

        # Initial local residual: r_loc = b_loc - H[loc, :] v  (ring sweep)
        def full_row_mvm(v_in):
            def level(lv, carry):
                axis, size = axes[lv], sizes[lv]

                def body(c, _):
                    acc, xr, vr = c
                    if lv + 1 < len(axes):
                        acc, xr, vr = level(lv + 1, (acc, xr, vr))
                    else:
                        acc = acc + kv_tile(x_loc, xr, vr)
                    xr, vr = _rotate((xr, vr), axis, size)
                    return (acc, xr, vr), None

                return jax.lax.scan(body, carry, None, length=size)[0]

            acc0 = jnp.zeros_like(v_in)
            acc, _, _ = level(0, (acc0, x_loc, v_in))
            return acc + noise_var * v_in

        r = b_loc - full_row_mvm(v_loc)

        def iteration(carry, _):
            v_loc, r = carry
            # Per-shard greedy: worst local block by Frobenius norm.
            blk_norms = jnp.sum(
                r.reshape(nb, block_size, -1) ** 2, axis=(1, 2)
            )
            i = jnp.argmax(blk_norms)
            start = i * block_size
            rb = jax.lax.dynamic_slice(r, (start, 0), (block_size, r.shape[1]))
            delta = omega_eff * jax.scipy.linalg.cho_solve((chols[i], True), rb)
            vb = jax.lax.dynamic_slice(v_loc, (start, 0),
                                       (block_size, v_loc.shape[1]))
            v_loc = jax.lax.dynamic_update_slice(v_loc, vb + delta, (start, 0))

            # Global residual update: every shard's (x_blk, delta) rides the
            # ring once; each device subtracts K(x_loc, x_blk_j) delta_j
            # (+ the local noise term for its own rows).
            x_blk = jax.lax.dynamic_slice(x_loc, (start, 0),
                                          (block_size, x_loc.shape[1]))

            def level(lv, carry):
                axis, size = axes[lv], sizes[lv]

                def body(c, _):
                    upd, xr, dr = c
                    if lv + 1 < len(axes):
                        upd, xr, dr = level(lv + 1, (upd, xr, dr))
                    else:
                        upd = upd + kv_tile(x_loc, xr, dr)
                    xr, dr = _rotate((xr, dr), axis, size)
                    return (upd, xr, dr), None

                return jax.lax.scan(body, carry, None, length=size)[0]

            upd0 = jnp.zeros_like(r)
            upd, _, _ = level(0, (upd0, x_blk, delta))
            # own-block noise contribution
            noise_upd = jnp.zeros_like(r)
            noise_upd = jax.lax.dynamic_update_slice(
                noise_upd, noise_var * delta, (start, 0)
            )
            r = r - upd - noise_upd
            return (v_loc, r), None

        (v_loc, r), _ = jax.lax.scan(
            iteration, (v_loc, r), None, length=num_iters
        )
        return v_loc, r

    spec = P(axes, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(spec, spec),
        check_rep=False,
    )(x, b_rhs, v0)
