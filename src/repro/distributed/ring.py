"""Distributed ring MVM for the GP path: K(x, x) @ V with rows of x and V
sharded over the whole production mesh.

shard_map implementation: each device holds a row block (x_loc, v_loc). A
rotating copy (x_rot, v_rot) moves around a hierarchical ring — innermost
over the "model" axis, then "data", then "pod" — one `collective_permute`
per step, issued before the local tile contraction so XLA's latency-hiding
scheduler overlaps communication with the Matérn tile GEMMs (DESIGN.md §6).

After `prod(mesh.shape)` steps every device has accumulated
    out_loc = sum_j K(x_loc, x_j) v_j
i.e. the full row block of K @ V. O(n_loc^2 d) compute per step, O(n_loc)
communication; K is never materialised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import profile_from_r2, scaled_sqdist

ROW_AXES = ("pod", "data", "model")  # rows sharded over every mesh axis


def _present_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ROW_AXES if a in mesh.shape)


def _rotate(tree, axis_name: str, size: int):
    """ppermute all leaves one step forward along ``axis_name``."""
    perm = [(i, (i + 1) % size) for i in range(size)]
    return jax.tree.map(
        lambda a: jax.lax.ppermute(a, axis_name, perm), tree
    )


def ring_kernel_mvm(
    x: jax.Array,  # (n, d) GLOBAL, row-sharded over all mesh axes
    v: jax.Array,  # (n, s) GLOBAL, row-sharded identically
    params: HyperParams,
    mesh: Mesh,
    kind: str = "matern32",
    tile_dtype=jnp.float32,
) -> jax.Array:
    """K(x, x) @ v on the production mesh (noise NOT added).

    ``tile_dtype=bfloat16`` evaluates the distance/profile tiles in bf16
    with fp32 accumulation (the CG tolerance tau=0.01 is ~1e2 above bf16
    kernel-entry round-off; validated in tests) — halves the dominant
    tile HBM traffic AND puts the cross-term GEMM on the MXU's native
    dtype.
    """
    axes = _present_axes(mesh)
    sizes = [mesh.shape[a] for a in axes]
    profile = profile_from_r2(kind)
    # Constrained hypers enter the manual region as explicit replicated
    # operands (closure capture of sharded tracers is rejected by shard_map).
    lengthscales = params.lengthscales
    signal = params.signal
    # With bf16 tiles, the ROTATING buffers travel the ICI in bf16 too —
    # the ring is compute/ICI balanced at fp32 (measured: 155ms vs 157ms on
    # gp_1m8), so halving rotation bytes moves it firmly compute-bound.
    comm_dtype = tile_dtype

    def local(x_loc, v_loc, ls, sig):
        x_loc_t = (x_loc / ls).astype(tile_dtype)

        # remat: reverse-AD through the ring would otherwise store every
        # (n_loc x n_loc) distance tile — O(devices * tile) HBM. Recompute
        # tiles in the backward sweep instead (they are pure functions of
        # the rotating buffers).
        @jax.checkpoint
        def tile(xr, vr):
            r2 = scaled_sqdist(
                x_loc_t, (xr / ls).astype(tile_dtype), jnp.ones((), tile_dtype)
            )
            k = profile(r2, sig.astype(tile_dtype))
            return jax.lax.dot(
                k, vr.astype(tile_dtype),
                preferred_element_type=jnp.float32,
            )

        def ring_level(level: int, carry):
            """Scan over rotations of mesh axis ``axes[level]``; inner levels
            complete a full sweep between successive rotations."""
            axis = axes[level]
            size = sizes[level]

            def body(c, _):
                acc, xr, vr = c
                if level + 1 < len(axes):
                    acc, xr, vr = ring_level(level + 1, (acc, xr, vr))
                else:
                    acc = acc + tile(xr, vr)
                xr, vr = _rotate((xr, vr), axis, size)
                return (acc, xr, vr), None

            (carry, _) = jax.lax.scan(body, carry, None, length=size)[0], None
            return carry

        acc0 = jnp.zeros((x_loc.shape[0], v_loc.shape[1]), dtype=jnp.float32)
        acc, _, _ = ring_level(
            0, (acc0, x_loc.astype(comm_dtype), v_loc.astype(comm_dtype))
        )
        return acc.astype(v_loc.dtype)

    spec = P(axes, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, P(), P()),
        out_specs=spec,
        check_rep=False,
    )(x, v, lengthscales, signal)


def ring_h_mvm(x, v, params, mesh, kind="matern32", tile_dtype=jnp.float32):
    """H @ v = K @ v + sigma^2 v (distributed)."""
    return ring_kernel_mvm(
        x, v, params, mesh, kind=kind, tile_dtype=tile_dtype
    ) + (params.noise**2) * v


def global_col_norms(r: jax.Array) -> jax.Array:
    """Per-column L2 norms of a row-sharded matrix (works under pjit: the
    reduction is a plain jnp op that XLA turns into cross-device psums)."""
    return jnp.sqrt(jnp.sum(r * r, axis=0))
