"""Atomic, resumable checkpointing for arbitrary JAX pytrees.

Design (fault-tolerance contract, DESIGN.md §6):

* **Atomic**: each checkpoint is written to ``<dir>/tmp.<step>`` and
  ``os.rename``d to ``<dir>/step_<step>.npz`` — a crash mid-write never
  corrupts the latest restorable state.
* **Self-describing enough**: leaves are stored positionally; restore takes
  a *template* pytree (same treedef) so no pickling of Python structure is
  required. A small JSON sidecar records step, leaf count and user metadata.
* **Warm-start synergy** (the paper's amortisation doubles as FT): for the
  GP path the checkpoint contains the solver carry ``V``, probe base
  randomness and Adam state — a restarted job resumes with all accumulated
  inner-solver progress intact.
* **Multi-host**: only process 0 writes (`jax.process_index() == 0`); arrays
  are fetched with `jax.device_get` (addressable shards must cover the
  arrays — fully-sharded arrays on multi-host should be gathered via
  `multihost_utils` by the caller; single-controller dry-run/CPU paths are
  covered directly).
* **Retention**: keep the last ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def file_sha256(path: str, chunk_bytes: int = 1 << 20) -> str:
    """Streaming sha256 of a file (content-addressing for artifact stores)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while block := f.read(chunk_bytes):
            h.update(block)
    return h.hexdigest()


def checkpoint_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Content-hash manifest of one checkpoint (default: latest).

    Lists every file the checkpoint consists of (the ``.npz`` payload and
    its JSON sidecar) with size and sha256, so a reader in another process
    can verify it fetched exactly what the writer published (torn copies,
    partial rsyncs and bit rot all fail loudly instead of deserialising
    garbage into a served model).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    files = {}
    for suffix in (".npz", ".json"):
        name = f"step_{step}{suffix}"
        path = os.path.join(ckpt_dir, name)
        files[name] = {
            "sha256": file_sha256(path),
            "bytes": os.path.getsize(path),
        }
    return {"step": int(step), "files": files}


def verify_manifest(ckpt_dir: str, manifest: dict) -> None:
    """Raise ValueError if any manifest-listed file is missing or corrupt."""
    for name, want in manifest["files"].items():
        path = os.path.join(ckpt_dir, name)
        if not os.path.exists(path):
            raise ValueError(f"manifest file missing: {path}")
        got = file_sha256(path)
        if got != want["sha256"]:
            raise ValueError(
                f"content hash mismatch for {path}: "
                f"manifest {want['sha256'][:12]}.., file {got[:12]}.."
            )


def _is_writer() -> bool:
    return jax.process_index() == 0


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    tree: Any,
    metadata: Optional[dict] = None,
    keep: int = 3,
) -> str:
    """Atomically persist ``tree`` at ``step``. Returns the final path."""
    if not _is_writer():
        return ""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l)) for i, l in enumerate(leaves)}
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.npz")
    final = os.path.join(ckpt_dir, f"step_{step}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)

    meta = {"step": int(step), "num_leaves": len(leaves)}
    meta.update(metadata or {})
    meta_tmp = os.path.join(ckpt_dir, f"tmp.meta.{step}.json")
    with open(meta_tmp, "w") as f:
        json.dump(meta, f)
    os.rename(meta_tmp, os.path.join(ckpt_dir, f"step_{step}.json"))

    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := _STEP_RE.search(name))
    ]
    return max(steps) if steps else None


def load_metadata(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Read the JSON sidecar written next to a checkpoint (default: latest).

    The sidecar is what makes a checkpoint self-describing across processes:
    callers that cannot rebuild the original pytree from code (e.g. loading a
    serving artifact with unknown n/s/kernel) store the shape/static info
    here and reconstruct a restore template from it.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}.json")
    with open(path) as f:
        return json.load(f)


def restore_checkpoint(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
) -> tuple[Any, int]:
    """Restore the pytree saved at ``step`` (default: latest).

    ``template`` supplies the treedef; leaf dtypes/shapes are taken from the
    stored arrays (allowing e.g. restore-then-reshard via device_put).
    Raises FileNotFoundError if no checkpoint exists.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    with np.load(path) as data:
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    treedef = jax.tree.structure(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"template has {treedef.num_leaves} leaves, checkpoint has {len(leaves)}"
        )
    tmpl_leaves = jax.tree.leaves(template)
    out = [
        jax.numpy.asarray(l, dtype=t.dtype) if hasattr(t, "dtype") else l
        for l, t in zip(leaves, tmpl_leaves)
    ]
    return jax.tree.unflatten(treedef, out), step


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := _STEP_RE.search(name))
    )
    for s in steps[:-keep] if keep > 0 else []:
        for suffix in (".npz", ".json"):
            p = os.path.join(ckpt_dir, f"step_{s}{suffix}")
            if os.path.exists(p):
                os.remove(p)
