"""Training driver for iterative-GP marginal-likelihood optimisation.

Python-level loop around the jitted `outer_step`: metrics capture, periodic
evaluation via pathwise conditioning, SGD learning-rate grid search (paper
Appendix B protocol), the large-dataset hyperparameter-initialisation
heuristic, and checkpoint/restart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimators import PATHWISE, build_system_targets, init_probes
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    init_outer_state,
    outer_step,
)
from repro.core.predict import pathwise_predict, predictive_metrics
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.gp.hyperparams import HyperParams
from repro.solvers import HOperator, SolverConfig, solve
from repro.train.adam import AdamConfig, adam_init, adam_update

SGD_LR_GRID = [5.0, 10.0, 20.0, 30.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0]


@dataclass
class FitResult:
    state: OuterState
    history: dict  # str -> np.ndarray over steps
    wall_time_s: float
    solver_time_s: float


def pick_sgd_learning_rate(
    x: jax.Array,
    y: jax.Array,
    params: HyperParams,
    cfg: OuterConfig,
    key: jax.Array,
    grid=None,
    probe_epochs: float = 3.0,
    halve: bool = False,
) -> float:
    """Paper protocol: largest grid lr whose first-step solve does not
    diverge; ``halve=True`` returns half of it (large-dataset rule)."""
    grid = sorted(grid or SGD_LR_GRID)
    n, d = x.shape
    kind = effective_kind(cfg, params)
    probes = init_probes(
        key, cfg.estimator, n, d, cfg.num_probes, cfg.num_rff_pairs,
        kind=kind, dtype=x.dtype,
    )
    targets = build_system_targets(probes, x, y, params)
    op = HOperator(x=x, params=params, kind=kind, backend=cfg.backend,
                   bm=cfg.bm, bn=cfg.bn)
    best = grid[0]
    for lr in grid:
        scfg = replace(cfg.solver, name="sgd", learning_rate=lr,
                       max_epochs=probe_epochs, kind=kind)
        res = solve(op, targets, None, scfg, key=key)
        r = float(res.res_y) + float(res.res_z)
        if np.isfinite(r) and r < 2.0 * 2.0:  # residuals are relative; >2 => diverging
            best = lr
        else:
            break
    return best / 2.0 if halve else best


def init_hypers_heuristic(
    key: jax.Array,
    x: jax.Array,
    y: jax.Array,
    subset_size: int = 10_000,
    num_centroids: int = 10,
    num_steps: int = 30,
    adam_lr: float = 0.1,
    kind: str = "matern32",
) -> HyperParams:
    """Large-dataset initialisation heuristic (paper Appendix B / Lin et al.):

    repeat ``num_centroids`` times: pick a random centroid, take its
    ``subset_size`` nearest neighbours, maximise the EXACT subset MLL;
    average the resulting hyperparameters (in raw space).
    """
    from repro.gp.exact import exact_mll

    n, d = x.shape
    subset_size = min(subset_size, n)
    keys = jax.random.split(key, num_centroids)
    acc = None

    @jax.jit
    def subset_fit(xc, yc):
        params = HyperParams.create(d, dtype=x.dtype, kernel=kind)
        adam = adam_init(params)
        cfg = AdamConfig(learning_rate=adam_lr)

        def body(carry, _):
            p, a = carry
            g = jax.grad(lambda q: exact_mll(xc, yc, q, kind=kind))(p)
            p, a = adam_update(g, a, p, cfg, maximize=True)
            return (p, a), None

        (params, _), _ = jax.lax.scan(body, (params, adam), None, length=num_steps)
        return params

    for k in keys:
        i = jax.random.randint(k, (), 0, n)
        dist = jnp.sum((x - x[i]) ** 2, axis=1)
        idx = jnp.argsort(dist)[:subset_size]
        p = subset_fit(x[idx], y[idx])
        acc = p if acc is None else jax.tree.map(jnp.add, acc, p)
    return jax.tree.map(lambda v: v / num_centroids, acc)


def fit(
    x: jax.Array,
    y: jax.Array,
    cfg: OuterConfig,
    key: Optional[jax.Array] = None,
    init_params: Optional[HyperParams] = None,
    x_test: Optional[jax.Array] = None,
    y_test: Optional[jax.Array] = None,
    eval_every: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = True,
    verbose: bool = False,
) -> FitResult:
    """Run ``cfg.num_steps`` outer MLL steps with optional eval/checkpointing.

    Restart semantics: if ``ckpt_dir`` holds a checkpoint and ``resume``,
    training continues from it — including warm-start carry and probe draws,
    so solver progress survives preemption (DESIGN.md §6).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    state = init_outer_state(key, cfg, x, init_params=init_params)
    start_step = 0
    if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
        state, start_step = restore_checkpoint(ckpt_dir, state)

    history: dict[str, list] = {
        "res_y": [], "res_z": [], "iters": [], "epochs": [],
        "hypers": [], "grad_norm": [], "data_fit": [],
        "eval_step": [], "eval_rmse": [], "eval_llh": [],
        "step_time_s": [], "solver_frac_iters": [],
    }
    t0 = time.perf_counter()
    solver_time = 0.0

    for step in range(start_step, cfg.num_steps):
        ts = time.perf_counter()
        state, metrics = outer_step(state, x, y, cfg)
        jax.block_until_ready(state.carry_v)
        dt = time.perf_counter() - ts
        solver_time += dt  # inner solve dominates; refined split in benchmarks
        history["res_y"].append(float(metrics["res_y"]))
        history["res_z"].append(float(metrics["res_z"]))
        history["iters"].append(int(metrics["iters"]))
        history["epochs"].append(float(metrics["epochs"]))
        history["hypers"].append(np.asarray(metrics["hypers"]))
        history["grad_norm"].append(float(metrics["grad_norm"]))
        history["data_fit"].append(float(metrics["data_fit"]))
        history["step_time_s"].append(dt)

        if eval_every and x_test is not None and (step + 1) % eval_every == 0:
            m = evaluate(x, state, cfg, x_test, y_test)
            history["eval_step"].append(step + 1)
            history["eval_rmse"].append(m["rmse"])
            history["eval_llh"].append(m["llh"])
            if verbose:
                print(f"[fit] step {step+1}: rmse={m['rmse']:.4f} llh={m['llh']:.4f}")

        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, state)

        if verbose:
            print(
                f"[fit] step {step+1}/{cfg.num_steps} "
                f"res_y={history['res_y'][-1]:.4f} res_z={history['res_z'][-1]:.4f} "
                f"iters={history['iters'][-1]} ({dt:.2f}s)"
            )

    if ckpt_dir:
        save_checkpoint(ckpt_dir, cfg.num_steps, state)
    wall = time.perf_counter() - t0
    hist = {k: np.asarray(v) for k, v in history.items()}
    return FitResult(state=state, history=hist, wall_time_s=wall,
                     solver_time_s=solver_time)


def evaluate(
    x: jax.Array,
    state: OuterState,
    cfg: OuterConfig,
    x_test: jax.Array,
    y_test: jax.Array,
) -> dict:
    """Test RMSE / mean predictive LLH.

    Pathwise estimator: zero extra solves (eq. 16 amortisation) — uses the
    current carry. Standard estimator: runs the s pathwise eval solves the
    paper charges to the standard path (Fig. 1), warm-started from zero.
    """
    kind = effective_kind(cfg, state.params)
    if cfg.estimator == PATHWISE:
        pred = pathwise_predict(
            x, x_test, state.carry_v, state.probes, state.params,
            kind=kind, bm=cfg.bm, bn=cfg.bn,
        )
        m = predictive_metrics(y_test, pred, state.params)
    else:
        n, d = x.shape
        key = jax.random.fold_in(state.key, 7)
        eval_probes = init_probes(
            key, PATHWISE, n, d, state.carry_v.shape[1] - 1,
            cfg.num_rff_pairs, kind=kind, dtype=x.dtype,
        )
        # Reuse v_y from the carry; solve only the s probe systems.
        targets = build_system_targets(eval_probes, x, jnp.zeros((n,), x.dtype),
                                       state.params)
        op = HOperator(x=x, params=state.params, kind=kind,
                       backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
        scfg = (cfg.solver if cfg.solver.kind == kind
                else replace(cfg.solver, kind=kind))
        res = solve(op, targets[:, 1:], None, scfg, key=key)
        v = jnp.concatenate([state.carry_v[:, :1], res.v], axis=1)
        pred = pathwise_predict(x, x_test, v, eval_probes, state.params,
                                kind=kind, bm=cfg.bm, bn=cfg.bn)
        m = predictive_metrics(y_test, pred, state.params)
    return {k: float(v) for k, v in m.items()}
