"""Training driver for iterative-GP marginal-likelihood optimisation.

Python-level loop around the jitted `outer_step`: metrics capture, periodic
evaluation via pathwise conditioning, SGD learning-rate grid search (paper
Appendix B protocol), the large-dataset hyperparameter-initialisation
heuristic, and checkpoint/restart.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimators import PATHWISE, build_system_targets, init_probes
from repro.core.outer import (
    OuterConfig,
    OuterState,
    _require_history,
    effective_kind,
    init_outer_state,
    init_outer_state_lanes,
    num_lanes,
    outer_scan,
    unstack_state,
)
from repro.core.predict import pathwise_predict, predictive_metrics
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.gp.hyperparams import HyperParams
from repro.solvers import (
    HOperator,
    SolverNumerics,
    broadcast_numerics,
    solve,
)
from repro.solvers.adaptive import (
    BudgetPolicy,
    broadcast_policy,
    resolve_horizon,
)
from repro.train.adam import AdamConfig, adam_init, adam_update

SGD_LR_GRID = [5.0, 10.0, 20.0, 30.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0]

# Divergence cut-off for the SGD learning-rate grid search (paper Appendix B:
# "the largest learning rate which does not cause divergence"). Systems are
# normalised to ||b~|| = 1 (solvers.base), so a cold-started probe solve
# begins at relative residual ~1 per system family; after the probe epochs,
# res_y + res_z above 2 + 2 means BOTH families grew past twice their
# starting norm — the iteration is expanding, not contracting.
SGD_DIVERGENCE_THRESHOLD = 4.0

# Epoch-equivalents charged to gradient assembly when splitting a measured
# step time into solve vs grad/Adam time. mll_grad_estimate differentiates
# one tiled kernel MVM: the forward pass touches every entry of H once
# (1 epoch-equivalent) and the reverse pass re-streams the tiles for the
# cotangents (~2 more). Adam and target building are O(n) and ignored.
GRAD_EPOCH_EQUIV = 3.0


@dataclass
class FitResult:
    """What `fit`/`fit_batch` return: final state + per-step history."""

    state: OuterState
    history: dict  # str -> np.ndarray over steps
    wall_time_s: float
    solver_time_s: float  # estimated inner-solve share (epoch accounting)
    grad_time_s: float = 0.0  # estimated grad-assembly + Adam share


def pick_sgd_learning_rate(
    x: jax.Array,
    y: jax.Array,
    params: HyperParams,
    cfg: OuterConfig,
    key: jax.Array,
    grid=None,
    probe_epochs: float = 3.0,
    halve: bool = False,
    divergence_threshold: float = SGD_DIVERGENCE_THRESHOLD,
) -> float:
    """Paper protocol: largest grid lr whose first-step solve does not
    diverge; ``halve=True`` returns half of it (large-dataset rule).
    "Diverged" means ``res_y + res_z`` is non-finite or exceeds
    ``divergence_threshold`` (see :data:`SGD_DIVERGENCE_THRESHOLD`),
    evaluated on the FINAL probe residual (paper protocol) — the threshold
    is deliberately NOT baked into the probe solver config, because
    freezing at the first crossing would reject learning rates whose noisy
    early residual estimate transiently overshoots but recovers within the
    probe budget."""
    grid = sorted(grid or SGD_LR_GRID)
    n, d = x.shape
    kind = effective_kind(cfg, params)
    probes = init_probes(
        key, cfg.estimator, n, d, cfg.num_probes, cfg.num_rff_pairs,
        kind=kind, dtype=x.dtype,
    )
    targets = build_system_targets(probes, x, y, params)
    op = HOperator(x=x, params=params, kind=kind, backend=cfg.backend,
                   bm=cfg.bm, bn=cfg.bn)
    best = grid[0]
    for lr in grid:
        # Pin the probe's divergence freeze OFF even if the caller's config
        # sets one: the decision must read the FINAL residual (see above).
        scfg = replace(cfg.solver, name="sgd", learning_rate=lr,
                       max_epochs=probe_epochs, kind=kind,
                       divergence_threshold=float("inf"))
        res = solve(op, targets, None, scfg, key=key)
        r = float(res.res_y) + float(res.res_z)
        if np.isfinite(r) and r < divergence_threshold:
            best = lr
        else:
            break
    return best / 2.0 if halve else best


def init_hypers_heuristic(
    key: jax.Array,
    x: jax.Array,
    y: jax.Array,
    subset_size: int = 10_000,
    num_centroids: int = 10,
    num_steps: int = 30,
    adam_lr: float = 0.1,
    kind: str = "matern32",
) -> HyperParams:
    """Large-dataset initialisation heuristic (paper Appendix B / Lin et al.):

    repeat ``num_centroids`` times: pick a random centroid, take its
    ``subset_size`` nearest neighbours, maximise the EXACT subset MLL;
    average the resulting hyperparameters (in raw space).
    """
    from repro.gp.exact import exact_mll

    n, d = x.shape
    subset_size = min(subset_size, n)
    keys = jax.random.split(key, num_centroids)
    acc = None

    @jax.jit
    def subset_fit(xc, yc):
        params = HyperParams.create(d, dtype=x.dtype, kernel=kind)
        adam = adam_init(params)
        cfg = AdamConfig(learning_rate=adam_lr)

        def body(carry, _):
            p, a = carry
            g = jax.grad(lambda q: exact_mll(xc, yc, q, kind=kind))(p)
            p, a = adam_update(g, a, p, cfg, maximize=True)
            return (p, a), None

        (params, _), _ = jax.lax.scan(body, (params, adam), None, length=num_steps)
        return params

    for k in keys:
        i = jax.random.randint(k, (), 0, n)
        dist = jnp.sum((x - x[i]) ** 2, axis=1)
        idx = jnp.argsort(dist)[:subset_size]
        p = subset_fit(x[idx], y[idx])
        acc = p if acc is None else jax.tree.map(jnp.add, acc, p)
    return jax.tree.map(lambda v: v / num_centroids, acc)


def _empty_history() -> dict[str, list]:
    return {
        "res_y": [], "res_z": [], "iters": [], "epochs": [],
        "hypers": [], "grad_norm": [], "data_fit": [],
        "eval_step": [], "eval_rmse": [], "eval_llh": [],
        "step_time_s": [], "solver_frac_iters": [],
    }


def _round_size(step: int, num_steps: int, steps_per_round: int,
                *boundaries: int) -> int:
    """Steps to scan this round: capped by ``steps_per_round`` (<= 0 means
    "all remaining") and never crossing an eval/checkpoint boundary."""
    k = num_steps - step
    if steps_per_round > 0:
        k = min(k, steps_per_round)
    for every in boundaries:
        if every:
            k = min(k, every - step % every)
    return k


def _append_round(history: dict, metrics: dict, dt: float, k: int,
                  lane: Optional[int] = None,
                  event_log=None, solver: str = "") -> float:
    """Append one scan round's stacked metrics (leading axis = k steps) to
    the per-step history lists. Returns the round's estimated solve time.

    The solve vs grad/Adam split comes from epoch accounting (the scan runs
    on-device, so there is no per-phase host timer): each step's solver work
    is ``epochs`` epoch-equivalents against :data:`GRAD_EPOCH_EQUIV` for
    gradient assembly; ``solver_frac_iters`` records that per-step fraction.

    When ``event_log`` (a :class:`repro.obs.trace.EventLog`) is given, one
    structured ``solve_step`` event is emitted per outer step — the host-side
    aggregation point for the solvers' in-loop telemetry. When the solver
    recorded residual rings (``SolverConfig.record_history``), the metrics
    carry ``res_history`` and each event (and the history dict) gets the
    step's time-ordered residual trajectory.

    Under an adaptive budget (``fit(budget_policy=...)``) the metrics carry
    the ``budget_*`` family; those columns join the history dict and each
    step additionally emits a ``budget_decision`` event — predicted vs
    realised epochs plus the controller's calibrated state (schema:
    ``docs/adaptive.md``).
    """
    def col(name, dtype=float):
        a = np.asarray(metrics[name])
        return np.asarray(a[:, lane] if lane is not None else a, dtype=dtype)

    epochs = col("epochs", np.float64)
    frac = epochs / (epochs + GRAD_EPOCH_EQUIV)
    steps = col("step", int)
    iters = col("iters", int)
    res_y, res_z = col("res_y"), col("res_z")
    history["res_y"].extend(res_y)
    history["res_z"].extend(res_z)
    history["iters"].extend(iters)
    history["epochs"].extend(epochs)
    history["hypers"].extend(col("hypers", None))
    history["grad_norm"].extend(col("grad_norm"))
    history["data_fit"].extend(col("data_fit"))
    history["step_time_s"].extend([dt / k] * k)
    history["solver_frac_iters"].extend(frac)
    rings = None
    if "res_history" in metrics:
        from repro.solvers.base import unroll_history

        a = np.asarray(metrics["res_history"])
        a = a[:, lane] if lane is not None else a  # (k, H, 2)
        rings = np.stack([unroll_history(h, i) for h, i in zip(a, iters)])
        history.setdefault("res_history", []).extend(rings)
    budget_cols = {
        name: col(name) for name in metrics if name.startswith("budget_")
    }
    for name, vals in budget_cols.items():
        history.setdefault(name, []).extend(vals)
    if event_log is not None:
        for j in range(k):
            fields = dict(
                step=int(steps[j]), solver=solver, lane=lane,
                res_y=float(res_y[j]), res_z=float(res_z[j]),
                iters=int(iters[j]), epochs=float(epochs[j]),
                step_time_s=dt / k,
            )
            if rings is not None:
                row = rings[j]
                fields["res_history"] = row[np.isfinite(row[:, 0])].tolist()
            event_log.emit("solve_step", **fields)
            if budget_cols:
                event_log.emit("budget_decision", step=int(steps[j]),
                               solver=solver, lane=lane, **{
                                   name[len("budget_"):]: float(vals[j])
                                   for name, vals in budget_cols.items()
                               })
    return float(np.sum(dt / k * frac))


def fit(
    x: jax.Array,
    y: jax.Array,
    cfg: OuterConfig,
    key: Optional[jax.Array] = None,
    init_params: Optional[HyperParams] = None,
    x_test: Optional[jax.Array] = None,
    y_test: Optional[jax.Array] = None,
    eval_every: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = True,
    verbose: bool = False,
    steps_per_round: int = 8,
    numerics: Optional[SolverNumerics] = None,
    event_log=None,
    budget_policy: Optional[BudgetPolicy] = None,
) -> FitResult:
    """Run ``cfg.num_steps`` outer MLL steps with optional eval/checkpointing.

    The outer loop runs in scan chunks of up to ``steps_per_round`` steps
    (:func:`repro.core.outer.outer_scan`): one device dispatch and one host
    sync per round instead of per step. Chunks never cross an eval or
    checkpoint boundary, and the scan body is the same traced computation
    as :func:`outer_step`, so the trajectory is independent of the chunking
    (``steps_per_round=1`` reproduces the legacy per-step loop exactly;
    ``<= 0`` scans all remaining steps in one dispatch).

    Compile-cost note: each distinct chunk length is a separate
    ``outer_scan`` executable (``num_steps`` is static). Aligned cadences —
    no boundaries, or ``eval_every``/``ckpt_every`` multiples of
    ``steps_per_round`` — use one or two; pathological co-prime cadences
    can produce one per distinct remainder, so align them when compile
    time matters.

    Restart semantics: if ``ckpt_dir`` holds a checkpoint and ``resume``,
    training continues from it — including warm-start carry and probe draws,
    so solver progress survives preemption (DESIGN.md §6).

    ``numerics`` (a scalar-leaf :class:`SolverNumerics`) overrides the
    numeric solver settings as TRACED values: runs differing only in
    tolerance/budget/lr share one executable (same maths as baking them
    into ``cfg.solver``).

    ``event_log`` (a :class:`repro.obs.trace.EventLog`) turns on structured
    telemetry: one ``solve_step`` JSONL event per outer step (residuals,
    iteration/epoch counts, per-step residual trajectory when
    ``cfg.solver.record_history`` is on) plus a final ``fit_done`` summary —
    wall-clock-free ground truth for convergence-ordering assertions.

    ``budget_policy`` (a scalar-leaf
    :class:`repro.solvers.adaptive.BudgetPolicy`, see
    ``make_budget_policy``) turns on the adaptive budget controller: each
    step's ``max_epochs`` becomes the controller's traced allocation,
    calibrated online from the solver residual rings — which requires
    ``cfg.solver.record_history >= 2`` (raises ``ValueError`` otherwise).
    An :data:`~repro.solvers.adaptive.AUTO_HORIZON` horizon is resolved to
    ``cfg.num_steps`` here. History gains the ``budget_*`` columns and
    ``event_log`` a per-step ``budget_decision`` event; ``None`` (default)
    keeps ``fit`` bit-identical to the fixed-budget behaviour.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    policy = budget_policy
    if policy is not None:
        _require_history(cfg)  # eager: fail before any compile work
        policy = resolve_horizon(policy, cfg.num_steps)
    state = init_outer_state(key, cfg, x, init_params=init_params)
    start_step = 0
    if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
        state, start_step = restore_checkpoint(ckpt_dir, state)

    history = _empty_history()
    t0 = time.perf_counter()
    solver_time = 0.0

    step = start_step
    while step < cfg.num_steps:
        k = _round_size(step, cfg.num_steps, steps_per_round,
                        eval_every if x_test is not None else 0,
                        ckpt_every if ckpt_dir else 0)
        ts = time.perf_counter()
        if policy is None:
            state, metrics = outer_scan(state, x, y, cfg, k,
                                        numerics=numerics)
        else:
            # The policy rides the scan carry WITHIN a chunk and is handed
            # back in explicitly ACROSS chunks — EMAs, anneal counter and
            # epoch pool are invariant to the chunking.
            (state, policy), metrics = outer_scan(
                state, x, y, cfg, k, numerics=numerics, budget=policy
            )
        jax.block_until_ready(state.carry_v)
        dt = time.perf_counter() - ts
        solver_time += _append_round(history, metrics, dt, k,
                                     event_log=event_log,
                                     solver=cfg.solver.name)
        step += k

        if eval_every and x_test is not None and step % eval_every == 0:
            m = evaluate(x, state, cfg, x_test, y_test, numerics=numerics)
            history["eval_step"].append(step)
            history["eval_rmse"].append(m["rmse"])
            history["eval_llh"].append(m["llh"])
            if verbose:
                print(f"[fit] step {step}: rmse={m['rmse']:.4f} llh={m['llh']:.4f}")

        if ckpt_dir and ckpt_every and step % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step, state)

        if verbose:
            print(
                f"[fit] step {step}/{cfg.num_steps} "
                f"res_y={history['res_y'][-1]:.4f} res_z={history['res_z'][-1]:.4f} "
                f"iters={history['iters'][-1]} ({dt:.2f}s/{k} steps)"
            )

    if ckpt_dir:
        save_checkpoint(ckpt_dir, cfg.num_steps, state)
    wall = time.perf_counter() - t0
    hist = {k_: np.asarray(v) for k_, v in history.items()}
    if event_log is not None:
        event_log.emit(
            "fit_done", solver=cfg.solver.name, num_steps=cfg.num_steps,
            total_iters=int(np.sum(hist["iters"])),
            total_epochs=float(np.sum(hist["epochs"])),
            wall_time_s=wall, solver_time_s=solver_time,
        )
    return FitResult(state=state, history=hist, wall_time_s=wall,
                     solver_time_s=solver_time,
                     grad_time_s=float(np.sum(hist["step_time_s"])) - solver_time)


def fit_batch(
    x: jax.Array,
    y: jax.Array,
    cfg: OuterConfig,
    keys: jax.Array,
    init_params: Optional[HyperParams] = None,
    x_test: Optional[jax.Array] = None,
    y_test: Optional[jax.Array] = None,
    verbose: bool = False,
    steps_per_round: int = 0,
    numerics: Optional[SolverNumerics] = None,
    mesh=None,
    event_log=None,
    budget_policy: Optional[BudgetPolicy] = None,
) -> list[FitResult]:
    """Fit B scenario lanes sharing one dataset and static config in ONE
    compiled program (one executable, vmap over lanes, scan over steps).

    Lanes differ in seed (``keys``: (B, 2) or a list of PRNG keys),
    optionally in initial hyperparameters (``init_params`` lane-stacked),
    and optionally in NUMERIC solver settings (``numerics`` lane-stacked:
    per-lane tolerance/budget/lr ride as traced values, so a solver-config
    grid is lanes of this one program too). Everything static — kernel
    kind, solver name, shapes — is shared, which is exactly the
    one-executable-per-group contract ``launch.batch`` partitions sweeps
    by. Lane ``l`` advances as ``fit(x, y, cfg, key=keys[l], ...)`` would
    (solver freeze masks), so results are per-cell comparable with single
    fits.

    ``mesh`` (a 1-D lane mesh, see ``repro.launch.mesh.make_lane_mesh``)
    shards the lane axis across devices: lane-stacked state/numerics are
    placed with ``NamedSharding`` over the mesh's axis, the dataset is
    replicated, and the SAME ``outer_scan`` program runs data-parallel over
    lanes (B must be a multiple of the device count). Per-lane results are
    unchanged up to fp32 accumulation order.

    ``steps_per_round <= 0`` (default) scans all steps in one dispatch.
    Checkpointing is not supported here; per-lane eval runs once at the end
    when ``x_test`` is given. Returned per-lane ``wall_time_s`` is the
    shared wall clock divided by B (the amortised per-scenario cost);
    ``solver_time_s`` splits each lane's share by its own epoch accounting.
    ``event_log`` emits lane-tagged ``solve_step`` events (see :func:`fit`).

    ``budget_policy`` turns on per-lane adaptive budgets: scalar leaves are
    broadcast to every lane, already-(B,)-stacked leaves give each lane its
    own pool/floor/ceiling — the controller then allocates, calibrates and
    anneals independently per lane inside the same executable (lane ``l``
    matches ``fit(..., budget_policy=<lane l's policy>)``). Requires
    ``cfg.solver.record_history >= 2``; see :func:`fit`.
    """
    keys = jnp.asarray(keys)
    lanes = keys.shape[0]
    states = init_outer_state_lanes(keys, cfg, x, init_params=init_params)
    assert num_lanes(states) == lanes
    if numerics is not None:
        numerics = broadcast_numerics(numerics, lanes)
    policy = budget_policy
    if policy is not None:
        _require_history(cfg)  # eager: fail before any compile work
        policy = broadcast_policy(resolve_horizon(policy, cfg.num_steps),
                                  lanes)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        ndev = mesh.devices.size
        if lanes % ndev != 0:
            raise ValueError(
                f"lanes={lanes} must be a multiple of the lane-mesh device "
                f"count {ndev} (pad the grid or drop --shard-lanes)"
            )
        lane_sharding = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))
        replicated = NamedSharding(mesh, PartitionSpec())
        states = jax.device_put(states, lane_sharding)
        x = jax.device_put(x, replicated)
        y = jax.device_put(y, replicated)
        if numerics is not None:
            numerics = jax.device_put(numerics, lane_sharding)
        if policy is not None:
            policy = jax.device_put(policy, lane_sharding)

    histories = [_empty_history() for _ in range(lanes)]
    t0 = time.perf_counter()
    solver_times = [0.0] * lanes

    step = 0
    while step < cfg.num_steps:
        k = _round_size(step, cfg.num_steps, steps_per_round)
        ts = time.perf_counter()
        if policy is None:
            states, metrics = outer_scan(states, x, y, cfg, k, lanes=True,
                                         numerics=numerics)
        else:
            (states, policy), metrics = outer_scan(
                states, x, y, cfg, k, lanes=True, numerics=numerics,
                budget=policy,
            )
        jax.block_until_ready(states.carry_v)
        dt = time.perf_counter() - ts
        # One device->host transfer per metric, not one per metric per lane.
        metrics = {name: np.asarray(v) for name, v in metrics.items()}
        for lane in range(lanes):
            solver_times[lane] += _append_round(
                histories[lane], metrics, dt / lanes, k, lane=lane,
                event_log=event_log, solver=cfg.solver.name)
        step += k
        if verbose:
            print(f"[fit_batch] step {step}/{cfg.num_steps} x {lanes} lanes "
                  f"({dt:.2f}s/{k} steps)")

    wall = time.perf_counter() - t0
    results = []
    for lane in range(lanes):
        lane_state = unstack_state(states, lane)
        hist = histories[lane]
        if x_test is not None:
            lane_num = (None if numerics is None
                        else jax.tree.map(lambda v: v[lane], numerics))
            m = evaluate(x, lane_state, cfg, x_test, y_test, numerics=lane_num)
            hist["eval_step"].append(cfg.num_steps)
            hist["eval_rmse"].append(m["rmse"])
            hist["eval_llh"].append(m["llh"])
        hist = {k_: np.asarray(v) for k_, v in hist.items()}
        results.append(FitResult(
            state=lane_state, history=hist, wall_time_s=wall / lanes,
            solver_time_s=solver_times[lane],
            grad_time_s=float(np.sum(hist["step_time_s"])) - solver_times[lane],
        ))
    return results


def evaluate(
    x: jax.Array,
    state: OuterState,
    cfg: OuterConfig,
    x_test: jax.Array,
    y_test: jax.Array,
    numerics: Optional[SolverNumerics] = None,
) -> dict:
    """Test RMSE / mean predictive LLH.

    Pathwise estimator: zero extra solves (eq. 16 amortisation) — uses the
    current carry. Standard estimator: runs the s pathwise eval solves the
    paper charges to the standard path (Fig. 1), warm-started from zero.
    """
    kind = effective_kind(cfg, state.params)
    if cfg.estimator == PATHWISE:
        pred = pathwise_predict(
            x, x_test, state.carry_v, state.probes, state.params,
            kind=kind, bm=cfg.bm, bn=cfg.bn,
        )
        m = predictive_metrics(y_test, pred, state.params)
    else:
        n, d = x.shape
        key = jax.random.fold_in(state.key, 7)
        eval_probes = init_probes(
            key, PATHWISE, n, d, state.carry_v.shape[1] - 1,
            cfg.num_rff_pairs, kind=kind, dtype=x.dtype,
        )
        # Reuse v_y from the carry; solve only the s probe systems.
        targets = build_system_targets(eval_probes, x, jnp.zeros((n,), x.dtype),
                                       state.params)
        op = HOperator(x=x, params=state.params, kind=kind,
                       backend=cfg.backend, bm=cfg.bm, bn=cfg.bn)
        scfg = (cfg.solver if cfg.solver.kind == kind
                else replace(cfg.solver, kind=kind))
        res = solve(op, targets[:, 1:], None, scfg, key=key, numerics=numerics)
        v = jnp.concatenate([state.carry_v[:, :1], res.v], axis=1)
        pred = pathwise_predict(x, x_test, v, eval_probes, state.params,
                                kind=kind, bm=cfg.bm, bn=cfg.bn)
        m = predictive_metrics(y_test, pred, state.params)
    return {k: float(v) for k, v in m.items()}
