"""Assembly of the stochastic marginal-likelihood gradient (paper eq. 5).

Given the solved batch V = [v_y, v_1..v_s] of H [v_y, v_*] = [y, b_*], the
gradient estimate for every hyperparameter is a sum of quadratic forms

    grad_k = 1/2 v_y^T (dH/dtheta_k) v_y  -  1/(2s) sum_j u_j^T (dH/dtheta_k) w_j

with (u_j, w_j) = (v_j, z_j) for the standard estimator (eq. 6) and
(v_j, v_j) for the pathwise estimator (eq. 9).

TPU/JAX adaptation (documented in DESIGN.md §3): instead of materialising the
d+2 matrices dH/dtheta_k and running one MVM each (the GPyTorch/CUDA
pattern), we differentiate the *scalar*

    S(theta) = sum_t c_t * a_t^T H(theta) b_t

through the tiled kernel MVM with the solution vectors stop-gradiented.
One reverse-mode pass yields every hyperparameter's gradient, sharing all
kernel-distance tiles across hypers — the same fusion the Pallas quadform
kernel performs explicitly in one sweep over tiles.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import PATHWISE, STANDARD
from repro.gp.hyperparams import HyperParams
from repro.solvers.operator import kernel_mvm_tiled


class GradAux(NamedTuple):
    """Diagnostics returned alongside the MLL gradient estimate."""

    data_fit: jax.Array  # -1/2 y^T v_y (the quadratic MLL term, for logging)
    quad_value: jax.Array  # value of the surrogate S (diagnostic)


def _weighted_quadratic(
    params: HyperParams,
    x: jax.Array,
    a: jax.Array,
    b: jax.Array,
    weights: jax.Array,
    kind: str,
    bm: int,
    bn: int,
) -> jax.Array:
    """S(theta) = sum_t weights_t * a[:, t]^T H(theta) b[:, t]."""
    kb = kernel_mvm_tiled(x, x, b, params, kind=kind, bm=bm, bn=bn)
    hb = kb + (params.noise**2) * b
    return jnp.sum(weights * jnp.sum(a * hb, axis=0))


def mll_grad_estimate(
    x: jax.Array,
    y: jax.Array,
    params: HyperParams,
    v: jax.Array,
    targets: jax.Array,
    estimator: str,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
):
    """Stochastic gradient of L wrt the raw hyperparameters.

    Args:
      v: (n, 1+s) solver solutions [v_y | v_1..v_s].
      targets: (n, 1+s) right-hand sides [y | b_1..b_s].
    Returns:
      (grads: HyperParams-pytree, GradAux)
    """
    s = v.shape[1] - 1
    v = jax.lax.stop_gradient(v)
    targets = jax.lax.stop_gradient(targets)
    v_y = v[:, :1]
    if estimator == STANDARD:
        a = jnp.concatenate([v_y, v[:, 1:]], axis=1)
        b = jnp.concatenate([v_y, targets[:, 1:]], axis=1)
    elif estimator == PATHWISE:
        a = jnp.concatenate([v_y, v[:, 1:]], axis=1)
        b = a
    else:
        raise ValueError(f"unknown estimator {estimator!r}")
    weights = jnp.concatenate(
        [jnp.array([0.5], dtype=v.dtype), jnp.full((s,), -0.5 / s, dtype=v.dtype)]
    )

    quad, grads = jax.value_and_grad(_weighted_quadratic)(
        params, x, a, b, weights, kind, bm, bn
    )
    data_fit = -0.5 * jnp.sum(y * v[:, 0])
    return grads, GradAux(data_fit=data_fit, quad_value=quad)


def exact_grad_reference(
    x: jax.Array,
    y: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
):
    """Dense-Cholesky exact gradient (paper's reference; tests only)."""
    from repro.gp.exact import exact_mll

    return jax.grad(lambda p: exact_mll(x, y, p, kind=kind))(params)
