"""The paper's contribution as a composable system:

estimators (standard | pathwise) x warm starting x compute budgets, around
any registered linear-system solver, driving Adam on the GP marginal
likelihood.
"""
from repro.core.estimators import (
    PATHWISE,
    STANDARD,
    ProbeState,
    build_system_targets,
    expected_initial_sqdistance,
    init_probes,
    probe_targets,
)
from repro.core.gradients import exact_grad_reference, mll_grad_estimate
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    exact_outer_step,
    extend_state,
    grow_capacity,
    init_outer_state,
    init_outer_state_lanes,
    num_lanes,
    outer_scan,
    outer_step,
    outer_step_lanes,
    stack_states,
    unstack_state,
)
from repro.core.predict import (
    Predictions,
    correction_matrix,
    mean_only_predict,
    pathwise_predict,
    pathwise_predict_from_correction,
    predictive_metrics,
)
from repro.core.driver import (
    GRAD_EPOCH_EQUIV,
    SGD_DIVERGENCE_THRESHOLD,
    FitResult,
    evaluate,
    fit,
    fit_batch,
    init_hypers_heuristic,
    pick_sgd_learning_rate,
)

__all__ = [
    "PATHWISE", "STANDARD", "ProbeState", "build_system_targets",
    "expected_initial_sqdistance", "init_probes", "probe_targets",
    "exact_grad_reference", "mll_grad_estimate",
    "OuterConfig", "OuterState", "effective_kind", "exact_outer_step",
    "extend_state", "grow_capacity", "init_outer_state",
    "init_outer_state_lanes",
    "num_lanes", "outer_scan", "outer_step", "outer_step_lanes",
    "stack_states", "unstack_state",
    "Predictions", "correction_matrix", "mean_only_predict",
    "pathwise_predict", "pathwise_predict_from_correction",
    "predictive_metrics",
    "GRAD_EPOCH_EQUIV", "SGD_DIVERGENCE_THRESHOLD",
    "FitResult", "evaluate", "fit", "fit_batch", "init_hypers_heuristic",
    "pick_sgd_learning_rate",
]
