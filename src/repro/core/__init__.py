"""The paper's contribution as a composable system:

estimators (standard | pathwise) x warm starting x compute budgets, around
any registered linear-system solver, driving Adam on the GP marginal
likelihood.
"""
from repro.core.estimators import (
    PATHWISE,
    STANDARD,
    ProbeState,
    build_system_targets,
    expected_initial_sqdistance,
    init_probes,
    probe_targets,
)
from repro.core.gradients import exact_grad_reference, mll_grad_estimate
from repro.core.outer import (
    OuterConfig,
    OuterState,
    effective_kind,
    exact_outer_step,
    extend_state,
    init_outer_state,
    outer_step,
)
from repro.core.predict import (
    Predictions,
    correction_matrix,
    mean_only_predict,
    pathwise_predict,
    pathwise_predict_from_correction,
    predictive_metrics,
)
from repro.core.driver import (
    FitResult,
    evaluate,
    fit,
    init_hypers_heuristic,
    pick_sgd_learning_rate,
)

__all__ = [
    "PATHWISE", "STANDARD", "ProbeState", "build_system_targets",
    "expected_initial_sqdistance", "init_probes", "probe_targets",
    "exact_grad_reference", "mll_grad_estimate",
    "OuterConfig", "OuterState", "effective_kind", "exact_outer_step",
    "extend_state", "init_outer_state", "outer_step",
    "Predictions", "correction_matrix", "mean_only_predict",
    "pathwise_predict", "pathwise_predict_from_correction",
    "predictive_metrics",
    "FitResult", "evaluate", "fit", "init_hypers_heuristic",
    "pick_sgd_learning_rate",
]
