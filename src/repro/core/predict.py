"""Pathwise-conditioning predictions (paper eqs. 3, 16).

With the pathwise estimator, the solved probe systems ARE posterior samples:

    (f|y)(.) = f(.) + k(., x) (v_y - z_hat_j)        [eq. 16]

so prediction costs zero extra linear solves (the paper's amortisation).
The predictive latent mean is k(., x) v_y — we fold it into the same cross-
kernel MVM by prepending the column v_y to the correction matrix.

For the *standard* estimator there are no posterior samples among the solver
outputs; callers must run `pathwise_eval_solves` (s extra solves) to obtain
them — reproducing Fig. 1's extra "prediction" cost for the standard path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import ProbeState
from repro.gp.hyperparams import HyperParams
from repro.gp.rff import prior_sample_at
from repro.solvers.operator import kernel_mvm_tiled


class Predictions(NamedTuple):
    mean: jax.Array  # (m,) latent posterior mean k(xs,x) v_y
    var: jax.Array  # (m,) latent variance (sample estimate over s paths)
    samples: jax.Array  # (m, s) posterior function samples at xs


def pathwise_predict(
    x: jax.Array,
    xs: jax.Array,
    v: jax.Array,
    probes: ProbeState,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> Predictions:
    """Posterior mean/variance/samples at xs from pathwise solver output.

    Args:
      v: (n, 1+s) solutions [v_y | z_hat_1..z_hat_s] (pathwise estimator).
    """
    if probes.estimator != "pathwise":
        raise ValueError("pathwise_predict needs pathwise solver output")
    v_y = v[:, :1]
    corrections = v_y - v[:, 1:]  # (n, s)
    d = jnp.concatenate([v_y, corrections], axis=1)  # (n, 1+s)
    cross = kernel_mvm_tiled(xs, x, d, params, kind=kind, bm=bm, bn=bn)
    mean = cross[:, 0]
    f_prior = prior_sample_at(xs, probes.rff, params)  # (m, s)
    samples = f_prior + cross[:, 1:]
    s = samples.shape[1]
    var = jnp.sum((samples - mean[:, None]) ** 2, axis=1) / jnp.maximum(s - 1, 1)
    return Predictions(mean=mean, var=jnp.maximum(var, 1e-12), samples=samples)


def predictive_metrics(
    y_test: jax.Array, pred: Predictions, params: HyperParams
) -> dict:
    """Test RMSE and mean predictive log-likelihood (paper's metrics)."""
    from repro.gp.exact import gaussian_loglik, rmse

    var_y = pred.var + params.noise**2
    return {
        "rmse": rmse(y_test, pred.mean),
        "llh": gaussian_loglik(y_test, pred.mean, var_y),
    }


def mean_only_predict(
    x: jax.Array,
    xs: jax.Array,
    v_y: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> jax.Array:
    """k(xs, x) @ v_y — works for either estimator (no variance)."""
    return kernel_mvm_tiled(xs, x, v_y[:, None], params, kind=kind, bm=bm, bn=bn)[:, 0]
