"""Pathwise-conditioning predictions (paper eqs. 3, 16).

With the pathwise estimator, the solved probe systems ARE posterior samples:

    (f|y)(.) = f(.) + k(., x) (v_y - z_hat_j)        [eq. 16]

so prediction costs zero extra linear solves (the paper's amortisation).
The predictive latent mean is k(., x) v_y — we fold it into the same cross-
kernel MVM by prepending the column v_y to the correction matrix.

For the *standard* estimator there are no posterior samples among the solver
outputs; callers must run `pathwise_eval_solves` (s extra solves) to obtain
them — reproducing Fig. 1's extra "prediction" cost for the standard path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import ProbeState
from repro.gp.hyperparams import HyperParams
from repro.gp.rff import RFFState, prior_sample_at
from repro.solvers.operator import kernel_mvm_tiled


class Predictions(NamedTuple):
    """Posterior at query points: mean, variance, and sample paths."""

    mean: jax.Array  # (m,) latent posterior mean k(xs,x) v_y
    var: jax.Array  # (m,) latent variance (sample estimate over s paths)
    samples: jax.Array  # (m, s) posterior function samples at xs


def correction_matrix(v: jax.Array) -> jax.Array:
    """Pre-concatenated correction ``[v_y | v_y - z_hat_1..z_hat_s]``.

    ``v`` is the (n, 1+s) pathwise solver output ``[v_y | z_hat_j]``. The
    result is everything eq. 16 needs from the solves, folded so that one
    cross-kernel MVM yields both the posterior mean (column 0) and all s
    sample corrections (columns 1..s). The map is invertible
    (``z_hat_j = d_0 - d_j``), so the artifact layer stores only this form.
    """
    v_y = v[:, :1]
    return jnp.concatenate([v_y, v_y - v[:, 1:]], axis=1)


def _sample_variance(samples: jax.Array, mean: jax.Array) -> jax.Array:
    """Unbiased per-row variance over the s posterior samples.

    A single sample carries no variance information — ``s == 1`` used to hit
    ``jnp.maximum(s - 1, 1)`` and silently return an all-but-zero variance,
    which poisons predictive log-likelihoods downstream. The sample count is
    a static shape, so we fail at trace time instead.
    """
    s = samples.shape[1]
    if s < 2:
        raise ValueError(
            f"posterior variance needs >= 2 pathwise samples, got s={s}; "
            "fit with num_probes >= 2 or use mean_only_predict"
        )
    var = jnp.sum((samples - mean[:, None]) ** 2, axis=1) / (s - 1)
    return jnp.maximum(var, 1e-12)


def pathwise_predict_from_correction(
    x: jax.Array,
    xs: jax.Array,
    correction: jax.Array,
    rff: "RFFState",
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> Predictions:
    """Eq. 16 evaluated from a precomputed correction matrix (jit-friendly).

    This is the serving entry point: ``correction`` is
    :func:`correction_matrix` of the solver carry, computed ONCE when a model
    is exported, so each query costs exactly one cross-kernel MVM plus one
    RFF feature evaluation — zero solves, zero per-request concatenation.
    All inputs are pytrees/arrays (``kind`` static), so the whole function
    jits into a single executable per query shape.
    """
    s_corr, s_rff = correction.shape[1] - 1, rff.w.shape[1]
    if s_corr != s_rff:
        raise ValueError(
            f"correction carries {s_corr} sample columns but the RFF state "
            f"holds {s_rff} prior samples; they must come from the same fit"
        )
    cross = kernel_mvm_tiled(xs, x, correction, params, kind=kind, bm=bm, bn=bn)
    mean = cross[:, 0]
    f_prior = prior_sample_at(xs, rff, params)  # (m, s)
    samples = f_prior + cross[:, 1:]
    return Predictions(
        mean=mean, var=_sample_variance(samples, mean), samples=samples
    )


def pathwise_predict(
    x: jax.Array,
    xs: jax.Array,
    v: jax.Array,
    probes: ProbeState,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> Predictions:
    """Posterior mean/variance/samples at xs from pathwise solver output.

    Args:
      v: (n, 1+s) solutions [v_y | z_hat_1..z_hat_s] (pathwise estimator).
    """
    if probes.estimator != "pathwise":
        raise ValueError("pathwise_predict needs pathwise solver output")
    return pathwise_predict_from_correction(
        x, xs, correction_matrix(v), probes.rff, params, kind=kind, bm=bm, bn=bn
    )


def predictive_metrics(
    y_test: jax.Array, pred: Predictions, params: HyperParams
) -> dict:
    """Test RMSE and mean predictive log-likelihood (paper's metrics)."""
    from repro.gp.exact import gaussian_loglik, rmse

    var_y = pred.var + params.noise**2
    return {
        "rmse": rmse(y_test, pred.mean),
        "llh": gaussian_loglik(y_test, pred.mean, var_y),
    }


def mean_only_predict(
    x: jax.Array,
    xs: jax.Array,
    v_y: jax.Array,
    params: HyperParams,
    kind: Optional[str] = None,
    bm: int = 1024,
    bn: int = 1024,
) -> jax.Array:
    """k(xs, x) @ v_y — works for either estimator (no variance)."""
    return kernel_mvm_tiled(xs, x, v_y[:, None], params, kind=kind, bm=bm, bn=bn)[:, 0]
