"""The outer marginal-likelihood optimisation loop (paper Fig. 2, §2.1).

Three-level hierarchy:

    outer   Adam ascent on theta (softplus-reparameterised)
    middle  standard | pathwise gradient estimator
    inner   CG | AP | SGD linear-system solver (warm-started or not)

One `outer_step` = build targets -> (maybe) warm-start from carry ->
inner solve (to tolerance and/or epoch budget) -> gradient assembly ->
Adam update -> new carry. The whole step is a single jitted function;
the solver's while-loop runs under `lax.while_loop`.

Lane batching and scan chunking: the step body is vmap-safe over
lane-stacked `OuterState`s (B scenarios differing in seed/inits advance in
one program — `outer_step_lanes`; the solver freeze masks keep early-
converging lanes identical to single runs) and `outer_scan` runs K steps
under one `lax.scan` dispatch, returning stacked metrics instead of one
host round-trip per step. Static configuration (kernel kind, solver name,
shapes) stays per-executable; grids over it are partitioned by
`repro.launch.batch`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.estimators import (
    PATHWISE,
    STANDARD,
    ProbeState,
    build_system_targets,
    init_probes,
)
from repro.core.gradients import mll_grad_estimate
from repro.gp.hyperparams import HyperParams
from repro.solvers import (
    HOperator,
    SolverConfig,
    SolverNumerics,
    numerics_of,
    solve,
)
from repro.solvers.adaptive import (
    MIN_RECORD_HISTORY,
    BudgetPolicy,
    budget_allocate,
    budget_observe,
)
from repro.train.adam import AdamConfig, AdamState, adam_init, adam_update


@dataclass(frozen=True)
class OuterConfig:
    """Static configuration of the outer MLL loop (hashable, jit-static).

    Composes the paper's three-level hierarchy: the gradient estimator
    (standard | pathwise), warm starting, and the inner `SolverConfig`,
    around Adam on the marginal likelihood.
    """

    estimator: str = PATHWISE  # standard | pathwise
    warm_start: bool = True
    num_probes: int = 64  # s (paper default)
    num_rff_pairs: int = 1000  # m sin/cos pairs (2m features)
    kind: Optional[str] = None  # registered kernel; None => params.kernel
    solver: SolverConfig = field(default_factory=SolverConfig)
    adam: AdamConfig = field(default_factory=lambda: AdamConfig(learning_rate=0.1))
    num_steps: int = 100
    backend: str = "streamed"  # HOperator backend
    bm: int = 1024
    bn: int = 1024


def effective_kind(cfg: "OuterConfig", params: HyperParams) -> str:
    """Kernel precedence: OuterConfig.kind > SolverConfig.kind > params.kernel."""
    if cfg.kind is not None:
        return cfg.kind
    if cfg.solver.kind is not None:
        return cfg.solver.kind
    return params.kernel


class OuterState(NamedTuple):
    """Everything that evolves across outer steps (a pytree; checkpointable)."""

    params: HyperParams
    adam: AdamState
    probes: ProbeState
    carry_v: jax.Array  # (n, 1+s) previous solutions (warm-start carry)
    key: jax.Array
    step: jax.Array  # int32

    # Rolling diagnostics from the last step.
    last_res_y: jax.Array
    last_res_z: jax.Array
    last_iters: jax.Array
    last_epochs: jax.Array


def init_outer_state(
    key: jax.Array,
    cfg: OuterConfig,
    x: jax.Array,
    init_params: Optional[HyperParams] = None,
) -> OuterState:
    """Fresh `OuterState`: hyperparameters, Adam, probes, zero carry.

    Args:
      key: PRNG key (split for hypers / probes / the evolving state key).
      cfg: outer-loop config (probe counts, estimator, kernel precedence).
      x: (n, d) training inputs (fixes shapes and dtype).
      init_params: starting `HyperParams`; a kernel-matched default when
        None.
    Returns:
      An `OuterState` with (n, 1+s) zero warm-start carry.
    """
    n, d = x.shape
    kp, kprobe, krest = jax.random.split(key, 3)
    if init_params is not None:
        params = init_params
    else:
        params = HyperParams.create(
            d, kernel=cfg.kind or cfg.solver.kind or "matern32"
        )
    probes = init_probes(
        kprobe, cfg.estimator, n, d, cfg.num_probes, cfg.num_rff_pairs,
        kind=effective_kind(cfg, params), dtype=x.dtype,
    )
    carry = jnp.zeros((n, 1 + cfg.num_probes), dtype=x.dtype)
    z = jnp.zeros((), jnp.float32)
    return OuterState(
        params=params,
        adam=adam_init(params),
        probes=probes,
        carry_v=carry,
        key=krest,
        step=jnp.zeros((), jnp.int32),
        last_res_y=z, last_res_z=z,
        last_iters=jnp.zeros((), jnp.int32), last_epochs=z,
    )


# Geometric capacity-growth factor for sequential appends (online serving /
# BO loops): growing the carry to factor^j * base instead of by the exact
# append size keeps the number of DISTINCT system shapes — and therefore the
# number of compiled solver executables — at O(log N) over N appended rows,
# instead of one retrace per round.
GROWTH_FACTOR = 2.0
MIN_CAPACITY = 16


def grow_capacity(
    current: int,
    needed: int,
    factor: float = GROWTH_FACTOR,
    minimum: int = MIN_CAPACITY,
) -> int:
    """Geometric capacity schedule for append-heavy workloads.

    Returns the smallest capacity ``>= needed`` on the geometric ladder
    ``max(current, minimum) * factor^j`` (j >= 0). Repeated calls over N
    one-row appends therefore return O(log N) distinct values — the compile
    count of any shape-specialised consumer (solvers, the serving engine)
    stays logarithmic in the stream length.

    Args:
      current: the present capacity (row count) of the padded arrays.
      needed: the minimum capacity that must be accommodated.
      factor: geometric growth factor (> 1).
      minimum: floor for the first allocation.
    Returns:
      int capacity ``>= max(needed, current)``.
    """
    if factor <= 1.0:
        raise ValueError(f"growth factor must be > 1, got {factor}")
    cap = max(int(current), int(minimum))
    needed = int(needed)
    while cap < needed:
        cap = max(cap + 1, int(math.ceil(cap * factor)))
    return cap


def extend_state(
    state: OuterState, num_new: int, dtype=None
) -> OuterState:
    """Extend the warm-start carry for ``num_new`` appended observations.

    The online-refresh hook (Dong et al., 2025): when new rows (x, y) stream
    in, the old solutions zero-padded on the new rows are the warm start for
    the enlarged system — the accumulated solver progress on the old rows is
    kept (negligible-bias carry, Lin et al., 2024). Base probe randomness for
    the NEW rows is drawn once here and then fixed, preserving the
    warm-start contract of Appendix B:

      * carry_v gains ``num_new`` zero rows,
      * pathwise ``w_eps`` (standard ``z``) gains ``num_new`` fresh N(0,1)
        rows — the RFF base draws are function-space and need no extension.
    """
    if num_new <= 0:
        return state
    dtype = dtype if dtype is not None else state.carry_v.dtype
    key, knew = jax.random.split(state.key)
    s = state.carry_v.shape[1] - 1
    carry = jnp.concatenate(
        [state.carry_v, jnp.zeros((num_new, 1 + s), dtype=dtype)], axis=0
    )
    probes = state.probes
    if probes.estimator == PATHWISE:
        rows = jax.random.normal(knew, (num_new, s), dtype=dtype)
        probes = probes._replace(
            w_eps=jnp.concatenate([probes.w_eps, rows], axis=0)
        )
    else:
        rows = jax.random.normal(knew, (num_new, probes.z.shape[1]), dtype=dtype)
        probes = probes._replace(z=jnp.concatenate([probes.z, rows], axis=0))
    return state._replace(carry_v=carry, probes=probes, key=key)


def _resample_probes(key: jax.Array, probes: ProbeState, x: jax.Array) -> ProbeState:
    """Fresh base randomness with identical shapes (non-warm-start regime)."""
    n, d = x.shape
    if probes.estimator == STANDARD:
        s = probes.z.shape[1]
        return init_probes(key, STANDARD, n, d, s, dtype=x.dtype)
    m = probes.rff.z.shape[0]
    s = probes.rff.w.shape[1]
    return init_probes(
        key, PATHWISE, n, d, s, num_rff_pairs=m, kind=probes.rff.kind, dtype=x.dtype
    )


def _outer_step(
    state: OuterState, x: jax.Array, y: jax.Array, cfg: OuterConfig,
    numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, dict]:
    """One outer MLL step: solve -> gradient -> Adam -> carry (unjitted).

    Pure in ``state`` given static ``cfg`` and safe to ``jax.vmap`` over
    lane-stacked states (the solver while-loops carry per-lane freeze
    masks), so the same body serves :func:`outer_step` (jit),
    :func:`outer_step_lanes` (jit-of-vmap) and :func:`outer_scan`
    (jit-of-scan[-of-vmap]).

    ``numerics`` (traced) overrides the numeric solver settings of
    ``cfg.solver`` — per-lane under vmap, so tolerance/budget/lr grids share
    one executable; None reads them from the static config (same maths).
    """
    kind = effective_kind(cfg, state.params)
    key, ksolve, kprobe = jax.random.split(state.key, 3)

    probes = state.probes
    if not cfg.warm_start:
        probes = _resample_probes(kprobe, probes, x)

    targets = build_system_targets(probes, x, y, state.params)
    v0 = state.carry_v if cfg.warm_start else None

    op = HOperator(
        x=x, params=state.params, kind=kind,
        backend=cfg.backend, bm=cfg.bm, bn=cfg.bn,
    )
    # Align the solver config with the resolved kernel so the documented
    # precedence (OuterConfig.kind > SolverConfig.kind) holds; solve()'s
    # conflict check then only fires for hand-built operator/config pairs.
    scfg = cfg.solver if cfg.solver.kind == kind else replace(cfg.solver, kind=kind)
    res = solve(op, targets, v0, scfg, key=ksolve, numerics=numerics)

    grads, aux = mll_grad_estimate(
        x, y, state.params, res.v, targets, cfg.estimator,
        kind=kind, bm=cfg.bm, bn=cfg.bn,
    )
    new_params, new_adam = adam_update(
        grads, state.adam, state.params, cfg.adam, maximize=True
    )

    new_state = OuterState(
        params=new_params,
        adam=new_adam,
        probes=probes,
        carry_v=res.v,
        key=key,
        step=state.step + 1,
        last_res_y=res.res_y.astype(jnp.float32),
        last_res_z=res.res_z.astype(jnp.float32),
        last_iters=res.iters,
        last_epochs=res.epochs.astype(jnp.float32),
    )
    metrics = {
        "step": state.step,
        "res_y": res.res_y,
        "res_z": res.res_z,
        "iters": res.iters,
        "epochs": res.epochs,
        "data_fit": aux.data_fit,
        "hypers": new_params.flat(),
        "grad_norm": jnp.sqrt(
            sum(jnp.sum(g**2) for g in jax.tree.leaves(grads))
        ),
    }
    # Solver telemetry (SolverConfig.record_history > 0): the per-iteration
    # residual ring rides the metrics dict. Static-config branch, so the
    # default (off) metrics pytree is unchanged.
    if res.res_history is not None:
        metrics["res_history"] = res.res_history
    return new_state, metrics


outer_step = partial(jax.jit, static_argnames=("cfg",))(_outer_step)


def _outer_step_lanes(
    states: OuterState, x: jax.Array, y: jax.Array, cfg: OuterConfig,
    numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, dict]:
    if numerics is None:
        return jax.vmap(lambda s: _outer_step(s, x, y, cfg))(states)
    return jax.vmap(
        lambda s, nm: _outer_step(s, x, y, cfg, nm)
    )(states, numerics)


@partial(jax.jit, static_argnames=("cfg",))
def outer_step_lanes(
    states: OuterState, x: jax.Array, y: jax.Array, cfg: OuterConfig,
    numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, dict]:
    """One outer MLL step for B lane-stacked scenarios in one program.

    ``states`` is an :class:`OuterState` whose leaves carry a leading lane
    axis (see :func:`stack_states` / :func:`init_outer_state_lanes`); the
    dataset ``(x, y)`` and the static ``cfg`` — kernel kind, solver name,
    shapes — are shared by every lane. ``numerics`` (optional) must be
    lane-stacked with (B,) leaves: lane ``l`` then solves under its OWN
    tolerance/budget/lr, so solver-config grids are lanes of this one
    executable. Returns lane-stacked ``(new_states, metrics)``; each lane
    advances exactly as it would under a plain :func:`outer_step` (solver
    freeze masks keep early-converging lanes honest).
    """
    return _outer_step_lanes(states, x, y, cfg, numerics)


def _require_history(cfg: OuterConfig) -> None:
    """Trace-time guard: adaptive budgets need the solver residual ring.

    The decay estimator fits a slope to ``SolveResult.res_history``;
    without at least :data:`MIN_RECORD_HISTORY` recorded points there is
    no model to calibrate and the controller would silently run its
    fixed-budget fallback forever — an error beats a misprediction.
    """
    if cfg.solver.record_history < MIN_RECORD_HISTORY:
        raise ValueError(
            "adaptive budgets (budget_policy=) require solver residual "
            f"telemetry: set SolverConfig.record_history >= "
            f"{MIN_RECORD_HISTORY} (got {cfg.solver.record_history}); the "
            "decay estimator fits its model to SolveResult.res_history"
        )


def _outer_step_budget(
    state: OuterState, policy: BudgetPolicy, x: jax.Array, y: jax.Array,
    cfg: OuterConfig, numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, BudgetPolicy, dict]:
    """One outer step under the adaptive budget controller (unjitted).

    allocate -> solve (the SAME :func:`_outer_step` body, with
    ``max_epochs`` replaced by the controller's traced allocation) ->
    observe (fold the step's residual ring back into the policy state).
    vmap-safe like :func:`_outer_step`: lane-stacked ``policy`` leaves
    give per-lane budgets inside one executable.

    The metrics dict gains the ``budget_*`` telemetry family — the traced
    half of the ``budget_decision`` event the driver emits per step:
    ``budget_alloc`` (epochs granted), ``budget_pred_to_tol`` (predicted
    epochs to reach tolerance; NaN before the first accepted fit),
    ``budget_realised``/``budget_res``/``budget_slope``/``budget_noise``/
    ``budget_perturbation``/``budget_grad_noise``/``budget_pool``/
    ``budget_epochs_per_iter`` from :func:`budget_observe`.
    """
    _require_history(cfg)
    num = numerics if numerics is not None else numerics_of(cfg.solver)
    alloc, pred = budget_allocate(policy, num)
    new_state, metrics = _outer_step(
        state, x, y, cfg, num._replace(max_epochs=alloc)
    )
    new_policy, decision = budget_observe(
        policy, metrics["res_history"], metrics["iters"], metrics["epochs"],
        metrics["res_y"], metrics["res_z"], num.tolerance,
    )
    metrics["budget_alloc"] = alloc
    metrics["budget_pred_to_tol"] = pred
    for name, val in decision.items():
        metrics[f"budget_{name}"] = val
    return new_state, new_policy, metrics


outer_step_budget = partial(jax.jit, static_argnames=("cfg",))(
    _outer_step_budget
)


def _outer_step_budget_lanes(
    states: OuterState, policy: BudgetPolicy, x: jax.Array, y: jax.Array,
    cfg: OuterConfig, numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, BudgetPolicy, dict]:
    if numerics is None:
        return jax.vmap(
            lambda s, p: _outer_step_budget(s, p, x, y, cfg)
        )(states, policy)
    return jax.vmap(
        lambda s, p, nm: _outer_step_budget(s, p, x, y, cfg, nm)
    )(states, policy, numerics)


@partial(jax.jit, static_argnames=("cfg",))
def outer_step_budget_lanes(
    states: OuterState, policy: BudgetPolicy, x: jax.Array, y: jax.Array,
    cfg: OuterConfig, numerics: Optional[SolverNumerics] = None,
) -> tuple[OuterState, BudgetPolicy, dict]:
    """Lane-stacked :func:`outer_step_budget`: each lane allocates, solves
    and observes under its OWN :class:`BudgetPolicy` leaves (and optional
    per-lane ``numerics``) — adaptive tolerance/budget grids stay one
    executable, exactly like :func:`outer_step_lanes`.
    """
    return _outer_step_budget_lanes(states, policy, x, y, cfg, numerics)


@partial(jax.jit, static_argnames=("cfg", "num_steps", "lanes"))
def outer_scan(
    state: OuterState,
    x: jax.Array,
    y: jax.Array,
    cfg: OuterConfig,
    num_steps: int,
    lanes: bool = False,
    numerics: Optional[SolverNumerics] = None,
    budget: Optional[BudgetPolicy] = None,
) -> tuple[OuterState, dict]:
    """Run ``num_steps`` outer MLL steps under one ``lax.scan`` dispatch.

    Kills the per-step host round-trip of the Python driver loop: one
    device program advances the whole chunk and returns stacked metrics
    with a leading ``num_steps`` axis (plus a lane axis right after it when
    ``lanes=True`` and ``state`` is lane-stacked). Step semantics are
    identical to iterating :func:`outer_step` — the scan body is the same
    traced function. ``numerics`` is threaded to every step (lane-stacked
    when ``lanes=True``); with lane-sharded inputs (``NamedSharding`` over
    the lane axis) the same program runs data-parallel across devices.

    ``budget`` (a :class:`BudgetPolicy`, lane-stacked when ``lanes=True``)
    switches the scan body to :func:`_outer_step_budget`: the policy state
    rides the scan carry — EMAs and the epoch pool survive chunk
    boundaries because the caller passes the RETURNED policy into the next
    chunk — and the return value becomes ``((state, policy), metrics)``
    with the ``budget_*`` metrics family stacked over steps. ``None``
    (default) is the existing fixed-budget path, bit-identical to before.
    """
    if budget is None:
        step = _outer_step_lanes if lanes else _outer_step

        def body(s, _):
            return step(s, x, y, cfg, numerics)

        return jax.lax.scan(body, state, None, length=num_steps)

    bstep = _outer_step_budget_lanes if lanes else _outer_step_budget

    def bbody(carry, _):
        s, p = carry
        s2, p2, m = bstep(s, p, x, y, cfg, numerics)
        return (s2, p2), m

    return jax.lax.scan(bbody, (state, budget), None, length=num_steps)


def stack_states(states) -> OuterState:
    """Stack single-scenario :class:`OuterState` pytrees into one lane-
    stacked state (lane axis 0). All states must share static structure
    (kernel kind, estimator, shapes) — that is the one-executable contract.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(states: OuterState, lane: int) -> OuterState:
    """Extract lane ``lane`` of a lane-stacked state as a single state."""
    return jax.tree.map(lambda v: v[lane], states)


def num_lanes(states: OuterState) -> int:
    """Lane count of a lane-stacked state."""
    return states.carry_v.shape[0]


def init_outer_state_lanes(
    keys: jax.Array,
    cfg: OuterConfig,
    x: jax.Array,
    init_params: Optional[HyperParams] = None,
) -> OuterState:
    """Initialise B lanes in one shot: ``keys`` is (B, 2); ``init_params``
    may be lane-stacked (per-lane inits) or unstacked (shared init).
    Lane ``l`` is initialised exactly as ``init_outer_state(keys[l], ...)``.
    """
    if init_params is None:
        return jax.vmap(lambda k: init_outer_state(k, cfg, x))(keys)
    p_axis = 0 if jnp.ndim(init_params.raw_signal) > 0 else None
    return jax.vmap(
        lambda k, p: init_outer_state(k, cfg, x, init_params=p),
        in_axes=(0, p_axis),
    )(keys, init_params)


def exact_outer_step(
    params: HyperParams, adam: AdamState, x: jax.Array, y: jax.Array,
    adam_cfg: AdamConfig, kind: Optional[str] = None,
):
    """Reference: one Adam step on the EXACT Cholesky MLL gradient.

    Produces the paper's exact-optimisation trajectories (Figs. 5/8/11-13).
    """
    from repro.gp.exact import exact_mll

    mll, grads = jax.value_and_grad(lambda p: exact_mll(x, y, p, kind=kind))(params)
    new_params, new_adam = adam_update(grads, adam, params, adam_cfg, maximize=True)
    return new_params, new_adam, mll
