"""Standard and pathwise gradient-estimator probe machinery (paper §2.1, §3).

A *probe state* carries the base randomness behind the right-hand sides of
the batched linear system

    H [v_y, v_1..v_s] = [y, b_1..b_s].

* standard  (eq. 6):  b_j = z_j                with z_j ~ N(0, I)
* pathwise  (eq. 11): b_j = xi_j = f(x) + eps  with f ~ GP(0,k) via RFF,
                      eps = sigma * w_eps,  so xi_j ~ N(0, H_theta)

Warm-start contract (paper §4, Appendix B): with warm starting the base
randomness is drawn ONCE and kept fixed; only the deterministic
reparameterisation tracks theta (RFF frequencies from fixed (z, u); noise
eps = sigma * w_eps). Without warm starting, base randomness is resampled
every outer step (the paper's unbiased regime).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.gp.hyperparams import HyperParams
from repro.gp.rff import RFFState, init_rff, prior_sample_at

STANDARD = "standard"
PATHWISE = "pathwise"


class ProbeState(NamedTuple):
    """Fixed base randomness for either estimator (a pytree).

    For ``standard``: ``z`` (n, s) are the probes; rff/w_eps are None.
    For ``pathwise``: ``rff`` holds (z, u, w) for prior samples, ``w_eps``
    (n, s) is the base noise draw; z is None.

    ``estimator`` is registered as static aux data (not a leaf) so the
    state can flow through jit-ted outer steps.
    """

    estimator: str
    z: Optional[jax.Array]  # (n, s) standard probes
    rff: Optional[RFFState]  # pathwise prior-sample machinery
    w_eps: Optional[jax.Array]  # (n, s) base noise draws


jax.tree_util.register_pytree_node(
    ProbeState,
    lambda s: ((s.z, s.rff, s.w_eps), s.estimator),
    lambda est, children: ProbeState(est, *children),
)


def init_probes(
    key: jax.Array,
    estimator: str,
    n: int,
    d: int,
    num_probes: int,
    num_rff_pairs: int = 1000,
    kind: str = "matern32",
    dtype=jnp.float32,
) -> ProbeState:
    """Draw the probe randomness for one fit.

    Args:
      key: PRNG key.
      estimator: `STANDARD` (n-dim Gaussian probes z) or `PATHWISE` (RFF
        prior-sample state + (n, s) base noise w_eps).
      n: training rows; d: input dimension; num_probes: s.
      num_rff_pairs: sin/cos feature pairs for the pathwise prior samples.
      kind: registered kernel name (selects the RFF spectral sampler).
    Returns:
      A `ProbeState` pytree (estimator name rides as static aux data).
    """
    if estimator == STANDARD:
        z = jax.random.normal(key, (n, num_probes), dtype=dtype)
        return ProbeState(estimator=STANDARD, z=z, rff=None, w_eps=None)
    if estimator == PATHWISE:
        krff, keps = jax.random.split(key)
        rff = init_rff(krff, num_rff_pairs, d, num_probes, kind=kind, dtype=dtype)
        w_eps = jax.random.normal(keps, (n, num_probes), dtype=dtype)
        return ProbeState(estimator=PATHWISE, z=None, rff=rff, w_eps=w_eps)
    raise ValueError(f"unknown estimator {estimator!r}")


def probe_targets(
    probes: ProbeState, x: jax.Array, params: HyperParams
) -> jax.Array:
    """Right-hand sides b_1..b_s (n, s) for the current hyperparameters.

    standard: constant in theta. pathwise: xi = Phi_theta(x) w + sigma*w_eps,
    re-evaluated deterministically from the fixed base draws (paper App. B).
    """
    if probes.estimator == STANDARD:
        return probes.z
    f_x = prior_sample_at(x, probes.rff, params)  # (n, s)
    return f_x + params.noise * probes.w_eps


def build_system_targets(
    probes: ProbeState, x: jax.Array, y: jax.Array, params: HyperParams
) -> jax.Array:
    """Full batched RHS [y | b_1..b_s] of shape (n, 1+s)."""
    b = probe_targets(probes, x, params)
    return jnp.concatenate([y[:, None], b], axis=1)


def expected_initial_sqdistance(probes: ProbeState, h_dense: jax.Array) -> float:
    """Theory check (eqs. 14/15): E ||0 - u||_H^2 for a probe system.

    standard -> tr(H^-1); pathwise -> n. Used by tests/benchmarks only
    (needs a dense H).
    """
    n = h_dense.shape[0]
    if probes.estimator == STANDARD:
        return float(jnp.trace(jnp.linalg.inv(h_dense)))
    return float(n)
