"""Adam optimiser (Kingma & Ba), from scratch on pytrees.

Used by (i) the outer-loop marginal-likelihood optimiser (paper: Adam with
default betas, lr 0.1 small / 0.03 large datasets) and (ii) the LM training
path (bf16 params + fp32 moments). optax is intentionally not vendored — the
framework is self-contained.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first-moment pytree (fp32)
    nu: Any  # second-moment pytree (fp32)


class AdamConfig(NamedTuple):
    learning_rate: float = 0.1
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # decoupled (AdamW); 0 disables
    grad_clip_norm: float = 0.0  # global-norm clip; 0 disables


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
    zeros2 = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adam_update(
    grads: Any,
    state: AdamState,
    params: Any,
    cfg: AdamConfig,
    *,
    maximize: bool = False,
):
    """One Adam step. Returns (new_params, new_state).

    ``maximize=True`` ascends (the MLL outer loop maximises L); LM training
    descends on the loss.
    """
    if maximize:
        grads = jax.tree.map(lambda g: -g, grads)
    if cfg.grad_clip_norm > 0.0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gn + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = m / b1t
        vhat = v / b2t
        delta = cfg.learning_rate * mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0.0:
            delta = delta + cfg.learning_rate * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)
