"""Solver comparison (paper Table 1, one dataset): CG vs AP vs SGD under
the four estimator/warm-start variants, solving to tolerance.

    PYTHONPATH=src python examples/solver_comparison.py
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks.common import bench_dataset, run_variant  # noqa: E402


def main():
    ds = bench_dataset("elevators", max_n=1500)
    print(f"{'solver':6s} {'estimator':10s} {'warm':5s} "
          f"{'epochs':>8s} {'time(s)':>8s} {'LLH':>8s}")
    for solver in ("cg", "ap", "sgd"):
        for pathwise in (False, True):
            for warm in (False, True):
                r = run_variant(ds, solver, pathwise, warm, steps=15,
                                sgd_lr=2.0)
                print(f"{solver:6s} {'pathwise' if pathwise else 'standard':10s} "
                      f"{str(warm):5s} {r['total_epochs']:8.1f} "
                      f"{r['total_time_s']:8.1f} "
                      f"{r.get('test_llh', float('nan')):8.3f}")


if __name__ == "__main__":
    main()
