"""LM substrate demo: train a reduced config of each assigned architecture
for a few steps and decode from it — the same train_step/serve_step that the
512-chip dry-run lowers at full scale.

    PYTHONPATH=src python examples/lm_substrate_demo.py [--arch llama3-8b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import LM_ARCHS, get_config
from repro.data.synthetic import make_lm_batch
from repro.models import (
    init_cache,
    init_params,
    make_serve_step,
    make_train_step,
)
from repro.models.transformer import prefill_cross_cache
from repro.train.adam import adam_init


def demo(arch: str, steps: int = 5):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adam_init(params)
    train = jax.jit(make_train_step(cfg, num_microbatches=1))
    B, S = 4, 64
    for i in range(steps):
        batch = make_lm_batch(jax.random.fold_in(key, i), B, S, cfg.vocab_size)
        if cfg.is_encdec:
            batch = {
                "frames": jax.random.normal(jax.random.fold_in(key, 99 + i),
                                            (B, S, cfg.d_model)) * 0.3,
                "tokens": batch["tokens"][:, : cfg.decoder_len],
                "labels": batch["labels"][:, : cfg.decoder_len],
                "mask": batch["mask"][:, : cfg.decoder_len],
            }
        elif cfg.frontend.kind == "vision":
            batch["patch_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 199 + i),
                (B, cfg.frontend.num_prefix, cfg.frontend.embed_dim)) * 0.3
        params, opt, loss = train(params, opt, batch)
        print(f"  [{arch}] train step {i}: loss={float(loss):.4f}")

    # greedy decode a few tokens
    cache = init_cache(cfg, 2, 32, enc_len=16 if cfg.is_encdec else 0)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.3
        cache = prefill_cross_cache(params, cfg, frames, cache)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    toks = jnp.zeros((2,), jnp.int32)
    out = []
    for pos in range(8):
        logits, cache = serve(params, cache, toks, jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(toks[0]))
    print(f"  [{arch}] greedy decode: {out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one arch id (default: all ten)")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(LM_ARCHS)
    for arch in archs:
        print(f"== {arch} ==")
        demo(arch)


if __name__ == "__main__":
    main()
