"""Large-data regime (paper §5): limited compute budgets + warm starting.

Reproduces the Fig. 10 phenomenon end-to-end: under a budget of a few
solver epochs per outer step, warm starting lets solver progress ACCUMULATE
across steps — residuals fall over the trajectory — while the cold-started
solver's residuals stagnate. Uses the AP solver and the large-dataset
hyperparameter-initialisation heuristic.

    PYTHONPATH=src python examples/budget_large_scale.py
"""
import jax
import numpy as np

from repro.core import OuterConfig, fit, init_hypers_heuristic
from repro.data.synthetic import load_dataset, pad_to_block_multiple
from repro.solvers import SolverConfig
from repro.train.adam import AdamConfig


def main():
    # 3DROAD's (n, d) signature, truncated for CPU (same code path scales
    # to the paper's n=391k on accelerators / the ring MVM on a pod).
    ds = load_dataset("3droad", max_n=4000)
    block = 200
    x, y, _ = pad_to_block_multiple(ds.x_train, ds.y_train, block)

    # Paper's large-data heuristic: exact MLL on nearest-neighbour subsets.
    init = init_hypers_heuristic(jax.random.PRNGKey(1), x, y,
                                 subset_size=500, num_centroids=3,
                                 num_steps=15)
    print("heuristic init:", {k: np.round(np.asarray(v), 3).tolist()
                              for k, v in init.constrained().items()})

    for warm in (False, True):
        cfg = OuterConfig(
            estimator="pathwise",
            warm_start=warm,
            num_probes=32,
            solver=SolverConfig(name="ap", tolerance=0.01,
                                max_epochs=3,  # tiny budget!
                                block_size=block),
            adam=AdamConfig(learning_rate=0.03),
            num_steps=15,
            bm=512, bn=512,
        )
        res = fit(x, y, cfg, key=jax.random.PRNGKey(0), init_params=init,
                  x_test=ds.x_test, y_test=ds.y_test, eval_every=15)
        rz = res.history["res_z"]
        print(f"warm_start={warm}: res_z first->last "
              f"{rz[0]:.3f} -> {rz[-1]:.3f}; "
              f"test LLH={res.history['eval_llh'][-1]:.4f}")


if __name__ == "__main__":
    main()
