"""Quickstart: the paper's full pipeline in ~60 lines.

Trains GP hyperparameters on a synthetic UCI-shaped dataset with the
pathwise estimator + warm-started CG (the paper's fastest configuration),
then makes amortised predictions via pathwise conditioning — zero extra
linear solves at prediction time.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    OuterConfig,
    fit,
    pathwise_predict,
    predictive_metrics,
)
from repro.data.synthetic import load_dataset
from repro.solvers import SolverConfig
from repro.train.adam import AdamConfig


def main():
    # 1. Data: synthetic stand-in with POL's (n, d) signature, truncated to
    #    a laptop-friendly size (drop data/uci/pol.csv in to use real UCI).
    ds = load_dataset("pol", max_n=2000)
    print(f"dataset={ds.name} n_train={ds.x_train.shape[0]} "
          f"d={ds.x_train.shape[1]}")

    # 2. Configure the three-level hierarchy (paper Fig. 2):
    #    Adam (outer) / pathwise estimator (middle) / warm-started CG (inner).
    cfg = OuterConfig(
        estimator="pathwise",   # paper §3
        warm_start=True,        # paper §4
        num_probes=32,          # s (paper uses 64; 32 is quick)
        solver=SolverConfig(name="cg", tolerance=0.01, max_epochs=200,
                            precond_rank=50),
        adam=AdamConfig(learning_rate=0.1),
        num_steps=40,
        bm=512, bn=512,
    )

    # 3. Optimise the marginal likelihood.
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(0),
              x_test=ds.x_test, y_test=ds.y_test, eval_every=10,
              verbose=True)
    print(f"total wall time: {res.wall_time_s:.1f}s; "
          f"solver iterations/step: {res.history['iters'].tolist()}")

    # 4. Amortised prediction (eq. 16): the probe solutions ARE posterior
    #    samples; no further solves.
    state = res.state
    pred = pathwise_predict(ds.x_train, ds.x_test, state.carry_v,
                            state.probes, state.params, bm=512, bn=512)
    m = predictive_metrics(ds.y_test, pred, state.params)
    print(f"test RMSE={float(m['rmse']):.4f} "
          f"test LLH={float(m['llh']):.4f} "
          f"({pred.samples.shape[1]} posterior samples, 0 extra solves)")


if __name__ == "__main__":
    main()
