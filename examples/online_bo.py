"""Online Bayesian optimisation on the serving stack, end to end.

Fits a GP surrogate on a handful of observations of a multi-modal
objective, then runs a sequential acquire -> observe -> append -> refresh
loop (`repro.online.run_bo`): every round predicts over a fixed candidate
set through the bucketed serving engine, picks the UCB argmax, appends the
new observation via `OnlineGP`, and refreshes with the warm block path
(damped old-row correction, auto-escalation). The loop is shape-stable —
capacity for every append is reserved up front — so after warmup there are
ZERO retraces and the per-round solver cost stays at ~block scale instead
of a full re-solve.

    PYTHONPATH=src python examples/online_bo.py
"""
import jax

from repro.core import OuterConfig, fit
from repro.gp.hyperparams import HyperParams
from repro.online import BOConfig, make_gaussian_bumps, run_bo
from repro.solvers import SolverConfig


def main():
    # 1. A black box worth optimising: four Gaussian bumps in 2-D; the best
    #    bump's height is the (approximate) optimum used for regret.
    key = jax.random.PRNGKey(0)
    objective, f_opt = make_gaussian_bumps(jax.random.fold_in(key, 1), d=2)

    # 2. Surrogate: pathwise estimator + warm-started CG — the engine's
    #    predictive variance comes from the pathwise sample paths, and the
    #    warm carry is what makes per-round refreshes cheap.
    cfg = OuterConfig(
        estimator="pathwise", num_probes=8, num_rff_pairs=128,
        solver=SolverConfig(name="cg", tolerance=1e-2, precond_rank=0),
        num_steps=5, bm=256, bn=256,
    )
    x0 = jax.random.uniform(jax.random.fold_in(key, 2), (64, 2),
                            minval=-1.0, maxval=1.0)
    y0 = objective(x0)
    res = fit(x0, y0, cfg, key=jax.random.fold_in(key, 3),
              init_params=HyperParams.create(2, lengthscale=0.3,
                                             signal=1.0, noise=0.1))

    # 3. The sequential loop: 40 rounds, 256 candidates per round, block
    #    refresh with damped correction (auto-escalates only if the
    #    corrected residual stays above threshold).
    out = run_bo(
        objective, x0, y0, res.state, cfg,
        bo=BOConfig(rounds=40, num_candidates=256,
                    refresh_mode="auto", correction="damped"),
        bounds=(-1.0, 1.0), f_opt=f_opt,
    )

    for e in out.history[::8]:
        print(f"  round {e['round']:3d}: y={e['y']:+.3f} "
              f"best={e['best_y']:+.3f} regret={e['regret']:.4f} "
              f"mode={e.get('mode', '-')} epochs={e.get('epochs', 0.0):.2f}"
              f"{' [corrected]' if e.get('corrected') else ''}"
              f"{' [escalated]' if e.get('escalated') else ''}")
    print(f"best y={out.best_y:.4f} (optimum ~{f_opt:.4f}, "
          f"regret {out.regret:.4f}) after {len(out.history)} rounds")
    print(f"solver cost: {out.cum_epochs:.1f} cumulative epochs, "
          f"{out.escalations} escalations, {out.corrections} corrections, "
          f"{out.engine_retraces} engine retraces after warmup, "
          f"{out.solve_compiles} solver executables")


if __name__ == "__main__":
    main()
