"""Documentation lint: docstring coverage + markdown link integrity.

Stdlib-only (ast + pathlib — runnable in a bare CI job, no jax import, no
new dependencies), two checks:

  1. **Docstring coverage** — every public module, class, and function /
     method (name not starting with ``_``) under the packages in
     ``LINT_PACKAGES`` must carry a docstring. Nested (closure) functions
     are exempt: they are implementation detail, not API surface.
  2. **Markdown links** — every relative link / image target in README.md
     and docs/*.md must resolve to an existing file (anchors and external
     http/mailto links are skipped; pure-anchor links are checked against
     the current file's headings).

Run: python tools/docs_lint.py [--root REPO]   (exits non-zero on findings)
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

# Packages whose public API must be documented (repo-relative).
LINT_PACKAGES = (
    "src/repro/solvers",
    "src/repro/core",
    "src/repro/serve",
    "src/repro/online",
    "src/repro/obs",
    "src/repro/analysis",
)

# Markdown files whose links must resolve (docs/*.md globbed separately).
LINT_MARKDOWN = ("README.md",)

# [text](target) — target split from an optional "title" suffix.
_MD_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_MD_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_MD_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def missing_docstrings(path: Path) -> list[str]:
    """Public defs/classes (and the module itself) lacking docstrings.

    Returns human-readable ``file:line: <what>`` strings; empty = clean.
    """
    tree = ast.parse(path.read_text(), filename=str(path))
    findings = []
    if ast.get_docstring(tree) is None:
        findings.append(f"{path}:1: module docstring missing")

    def check_body(body, prefix: str):
        for node in body:
            if isinstance(node, ast.ClassDef):
                if _is_public(node.name):
                    if ast.get_docstring(node) is None:
                        findings.append(
                            f"{path}:{node.lineno}: class "
                            f"{prefix}{node.name} docstring missing"
                        )
                    # Methods are API surface; nested classes too.
                    check_body(node.body, f"{prefix}{node.name}.")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_public(node.name) and ast.get_docstring(node) is None:
                    findings.append(
                        f"{path}:{node.lineno}: def "
                        f"{prefix}{node.name} docstring missing"
                    )

    check_body(tree.body, "")
    return findings


def _anchor_of(heading: str) -> str:
    """GitHub-style anchor: lowercase, spaces -> dashes, drop punctuation."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def broken_links(path: Path, root: Path) -> list[str]:
    """Relative markdown links that do not resolve; empty = clean.

    Fenced code blocks are stripped first (shell snippets full of
    ``$(...)`` are not links). ``#anchor``-only links are validated
    against the file's own headings; cross-file anchors validate the file
    part only.
    """
    text = _MD_FENCE.sub("", path.read_text())
    anchors = {_anchor_of(h) for h in _MD_HEADING.findall(text)}
    findings = []
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        line = text[: m.start()].count("\n") + 1
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in anchors:
                findings.append(
                    f"{path}:{line}: anchor {target!r} has no matching "
                    f"heading"
                )
            continue
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).resolve().exists():
            findings.append(
                f"{path}:{line}: link target {target!r} does not exist"
            )
    return findings


def run_lint(root: Path) -> list[str]:
    """All findings for the repo at ``root`` (see module docstring)."""
    findings = []
    for pkg in LINT_PACKAGES:
        pkg_dir = root / pkg
        for py in sorted(pkg_dir.rglob("*.py")):
            findings.extend(missing_docstrings(py))
    md_files = [root / m for m in LINT_MARKDOWN]
    md_files.extend(sorted((root / "docs").glob("*.md")))
    for md in md_files:
        if md.exists():
            findings.extend(broken_links(md, root))
    return findings


def main(argv=None) -> int:
    """CLI entry; prints findings and returns the exit code."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=str(Path(__file__).parent.parent))
    args = ap.parse_args(argv)
    findings = run_lint(Path(args.root))
    for f in findings:
        print(f)
    n_py = sum(1 for f in findings if "docstring" in f)
    n_md = len(findings) - n_py
    if findings:
        print(f"[docs-lint] FAIL: {n_py} docstring + {n_md} link finding(s)")
        return 1
    print("[docs-lint] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
