"""repro-lint CLI: project-invariant static analysis.

Thin wrapper so the suite runs from a checkout without installing the
package (mirrors ``tools/docs_lint.py``): puts ``src`` on ``sys.path``
and delegates to :mod:`repro.analysis.runner`. Stdlib-only — no jax
import — so it works in the bare ``static-lint`` CI job.

Usage::

    python tools/repro_lint.py --check            # exit 1 on findings
    python tools/repro_lint.py --update-baseline  # refresh the ledger
"""
from __future__ import annotations

import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO / "src"))

from repro.analysis.runner import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] + ([] if any(
        a.startswith("--root") for a in sys.argv[1:])
        else ["--root", str(_REPO)])))
