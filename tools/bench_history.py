"""Benchmark-regression observatory over the per-module bench histories.

Reads the rolling JSONL histories that ``benchmarks/run.py`` appends under
``<bench-dir>/history/`` (see ``benchmarks/history.py`` for the layout) and
compares each module's NEWEST entry against a rolling baseline — the
per-metric median of up to ``--window`` preceding entries (falling back to
a ``--baseline`` directory of committed ``BENCH_*.json`` snapshots when a
history has no past yet).

Each metric is classified by the direction table below: for lower-is-better
metrics (latencies, wall time, epochs) a regression is
``new > median * max-ratio``; for higher-is-better metrics (qps, speedups)
it is ``new < median / max-ratio``. Unclassified metrics render in the
trend report but never gate. ``--check`` exits nonzero on any regression,
so CI can gate on it.

Usage:
    python tools/bench_history.py [--bench-dir artifacts/bench]
        [--check] [--max-ratio 1.5] [--window 5]
        [--baseline DIR] [--modules a,b]

Stdlib only (imports ``benchmarks.history`` for the file layout — no jax).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import sys

# Make `benchmarks.history` importable when run as `python tools/...` from
# the repo root (benchmarks/ is a namespace package next to tools/).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import history  # noqa: E402

_SPARK = "▁▂▃▄▅▆▇█"

# Direction rules, first match wins (matched against the full dotted key).
#   "lower"  — smaller is better (time, epochs, latency)
#   "higher" — bigger is better (throughput, speedups)
# Metrics matching no rule are informational only: rendered, never gating.
RULES = [
    (re.compile(r"(^|\.)us_per_(call|step)$"), "lower"),
    (re.compile(r"(^|\.)wall_s$"), "lower"),
    (re.compile(r"(^|\.)(p50|p99|latency_p\d+)(_ms|_s)?$"), "lower"),
    (re.compile(r"(^|\.)cum_epochs$"), "lower"),
    (re.compile(r"(^|\.)epoch_ratio_warm_over_cold$"), "lower"),
    (re.compile(r"(^|\.)(qps|rounds_per_sec|throughput)$"), "higher"),
    (re.compile(r"(^|\.)speedup"), "higher"),
    (re.compile(r"(^|\.)epoch_ratio_best_fixed_over_adaptive$"), "higher"),
]

# Below this magnitude a ratio is numerical noise, not a signal.
_EPS = 1e-12


def direction_for(key: str):
    """'lower' / 'higher' for gated metrics, None for informational ones."""
    for pattern, direction in RULES:
        if pattern.search(key):
            return direction
    return None


def sparkline(values) -> str:
    """Linear-scale sparkline of a metric's history (empty for < 2 pts)."""
    finite = [v for v in values if isinstance(v, (int, float))]
    if len(finite) < 2:
        return ""
    lo, hi = min(finite), max(finite)
    span = (hi - lo) or 1.0
    return "".join(
        _SPARK[int((v - lo) / span * (len(_SPARK) - 1))] for v in finite)


def load_baseline_dir(baseline_dir: str, module: str):
    """Committed ``BENCH_<module>.json`` flattened, or None."""
    path = os.path.join(baseline_dir, f"BENCH_{module}.json")
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    metrics = history.flatten_metrics(report)
    return metrics or None


def check_module(entries, window: int, max_ratio: float,
                 baseline_metrics=None):
    """Compare the newest entry against the rolling baseline.

    Returns (findings, note). Each finding is a dict with key, direction,
    baseline, new, ratio, regressed. ``note`` explains a skipped module
    (no entries / no baseline).
    """
    if not entries:
        return [], "no history entries"
    newest = entries[-1]["metrics"]
    prior = entries[:-1][-window:]
    baselines = {}
    if prior:
        keys = set()
        for e in prior:
            keys.update(e["metrics"])
        for key in keys:
            vals = [e["metrics"][key] for e in prior if key in e["metrics"]]
            if vals:
                baselines[key] = statistics.median(vals)
    elif baseline_metrics:
        baselines = dict(baseline_metrics)
    else:
        return [], "no baseline yet (first run) — recorded, not gated"

    findings = []
    for key in sorted(newest):
        direction = direction_for(key)
        base = baselines.get(key)
        new = newest[key]
        if base is None or not isinstance(new, (int, float)):
            continue
        if max(abs(base), abs(new)) < _EPS:
            continue
        if direction == "lower":
            ratio = new / base if abs(base) > _EPS else float("inf")
            regressed = ratio > max_ratio
        elif direction == "higher":
            ratio = base / new if abs(new) > _EPS else float("inf")
            regressed = ratio > max_ratio
        else:
            ratio = new / base if abs(base) > _EPS else float("nan")
            regressed = False
        findings.append({
            "key": key, "direction": direction, "baseline": base,
            "new": new, "ratio": ratio, "regressed": regressed,
        })
    return findings, None


def render_module(module, entries, findings, note, verbose=False) -> int:
    """Print the trend block for one module; returns its regression count."""
    print(f"== {module} ({len(entries)} run(s))")
    if note:
        print(f"   {note}")
        return 0
    regressions = 0
    for f in findings:
        if f["regressed"]:
            regressions += 1
        gate = f["direction"] or "info"
        if not verbose and f["direction"] is None and not f["regressed"]:
            continue
        series = [e["metrics"].get(f["key"]) for e in entries]
        trend = sparkline([v for v in series if v is not None])
        flag = "REGRESSION" if f["regressed"] else "ok"
        print(f"   {flag:>10}  {f['key']:<48} {gate:<6} "
              f"base={f['baseline']:<12.6g} new={f['new']:<12.6g} "
              f"ratio={f['ratio']:<8.3g} {trend}")
    if regressions == 0 and not any(f["direction"] for f in findings):
        print("   (no gated metrics — informational only; --verbose to list)")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--bench-dir", default="artifacts/bench",
                    help="bench output dir holding history/ (and BENCH_*.json)")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="directory of committed BENCH_*.json used as the "
                         "baseline when a module's history has no past")
    ap.add_argument("--modules", default=None,
                    help="comma-separated module subset (default: all with "
                         "history)")
    ap.add_argument("--window", type=int, default=5,
                    help="rolling-baseline size: median of up to K preceding "
                         "entries")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="regression threshold: worse than baseline by this "
                         "factor fails (use ~5 for cross-machine CI noise)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any gated metric regressed")
    ap.add_argument("--verbose", action="store_true",
                    help="also list informational (ungated) metrics")
    args = ap.parse_args(argv)

    modules = (args.modules.split(",") if args.modules
               else history.list_modules(args.bench_dir))
    if not modules:
        print(f"no bench histories under {args.bench_dir}/history — "
              f"run `python -m benchmarks.run` first")
        return 1 if args.check else 0

    total_regressions = 0
    checked = 0
    for module in modules:
        entries = history.load_history(args.bench_dir, module)
        baseline_metrics = (load_baseline_dir(args.baseline, module)
                            if args.baseline else None)
        findings, note = check_module(
            entries, args.window, args.max_ratio, baseline_metrics)
        if note is None:
            checked += 1
        total_regressions += render_module(
            module, entries, findings, note, verbose=args.verbose)

    print(f"-- {checked}/{len(modules)} module(s) gated, "
          f"{total_regressions} regression(s), "
          f"max-ratio {args.max_ratio}, window {args.window}")
    if args.check and total_regressions:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
